"""Fault-tolerance runtime + elastic membership replan.

The supervisor/requeue tests are pure host (no JAX model).  The e2e
elastic TrainLoop test needs 4 emulated hosts — run it (and the CI leg
does) with

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -q tests/test_fault_tolerance.py
"""

import jax
import numpy as np
import pytest

from repro.checkpoint import latest_step
from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                        MembershipEvent, make_scheduler)
from repro.core.engine import PlanEngine
from repro.core.schedulers import AWF
from repro.runtime import (FailureInjector, TrainSupervisor, WorkerLost,
                           plan_degraded_mesh)
from repro.sched import StragglerMitigator

needs_hosts = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(the multi-host CI leg)")


def _counter_step(log=None):
    """Deterministic (state, step) -> state: w += step + 1, loss = f(w).
    Restore-equivalence holds iff the checkpoint round-trips exactly."""
    def make_step(state, step):
        w = state["w"] + float(step + 1)
        loss = float(np.sum(w) / (step + 1))
        if log is not None:
            log[step] = loss
        return {"w": w}, {"loss": loss}
    return make_step


def _init():
    return {"w": np.zeros(3)}


# --------------------------------------------------------------- supervisor
def test_transient_and_device_faults_restore(tmp_path):
    sup = TrainSupervisor(_counter_step(), _init, str(tmp_path),
                          ckpt_every=4, num_hosts=1,
                          injector=FailureInjector({3: "transient",
                                                    9: "device"}))
    rep = sup.run(11)
    assert rep.steps_completed == 11
    assert rep.restarts == 2
    # step-3 fault predates any checkpoint (fresh re-init, not a restore);
    # the step-9 fault restores from the step-8 checkpoint
    assert rep.restores == [8]
    assert rep.membership_events == [] and rep.requeued == []


def test_final_checkpoint_saved_when_steps_not_multiple(tmp_path):
    """Regression: total_steps % ckpt_every != 0 must still leave a
    checkpoint at the final step — otherwise ANY later restore of the
    directory silently re-executes the tail."""
    sup = TrainSupervisor(_counter_step(), _init, str(tmp_path),
                          ckpt_every=5, num_hosts=1)
    sup.run(13)
    assert latest_step(str(tmp_path)) == 13
    # a resume of the finished run must re-execute ZERO steps
    log = {}
    sup2 = TrainSupervisor(_counter_step(log), _init, str(tmp_path),
                           ckpt_every=5, num_hosts=1)
    rep2 = sup2.run(13)
    assert rep2.steps_completed == 13 and log == {}


def test_loss_trajectory_equivalence_under_faults(tmp_path):
    """Every step's recomputed loss after a restore must equal the
    uninterrupted run's — the checkpoint round-trips the exact state."""
    clean = {}
    TrainSupervisor(_counter_step(clean), _init,
                    str(tmp_path / "clean"), ckpt_every=4).run(14)
    faulted = {}
    rep = TrainSupervisor(
        _counter_step(faulted), _init, str(tmp_path / "faulted"),
        ckpt_every=4,
        injector=FailureInjector({5: "transient", 11: "device"})).run(14)
    assert rep.steps_completed == 14
    assert faulted == clean


def test_elastic_downsize_resizes_mitigator(tmp_path):
    """Regression: repeated faults halve the team — the mitigator MUST
    follow (it used to keep the old num_hosts, so share vectors and
    observe_step validation ran against a dead team size)."""
    sizes = []
    sup = TrainSupervisor(_counter_step(), _init, str(tmp_path),
                          ckpt_every=4, num_hosts=4,
                          injector=FailureInjector({3: "device",
                                                    4: "device"}),
                          on_elastic=lambda n: sizes.append(n),
                          elastic_after_failures=2)
    rep = sup.run(9)
    assert rep.steps_completed == 9
    assert sizes == [2]
    assert sup.mitigator.num_hosts == 2 == rep.final_hosts
    assert len(rep.membership_events) == 1
    ev = rep.membership_events[0]
    assert ev.kind == "loss" and ev.old_size == 4 and ev.new_size == 2
    assert ev.lost == (2, 3)
    # shares over the survivors: uniform cold start, sums exactly
    shares = sup.mitigator.token_shares(1000)
    assert shares.tolist() == [500, 500]
    # feeding a dead host id must fail loudly, not mis-attribute
    with pytest.raises(ValueError, match="resize"):
        sup.mitigator.observe_step({3: 0.1})


def test_host_loss_membership_callback_ordering(tmp_path):
    """on_membership fires AFTER the requeue audit and mitigator resize:
    the callback sees the new team everywhere it looks."""
    seen = []

    def on_membership(event):
        seen.append((event.lost, sup.mitigator.num_hosts, sup.num_hosts))

    sup = TrainSupervisor(_counter_step(), _init, str(tmp_path),
                          ckpt_every=3, num_hosts=4,
                          injector=FailureInjector({7: "host_loss:1,2"}),
                          on_membership=on_membership)
    rep = sup.run(10)
    assert rep.steps_completed == 10
    assert seen == [((1, 2), 2, 2)]
    assert rep.restores and rep.restores[0] == 6   # newest ckpt, no step lost
    assert latest_step(str(tmp_path)) == 10


def test_injector_host_loss_parses_ids():
    inj = FailureInjector({2: "host_loss:2,3", 5: "host_loss",
                           7: "transient"})
    with pytest.raises(WorkerLost) as e:
        inj.check(2)
    assert e.value.lost == (2, 3)
    with pytest.raises(WorkerLost) as e:
        inj.check(5)
    assert e.value.lost is None       # unnamed: supervisor picks a default
    with pytest.raises(RuntimeError):
        inj.check(7)
    assert inj.check(3) is None       # non-fault steps pass through


# ---------------------------------------------------------- requeue + plans
def test_requeue_covers_lost_work_exactly():
    """completed-by-the-dead + survivors' own + requeued == [0, N),
    disjointly — no iteration lost, none double-run."""
    eng = PlanEngine()
    loop = LoopSpec(0, 500, num_workers=4, loop_id="rq")
    plan = eng.plan(make_scheduler("fac2"), loop)
    lost = (1, 3)
    done_chunks = plan.owned_chunk_ids(lost)[:3]   # they finished 3 chunks
    new_plan, iter_map = eng.requeue_plan(
        plan, "fac2", lost_workers=lost, num_workers=2,
        completed_chunks=done_chunks)
    assert new_plan.coverage_ok()
    assert len(iter_map) == new_plan.loop.ub
    survivors_iters = {i for c in plan.owned_chunk_ids((0, 2))
                       for i in range(int(plan.starts[c]),
                                      int(plan.starts[c] + plan.sizes[c]))}
    done_iters = {i for c in done_chunks
                  for i in range(int(plan.starts[c]),
                                 int(plan.starts[c] + plan.sizes[c]))}
    requeued = set(iter_map)
    assert survivors_iters | done_iters | requeued == set(range(500))
    assert not (survivors_iters & requeued) and not (done_iters & requeued)


def test_membership_event_bumps_adaptive_plan_cache():
    """A membership change must invalidate cached adaptive plans — the
    sentinel invocation is the same epoch edge as a measured flush."""
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 800, num_workers=2, loop_id="mb")
    sched = AWF(variant="timestep")
    p1 = eng.plan(sched, loop, history=hist)
    assert eng.plan(sched, loop, history=hist) is p1       # cached
    tel = LoopTelemetry(hist, loop_id="mb", num_workers=2)
    tel.record_membership(MembershipEvent(kind="loss", old_size=2,
                                          new_size=1, lost=(1,)))
    assert eng.plan(sched, loop, history=hist) is not p1   # epoch bumped


def test_membership_sentinel_survives_json_and_rates():
    hist = LoopHistory()
    tel = LoopTelemetry(hist, loop_id="loop", num_workers=4)
    tel.record_chunk(0, 0, 10, 0.5)
    tel.record_chunk(1, 10, 20, 0.5)
    tel.flush()
    before = hist.measured_invocations("loop")
    tel.record_membership(MembershipEvent(kind="loss", old_size=4,
                                          new_size=2, lost=(2, 3)))
    assert hist.measured_invocations("loop") == before + 1
    restored = LoopHistory.from_json(hist.to_json())
    assert (restored.measured_invocations("loop")
            == hist.measured_invocations("loop"))
    tags = [inv.scheduler for inv in restored.invocations("loop")]
    assert "membership(4->2)" in tags
    # the zero-size sentinel is invisible to the rate statistics
    assert restored.worker_rates("loop") == hist.worker_rates("loop")
    assert -1 not in restored.worker_rates("loop")


def test_mitigator_resize_floors_history_window():
    """Post-churn shares come from the NEW team's measurements only —
    pre-churn invocations (4-host rates) never leak into a 2-host split."""
    m = StragglerMitigator(num_hosts=4, min_share=0.1)
    for _ in range(4):
        shares = m.token_shares(1000)
        m.observe_step({h: 0.1 * (2.0 if h == 3 else 1.0)
                        for h in range(4)},
                       host_tokens={h: max(int(shares[h]), 1)
                                    for h in range(4)})
    ev = m.resize(2, lost=(2, 3), step=4)
    assert ev.tag == "membership(4->2)"
    assert m.token_shares(1000).tolist() == [500, 500]   # uniform cold start
    m.observe_step({0: 0.1, 1: 0.2})
    shares = m.token_shares(1000)
    assert shares.sum() == 1000 and shares[0] > shares[1]


def test_plan_degraded_mesh_warns_on_capacity_loss():
    with pytest.warns(RuntimeWarning, match="idles 3 of 7"):
        assert plan_degraded_mesh(7, 1) == (4, 1)
    with pytest.warns(RuntimeWarning, match="pod axis was dropped"):
        assert plan_degraded_mesh(2, 2, pod_axis=True) == (1, 2)
    # clean shapes stay silent
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert plan_degraded_mesh(8, 2) == (4, 2)
        assert plan_degraded_mesh(8, 2, pod_axis=True) == (2, 2, 2)


# ------------------------------------------------------------- e2e (model)
def test_paged_serve_kill_token_for_token():
    """3 of 8 dispatch rows die mid-run: every request survives
    token-for-token through drain-and-readmit (greedy decode + replay
    prefix), and the slot shrink is a recorded membership event."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import PagedServeLoop, Request

    cfg = get_smoke_config("qwen2.5-3b")

    def mk():
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=rng.integers(4, 20)
                                            ).astype(np.int32),
                        max_new=6)
                for i in range(8)]

    kw = dict(num_blocks=48, block_size=8, max_context=64, concurrency=8,
              scheduler="dynamic", prefill_chunk=16)
    ref = PagedServeLoop(cfg, **kw).run(mk())
    loop = PagedServeLoop(cfg, **kw, kill_rows=3, kill_at_dispatch=1)
    out = loop.run(mk())
    assert out == ref
    s = loop.last_stats
    assert s["dead_rows"] == [5, 6, 7] and s["live_rows"] == 5
    assert len(loop.membership_events) == 1
    assert loop.membership_events[0].new_size == 5
    assert s["preemptions"] >= 1


def test_paged_serve_kill_validation():
    from repro.configs import get_smoke_config
    from repro.launch.serve import PagedServeLoop

    cfg = get_smoke_config("qwen2.5-3b")
    with pytest.raises(ValueError, match="live dispatch row"):
        PagedServeLoop(cfg, concurrency=4, kill_rows=4, kill_at_dispatch=1)
    with pytest.raises(ValueError, match="together"):
        PagedServeLoop(cfg, concurrency=4, kill_rows=2)


@needs_hosts
def test_trainloop_elastic_kill_e2e():
    """Injected kill of hosts {2,3} mid-run: no step dropped, the batch
    re-splits over the survivors, the mesh/mitigator follow."""
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainLoop

    cfg = get_smoke_config("qwen2.5-3b")
    loop = TrainLoop(cfg, batch=8, seq_len=64, seed=0, hosts=4,
                     elastic=True, kill_hosts=[2, 3], kill_at_step=2)
    losses = loop.run(5, log_every=10 ** 9)
    assert len(losses) == 5 and np.isfinite(losses).all()
    assert loop.hosts == 2 == loop.mitigator.num_hosts
    assert [e["hosts"] for e in loop.step_log] == [4, 4, 2, 2, 2]
    assert len(loop.membership_events) == 1
    ev = loop.membership_events[0]
    assert ev.lost == (2, 3) and ev.new_size == 2
    assert loop.last_shares is None or sum(loop.last_shares) > 0


@needs_hosts
def test_trainloop_kill_requires_elastic():
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainLoop

    cfg = get_smoke_config("qwen2.5-3b")
    with pytest.raises(ValueError, match="elastic"):
        TrainLoop(cfg, batch=8, seq_len=64, hosts=4,
                  kill_hosts=[3], kill_at_step=1)
