"""schedule(auto): online portfolio selection from LoopHistory telemetry.

Locks the selector's contract — cold-start determinism, candidate-grammar
round-trips, provenance tagging, hysteresis (no thrash between near-equal
schedules), and the headline acceptance criterion: on skewed-worker serve
and 2x-slow-host train scenarios, ``auto`` converges within 10% of the
best hand-picked fixed clause without being told which.
"""

import numpy as np
import pytest

from repro.core import (LoopHistory, LoopSpec, LoopTelemetry, get_engine,
                        parse_schedule)
from repro.core.auto import DEFAULT_CANDIDATES, AutoScheduler
from repro.core.executor import execute_plan
from repro.core.history import ChunkRecord
from repro.core.spec import resolve
from repro.sched.straggler import StragglerMitigator


# ------------------------------------------------------------ construction
def test_auto_default_portfolio():
    a = resolve("auto")
    assert [str(c) for c in a.candidates] == list(DEFAULT_CANDIDATES)


def test_auto_candidate_override_roundtrip():
    spec = parse_schedule("auto(candidates=guided:fac2:awf),4")
    assert parse_schedule(str(spec)) == spec
    a = resolve(spec)
    assert isinstance(a, AutoScheduler)
    assert [str(c) for c in a.candidates] == ["guided", "fac2", "awf"]
    # clause chunk applies only where the candidate takes a chunksize
    assert [str(c) for c in a.full_candidates()] == ["guided,4", "fac2", "awf"]


def test_auto_rejects_bad_portfolios():
    with pytest.raises(ValueError):
        AutoScheduler(candidates="auto:static")       # self-reference
    with pytest.raises(ValueError):
        AutoScheduler(candidates="runtime:static")    # late-binding inside
    with pytest.raises(Exception):
        AutoScheduler(candidates="no_such_schedule")
    with pytest.raises(ValueError):
        AutoScheduler(candidates="static:static")     # duplicate
    with pytest.raises(ValueError):
        AutoScheduler(candidates="")                  # empty portfolio
    with pytest.raises(ValueError):
        AutoScheduler(hysteresis=1.5)


# -------------------------------------------------------------- cold start
def test_auto_cold_start_selects_first_candidate():
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="cold")
    a = AutoScheduler()
    assert str(a.select(LoopHistory(), loop)) == "static"
    b = AutoScheduler(candidates="guided:fac2")
    assert str(b.select(LoopHistory(), loop)) == "guided"
    # history-less selection behaves identically
    assert str(AutoScheduler().select(None, loop)) == "static"


def test_auto_tags_invocations_with_selected_candidate():
    hist = LoopHistory()
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="tagged")
    get_engine().plan(resolve("auto"), loop, history=hist)
    invs = hist.invocations("tagged")
    assert invs and invs[-1].scheduler == "static"    # cold-start default


# -------------------------------------------------------------- convergence
def _drive_serve(clause, epochs, costs, speeds, loop):
    """Plan/execute/measure epochs of one clause; return makespans."""
    hist = LoopHistory()
    tel = LoopTelemetry(hist, loop_id=loop.loop_id,
                        num_workers=loop.num_workers)
    out = []
    for _ in range(epochs):
        sched = resolve(clause)                       # fresh each epoch:
        plan = get_engine().plan(sched, loop, history=hist)
        res = execute_plan(plan, costs, speeds=speeds,
                           history=hist, telemetry=tel)
        out.append(res.makespan)
    return out


def test_auto_converges_on_skewed_workers():
    """One worker at quarter speed: auto must land within 10% of the best
    fixed clause after a measured epoch, selecting it purely from
    telemetry (statelessly — a fresh resolve('auto') per epoch)."""
    p, n = 8, 4096
    speeds = [1.0] * p
    speeds[p - 1] = 0.25
    costs = np.ones(n)
    loop = LoopSpec(lb=0, ub=n, num_workers=p, loop_id="serve_skew")
    fixed = {c: _drive_serve(c, 3, costs, speeds, loop)[-1]
             for c in DEFAULT_CANDIDATES}
    best = min(fixed.values())
    auto = _drive_serve("auto", 6, costs, speeds, loop)
    assert auto[-1] <= best * 1.10, (auto, fixed)
    # and it stays converged (steady state, not a lucky epoch)
    assert max(auto[-3:]) <= best * 1.10


def test_auto_e2e_straggler_train_within_10pct():
    """2x-slow-host StragglerMitigator scenario: steady-state step time of
    scheduler='auto' within 10% of the best fixed clause."""
    total, hosts, slow, factor = 2048, 4, 3, 2.0

    def drive(clause, steps=16):
        m = StragglerMitigator(num_hosts=hosts, scheduler=clause,
                               min_share=0.1)
        ms = []
        for _ in range(steps):
            shares = m.token_shares(total)
            times = {h: float(shares[h]) * (factor if h == slow else 1.0)
                     for h in range(hosts)}
            m.observe_step(times, {h: int(shares[h]) for h in range(hosts)})
            ms.append(max(times.values()))
        return sum(ms[-4:]) / 4

    best = min(drive(c) for c in ("wf2", "static", "fac2", "awf"))
    assert drive("auto") <= best * 1.10


# --------------------------------------------------------------- hysteresis
def _measured_history(loop_id, tagged_makespans, p=4, iters=256):
    """History of measured invocations: (tag, makespan) pairs, the work
    spread evenly so per-worker rates stay uniform."""
    h = LoopHistory()
    for tag, ms in tagged_makespans:
        h.open_invocation(loop_id, scheduler=tag)
        k = iters // p
        for w in range(p):
            h.record(loop_id, ChunkRecord(worker=w, start=w * k,
                                          stop=(w + 1) * k,
                                          elapsed=ms / p * k / (iters // p)))
    return h


def test_auto_hysteresis_keeps_near_equal_incumbent():
    """A challenger inside the hysteresis band must not unseat the
    incumbent — near-equal schedules don't thrash the plan cache."""
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="hyst")
    # equal sample counts so the UCB bonus cancels; dynamic (incumbent,
    # most recent) is 5% worse than static — inside the 10% band
    invs = [("static", 100.0), ("dynamic", 105.0)] * 4
    hist = _measured_history("hyst", invs)
    a = AutoScheduler(candidates="static:dynamic", explore=0.0)
    for _ in range(5):
        assert str(a.select(hist, loop)) == "dynamic"


def test_auto_decisive_winner_unseats_incumbent():
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="unseat")
    invs = [("static", 100.0), ("dynamic", 200.0)] * 4   # 2x worse: switch
    hist = _measured_history("unseat", invs)
    a = AutoScheduler(candidates="static:dynamic", explore=0.0)
    assert str(a.select(hist, loop)) == "static"


def test_auto_selection_is_stateless_across_instances():
    """Two fresh selectors over the same history agree — selection is a
    pure function of the history, so per-invocation resolve('auto') (what
    the serve/train loops do) continues where the last left off."""
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="stateless")
    hist = _measured_history("stateless",
                             [("static", 100.0), ("dynamic", 400.0)] * 3)
    first = AutoScheduler(explore=0.0).select(hist, loop)
    second = AutoScheduler(explore=0.0).select(hist, loop)
    assert first == second


# ------------------------------------------------------------- plan cache
def test_auto_plan_cache_keys_on_selection():
    """Same selector config, different settled selection → different plan
    cache identities; equal selection → equal keys."""
    a, b = AutoScheduler(), AutoScheduler()
    loop = LoopSpec(lb=0, ub=256, num_workers=4, loop_id="key")
    a.select(LoopHistory(), loop)
    assert a.plan_key() != b.plan_key()      # b hasn't selected yet
    b.select(LoopHistory(), loop)
    assert a.plan_key() == b.plan_key()


def test_auto_explicit_selection_survives_historyless_plan():
    """The straggler path: select() against an out-of-band history, then
    plan without one — the plan must use the selected candidate, not the
    cold-start default."""
    p, n = 4, 1024
    hist = _measured_history("oob", [("static", 100.0)], p=p)
    # make worker rates skewed so guided/awf differ from static
    loop = LoopSpec(lb=0, ub=n, num_workers=p, loop_id="oob")
    a = resolve("auto(candidates=guided)")
    a.select(hist, loop, weights=[1.0, 1.0, 1.0, 0.5])
    assert str(a.selected) == "guided"
    plan = get_engine().plan(a, loop, weights=[1.0, 1.0, 1.0, 0.5])
    sizes = sorted(c.size for c in plan.chunks)
    guided = get_engine().plan(resolve("guided"), loop,
                               weights=[1.0, 1.0, 1.0, 0.5])
    assert sizes == sorted(c.size for c in guided.chunks)
