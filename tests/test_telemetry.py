"""The telemetry -> history -> replan loop: LoopTelemetry recording,
stream flush-on-close, execute_plan measured replay, and the end-to-end
adaptive rebalance under skewed worker speeds."""

import numpy as np
import pytest

from repro.core import (Chunk, LoopHistory, LoopSpec, LoopTelemetry,
                        SchedulerContext, execute_plan, get_engine,
                        make_scheduler, simulate_loop)
from repro.core.engine import PlanEngine


# ----------------------------------------------------------- unit: recorder
def test_ledger_accumulates_interleaved_chunk_time():
    tel = LoopTelemetry(LoopHistory(), loop_id="serve", num_workers=2)
    tel.begin(0, Chunk(0, 3, 0))
    tel.begin(1, Chunk(3, 4, 1))
    tel.add_time(0, 0.5, tokens=1)      # prefill
    tel.add_time(1, 0.2, tokens=1)
    tel.add_time(0, 0.25, tokens=1)     # decode steps, interleaved
    tel.add_time(0, 0.25, tokens=1)
    assert tel.end(0) == pytest.approx(1.0)
    assert tel.end(1) == pytest.approx(0.2)
    epoch = tel.flush()
    assert epoch == 1
    inv = tel.history.invocations("serve")[-1]
    assert [(c.worker, c.elapsed) for c in inv.chunks] == [
        (0, pytest.approx(1.0)), (1, pytest.approx(0.2))]
    assert tel.summary()["total_tokens"] == 4


def test_add_time_weighted_splits_step_time_proportionally():
    """The multi-host train attribution: ONE wall time split over the open
    per-host ledgers proportionally to the given weights."""
    tel = LoopTelemetry(LoopHistory(), loop_id="train_step", num_workers=3)
    for h, size in enumerate((4, 2, 2)):
        tel.begin(h, Chunk(h * 4, h * 4 + size, h))
    tel.add_time_weighted(1.0, {0: 2.0, 1: 1.0, 2: 1.0},
                          tokens={0: 4, 1: 2, 2: 2})
    assert tel.end(0) == pytest.approx(0.5)
    assert tel.end(1) == pytest.approx(0.25)
    assert tel.end(2) == pytest.approx(0.25)
    tel.flush()
    assert tel.summary()["total_tokens"] == 8
    # hosts without an open ledger are skipped; negative weights clamp
    tel.begin(0, Chunk(0, 1, 0))
    tel.add_time_weighted(0.3, {0: 1.0, 7: 5.0, 1: -2.0})
    assert tel.end(0) == pytest.approx(0.3)
    # all-zero weights fall back to an equal split (never drop a sample)
    tel.begin(0, Chunk(0, 1, 0))
    tel.begin(1, Chunk(1, 2, 1))
    tel.add_time_weighted(0.4, {0: 0.0, 1: 0.0})
    assert tel.end(0) == pytest.approx(0.2)
    assert tel.end(1) == pytest.approx(0.2)
    # no open ledgers at all: a silent no-op
    tel.add_time_weighted(1.0, {0: 1.0})


def test_add_time_split_per_worker_token_credit():
    """The fused-decode attribution: one dispatch's wall time splits
    equally across the slots it advanced, but each slot is credited its
    OWN produced-token count (slots freezing mid-dispatch produce fewer
    tokens than the quantum)."""
    tel = LoopTelemetry(LoopHistory(), loop_id="serve", num_workers=3)
    for s in range(3):
        tel.begin(s, Chunk(s, s + 1, s))
    tel.add_time_split([0, 1, 2], 0.9, tokens={0: 8, 1: 3, 2: 8})
    assert tel.end(0) == pytest.approx(0.3)
    assert tel.end(1) == pytest.approx(0.3)
    assert tel.end(2) == pytest.approx(0.3)
    tel.flush()
    s = tel.summary()
    assert s["total_tokens"] == 19
    assert s["per_worker"][1]["tokens"] == 3
    # the scalar form still broadcasts one count to every worker
    tel.begin(0, Chunk(0, 1, 0))
    tel.begin(1, Chunk(1, 2, 1))
    tel.add_time_split([0, 1], 0.2, tokens=1)
    tel.end(0), tel.end(1)
    tel.flush()
    assert tel.summary()["per_worker"][0]["tokens"] == 8 + 1


def test_flush_closes_open_ledgers_and_bumps_epoch_once():
    hist = LoopHistory()
    tel = LoopTelemetry(hist, loop_id="x", num_workers=1)
    tel.begin(0, Chunk(0, 2, 0))
    tel.add_time(0, 0.1)
    assert hist.measured_invocations("x") == 0
    assert tel.flush() == 1             # open ledger ended + recorded
    assert tel.pending == 0
    assert tel.flush() == 1             # empty flush does not bump again


def test_record_chunk_direct_api_feeds_worker_rates():
    hist = LoopHistory()
    tel = LoopTelemetry(hist, loop_id="train_step", num_workers=2)
    tel.record_chunk(0, 0, 100, 1.0, tokens=100)
    tel.record_chunk(1, 0, 100, 4.0, tokens=100)
    tel.flush()
    rates = hist.worker_rates("train_step")
    assert rates[1] == pytest.approx(4 * rates[0])
    # direct records carry the wall-clock bounds too, so the train-loop
    # path reports a throughput instead of tok_s=None
    assert tel.summary()["tok_s"] is not None


def test_flush_with_history_but_no_loop_id_raises():
    tel = LoopTelemetry(LoopHistory())        # never bound to a loop
    tel.record_chunk(0, 0, 10, 0.1)
    with pytest.raises(ValueError, match="loop_id"):
        tel.flush()


# ------------------------------------------- stream: flush on close, no dupes
def test_stream_with_telemetry_flushes_on_close_only():
    hist = LoopHistory()
    tel = LoopTelemetry(num_workers=2)   # history inherited from ctx
    loop = LoopSpec(0, 40, num_workers=2, loop_id="s")
    stream = get_engine().open_stream(
        make_scheduler("dynamic", chunk=10),
        SchedulerContext(loop=loop, history=hist), telemetry=tel)
    active = {0, 1}
    while active:                     # each worker drains to its terminal
        for w in list(active):        # None-dequeue, reporting elapsed
            if stream.next(w, 0.01) is None:
                active.discard(w)
    assert hist.measured_invocations("s") == 0   # buffered, not yet flushed
    stream.close()
    assert hist.measured_invocations("s") == 1
    inv = hist.invocations("s")[-1]
    # every dequeued chunk recorded exactly once (4 chunks of 10)
    assert sorted((c.start, c.stop) for c in inv.chunks
                  if c.elapsed is not None) == [
        (0, 10), (10, 20), (20, 30), (30, 40)]


def test_ledger_fed_elapsed_not_double_counted():
    """A chunk measured via the ledger AND fed back through stream.next must
    appear once in the history."""
    hist = LoopHistory()
    tel = LoopTelemetry(num_workers=1)
    loop = LoopSpec(0, 6, num_workers=1, loop_id="d")
    stream = get_engine().open_stream(
        make_scheduler("dynamic", chunk=3),
        SchedulerContext(loop=loop, history=hist), telemetry=tel)
    elapsed = None
    while True:
        chunk = stream.next(0, elapsed)
        if chunk is None:
            break
        tel.begin(0, chunk)
        tel.add_time(0, 0.5)
        elapsed = tel.end(0)
    stream.close()
    chunks = hist.invocations("d")[-1].chunks
    assert sorted((c.start, c.stop) for c in chunks) == [(0, 3), (3, 6)]
    assert all(c.elapsed == pytest.approx(0.5) for c in chunks)


# ----------------------------------------------- execute_plan measured replay
def test_execute_plan_records_and_invalidates_adaptive_cache():
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 800, num_workers=2, loop_id="replay")
    sched = make_scheduler("awf")
    p1 = eng.plan(sched, loop, history=hist)
    res = execute_plan(p1, np.ones(800), speeds=[2.0, 1.0], history=hist)
    assert hist.measured_invocations("replay") == 1
    assert res.wave_times is not None and len(res.wave_times) == p1.num_waves
    assert sum(res.wave_times) >= res.makespan - 1e-9
    p2 = eng.plan(sched, loop, history=hist)
    assert p2 is not p1                                 # epoch bump -> replan
    assert int(p2.worker_iters()[0]) > int(p1.worker_iters()[0])


def test_execute_plan_telemetry_object_aggregates():
    plan = PlanEngine().plan(make_scheduler("static_block"),
                             LoopSpec(0, 100, num_workers=4, loop_id="agg"))
    tel = LoopTelemetry(LoopHistory())
    execute_plan(plan, np.ones(100), telemetry=tel)
    assert tel.loop_id == "agg"                  # bound from the plan's loop
    assert sum(tel.worker_iters().values()) == 100
    assert tel.epoch() == 1


def test_execute_plan_binds_history_onto_bare_telemetry():
    """history= and an unbound telemetry= together: the telemetry inherits
    the history (mirrors open_stream) so the epoch still advances."""
    hist = LoopHistory()
    plan = PlanEngine().plan(make_scheduler("static_block"),
                             LoopSpec(0, 60, num_workers=2, loop_id="bind"))
    tel = LoopTelemetry()
    execute_plan(plan, np.ones(60), history=hist, telemetry=tel)
    assert tel.history is hist
    assert hist.measured_invocations("bind") == 1


# ------------------------------------------------- end-to-end: the issue gate
def test_adaptive_replan_shifts_work_off_slow_worker():
    """Acceptance: an executor steady-state loop under AWF with skewed
    synthetic worker speeds replans (>= 1 history-epoch cache invalidation
    from measured data) and the rebalanced plan gives the slow worker
    less."""
    eng = PlanEngine()
    hist = LoopHistory()
    n, p = 2048, 4
    loop = LoopSpec(0, n, num_workers=p, loop_id="e2e/awf")
    sched = make_scheduler("awf")
    speeds = [1.0, 1.0, 1.0, 0.25]

    shares, makespans = [], []
    for _ in range(4):
        tel = LoopTelemetry(hist, loop_id=loop.loop_id, num_workers=p)
        plan = eng.plan(sched, loop, history=hist)
        res = execute_plan(plan, np.ones(n), speeds=speeds, telemetry=tel)
        shares.append(int(plan.worker_iters()[3]))
        makespans.append(res.makespan)

    assert hist.measured_invocations(loop.loop_id) >= 1
    assert eng.cache_info().misses >= 2   # >=1 invalidation beyond first plan
    assert shares[-1] < shares[0]         # slow worker's share shrank
    assert makespans[-1] < makespans[0]   # and the step got faster
    # learned share should approach the speed ratio (0.25 / 3.25 of work)
    assert shares[-1] < n // p * 0.7


def test_awf_b_rebalances_within_invocation_and_bumps_cache_epoch():
    """AWF-B (batch-boundary adaptation): the streamed schedule itself
    shifts work off the slow worker, and the measured invocation
    invalidates the cached plan for the next step."""
    eng = PlanEngine()
    hist = LoopHistory()
    n, p = 2048, 4
    loop = LoopSpec(0, n, num_workers=p, loop_id="e2e/awf_b")
    speeds = [1.0, 1.0, 1.0, 0.25]

    p1 = eng.plan(make_scheduler("awf_b"), loop, history=hist)
    res = simulate_loop(make_scheduler("awf_b"), loop, np.ones(n),
                        speeds=speeds, history=hist)
    iters = np.zeros(p, np.int64)
    for c in res.chunks:
        iters[c.worker] += c.size
    assert iters[3] < n // p              # rebalanced away from the slow one
    assert hist.measured_invocations(loop.loop_id) == 1
    p2 = eng.plan(make_scheduler("awf_b"), loop, history=hist)
    assert p2 is not p1                   # epoch advanced -> cache invalidated
    assert eng.cache_info().misses == 2


def test_streaming_and_replay_epochs_compose():
    """Mixed feedback: a measured streaming run (simulate_loop) followed by
    measured replays keeps advancing one epoch per invocation."""
    hist = LoopHistory()
    eng = PlanEngine()
    loop = LoopSpec(0, 600, num_workers=3, loop_id="mix")
    simulate_loop(make_scheduler("awf"), loop, np.ones(600),
                  speeds=[1.0, 1.0, 0.5], history=hist)
    assert hist.measured_invocations("mix") == 1
    plan = eng.plan(make_scheduler("awf"), loop, history=hist)
    execute_plan(plan, np.ones(600), speeds=[1.0, 1.0, 0.5], history=hist)
    assert hist.measured_invocations("mix") == 2


# ----------------------------------------------------------- serve loop unit
def test_serve_loop_reports_per_chunk_wall_time():
    """The fixed feedback bug: a slot's second dequeue must report the wall
    time of its whole previous chunk (prefill + decode tokens), not a stale
    prefill-only value.  Exercised via the ledger discipline serve uses."""
    tel = LoopTelemetry(LoopHistory(), loop_id="serve", num_workers=1)
    tel.begin(0, Chunk(0, 1, 0))
    tel.add_time(0, 0.3, tokens=1)                     # prefill
    for _ in range(3):
        tel.add_time(0, 0.1, tokens=1)                 # decode steps
    first = tel.end(0)
    assert first == pytest.approx(0.6)                 # not 0.3 (prefill-only)
    tel.begin(0, Chunk(1, 2, 0))
    tel.add_time(0, 0.05, tokens=1)
    second = tel.end(0)
    assert second == pytest.approx(0.05)               # not stale 0.6
    tel.flush()
    rates = tel.history.worker_rates("serve")
    assert rates[0] == pytest.approx((0.6 + 0.05) / 2)


def test_straggler_mitigator_epoch_advances_per_step():
    from repro.sched import StragglerMitigator
    m = StragglerMitigator(num_hosts=4)
    for step in range(5):
        m.observe_step({h: 1.0 + (0.5 if h == 2 else 0.0) for h in range(4)})
    assert m.epoch() == 5
    assert 2 in m.stragglers()
    w = m.weights()
    assert w[2] < min(w[0], w[1], w[3])
