"""Sharding rules, spec construction, and the loop-aware HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import spec_for
from repro.launch.hlo_analysis import analyze_hlo, normalize_cost_analysis
from repro.launch.roofline import parse_collectives


# ---------------------------------------------------------------- spec rules
def test_spec_basic_mapping():
    rules = {"embed": "data", "heads": "model", "layers": None}
    assert spec_for(("layers", "embed", "heads"), rules) == \
        P(None, "data", "model")


def test_spec_duplicate_axis_dropped():
    rules = {"batch": ("data",), "embed": "data"}
    # data already used by batch -> embed falls back to replication
    assert spec_for(("batch", "embed"), rules) == P("data", None)


def test_spec_divisibility_fallback():
    rules = {"vocab": "model"}
    sizes = {"model": 16}
    # 122753 not divisible by 16 -> replicate (minicpm case)
    assert spec_for(("vocab",), rules, shape=(122753,),
                    axis_sizes=sizes) == P(None)
    assert spec_for(("vocab",), rules, shape=(131072,),
                    axis_sizes=sizes) == P("model")


def test_spec_multi_axis_tuple():
    rules = {"batch": ("pod", "data")}
    assert spec_for(("batch", None), rules) == P(("pod", "data"), None)


# ------------------------------------------------------------- HLO analyzer
def test_analyzer_exact_on_loop_free_matmul():
    f = jax.jit(lambda a, b: a @ b)
    comp = f.lower(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                   jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
    st = analyze_hlo(comp.as_text())
    assert st.flops == 2 * 64 * 32 * 128
    assert float(normalize_cost_analysis(
        comp.cost_analysis())["flops"]) == st.flops


def test_analyzer_scales_with_scan_length():
    def make(L):
        def body(x, w):
            return jnp.einsum("bd,de->be", x, w), None

        def f(x, ws):
            y, _ = jax.lax.scan(body, x, ws)
            return y

        return jax.jit(f).lower(
            jax.ShapeDtypeStruct((4, 32), jnp.float32),
            jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)).compile()

    f4 = analyze_hlo(make(4).as_text())
    f8 = analyze_hlo(make(8).as_text())
    assert f4.flops > 0
    assert f8.flops == pytest.approx(2 * f4.flops, rel=0.01)
    assert 4 in f4.while_trip_counts.values()
    assert 8 in f8.while_trip_counts.values()
    # XLA's own count misses the loop multiplier
    assert float(normalize_cost_analysis(
        make(8).cost_analysis())["flops"]) < f8.flops


def test_collective_parse_traffic_factors():
    hlo = """
HloModule m

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %a = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%a), replica_groups=[2,8]<=[16], to_apply=%add
  %ag = f32[32,64]{1,0} all-gather(%ar), replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}, replica_groups={}
}
"""
    st = parse_collectives(hlo)
    ar = 16 * 64 * 4 * 2 * 7 / 8          # 2(n-1)/n, n=8
    ag = 32 * 64 * 4 * 3 / 4              # (n-1)/n, n=4
    cp = 16 * 64 * 4                      # factor 1 (default group n=2)
    assert st.by_op["all-reduce"] == pytest.approx(ar)
    assert st.by_op["all-gather"] == pytest.approx(ag)
    assert st.by_op["collective-permute"] == pytest.approx(cp)
    assert st.count == 3


def test_small_mesh_train_lowering_has_expected_collectives():
    """End-to-end: a (1,2)-mesh TP train step contains all-reduces, and the
    analyzer multiplies them by the layer trip count."""
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.launch.mesh import make_mesh, rules_for, shardings_for
    from repro.launch.steps import (input_specs, input_shardings,
                                    make_train_step, opt_state_specs)
    from repro.configs.base import ShapeSpec
    from repro.optim import cosine_schedule, make_optimizer
    from repro.sharding import axis_rules

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (run under XLA_FLAGS host platform)")
    cfg = get_smoke_config("phi3-mini-3.8b")
    model = get_model(cfg)
    mesh = make_mesh((1, 2), ("data", "model"))
    shape = ShapeSpec("t", 32, 2, "train")
    rules = rules_for(cfg, mesh, "train", 2)
    params_abs, specs = model.init(jax.random.PRNGKey(0), jnp.bfloat16,
                                   abstract=True)
    pshard = shardings_for(specs, rules, mesh, tree=params_abs)
    opt_init, opt_update = make_optimizer("adamw", cosine_schedule(1e-3, 2, 9))
    opt_abs = jax.eval_shape(opt_init, params_abs)
    oshard = shardings_for(opt_state_specs("adamw", params_abs, specs),
                           rules, mesh, tree=opt_abs)
    fn = make_train_step(model, opt_update)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    with mesh, axis_rules(mesh, rules):
        comp = jax.jit(
            fn, in_shardings=(pshard, oshard, repl,
                              input_shardings(cfg, shape, rules, mesh)),
            out_shardings=(pshard, oshard, repl),
            donate_argnums=(0, 1),
        ).lower(params_abs, opt_abs, jax.ShapeDtypeStruct((), jnp.int32),
                input_specs(cfg, shape)).compile()
    st = analyze_hlo(comp.as_text())
    assert st.collective_count > 0
    assert cfg.num_layers in st.while_trip_counts.values()
    assert st.flops > 0


def test_padded_attention_matches_unpadded_under_mesh():
    """Head padding (indivisible head counts) must not change results."""
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.launch.mesh import make_mesh, rules_for
    from repro.sharding import axis_rules

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    # 6 heads on a 4-way model axis -> padded to 8 inside the mesh ctx
    cfg = get_smoke_config("minicpm-2b")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    ref, _ = model.forward(params, {"tokens": toks})          # no mesh ctx
    mesh = make_mesh((2, 4), ("data", "model")) if len(jax.devices()) >= 8 \
        else make_mesh((1, 2), ("data", "model"))
    rules = rules_for(cfg, mesh, "train", 4)
    with mesh, axis_rules(mesh, rules):
        out, _ = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))(
            params, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
