"""Serving-engine tests: batched/fused/per-slot equivalence and telemetry.

The batched decode path (one jitted call per token across all slots over a
stacked ``[slots, max_len]`` KV cache) must be *behaviourally invisible*:

* token-for-token identical generations to the per-slot escape hatch
  (one jit call per active slot over batch-1 caches), and
* identical chunk→slot assignments from the UDS admission scheduler,

for every builtin schedule family — static chunking, guided self-scheduling
and adaptive weighted factoring.  The telemetry loop must keep feeding
per-slot busy times into the LoopHistory so AWF admission still replans
per slot (the PR-2 measure stage survives batching).

The FUSED dispatch quantum (``decode_steps=T``: one jitted call runs T
tokens via an on-device ``lax.scan`` with per-slot stop handling) must be
equally invisible: greedy decode is deterministic, so every T serves the
same tokens — locked down for T ∈ {1, 4, 16} under every schedule family,
plus the mid-dispatch freeze cases (budget exhaustion, EOS, cache
capacity).  Prefill bucketing (prompts right-padded to power-of-two
buckets) must not change tokens and must bound compile count by buckets,
not distinct prompt lengths.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LoopHistory, LoopSpec, get_engine
from repro.core.spec import resolve
from repro.launch.serve import Request, ServeLoop

SLOTS = 3
MAX_LEN = 64
MAX_NEW = 3
N_REQUESTS = 6


def make_requests(seed: int, n: int = N_REQUESTS, max_new: int = MAX_NEW):
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("qwen2.5-3b")
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 12))
                                        ).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen2.5-3b")


# one loop per mode, shared across schedule families (compile once);
# scheduler and history are swapped per run
@pytest.fixture(scope="module")
def batched_loop(cfg):
    return ServeLoop(cfg, slots=SLOTS, max_len=MAX_LEN, batched=True)


@pytest.fixture(scope="module")
def per_slot_loop(cfg):
    return ServeLoop(cfg, slots=SLOTS, max_len=MAX_LEN, batched=False)


def run_with(loop: ServeLoop, scheduler, seed: int):
    """Run one isolated invocation: fresh history (no adaptive carry-over
    between parametrized cases), returning (results, chunk assignments)."""
    loop.scheduler = scheduler
    loop.history = LoopHistory()
    out = loop.run(make_requests(seed))
    chunks = sorted((c.worker, c.start, c.stop)
                    for c in loop.history.invocations(loop.loop_id)[-1].chunks)
    return out, chunks


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("clause", ["static", "guided,2", "awf"])
def test_batched_token_and_assignment_equivalence(clause, batched_loop,
                                                  per_slot_loop):
    """The tentpole guarantee: under every builtin schedule family the
    batched engine serves the same tokens to the same requests, admitted
    through the same chunk→slot assignments, as the per-slot path."""
    out_b, chunks_b = run_with(batched_loop, clause, seed=42)
    out_p, chunks_p = run_with(per_slot_loop, clause, seed=42)
    assert batched_loop.mode == "batched"
    assert per_slot_loop.mode == "per_slot"
    assert sorted(out_b) == list(range(N_REQUESTS))
    assert out_b == out_p                      # token-for-token identical
    assert chunks_b == chunks_p                # same UDS admission decisions


def test_batched_is_the_default(cfg, batched_loop):
    assert ServeLoop.__init__.__kwdefaults__["batched"] is True
    assert batched_loop.batched
    # stacked cache: one buffer for all slots, per-slot lengths
    assert batched_loop.cache["len"].shape == (SLOTS,)
    assert batched_loop.cache["k"].shape[1] == SLOTS
    assert batched_loop.caches is None


def test_ssm_family_falls_back_to_per_slot():
    """rwkv6 has no stacked-cache decode yet: requesting batched serving
    must degrade to the per-slot path instead of refusing to serve."""
    from repro.models import get_model
    cfg = get_smoke_config("rwkv6-3b")
    assert get_model(cfg).batched_decode is None


def test_over_capacity_request_is_truncated_and_reported(batched_loop):
    """prompt + max_new beyond max_len is admitted with the generation
    budget clamped to cache capacity, and the truncation is REPORTED per
    request — never silently padded (dropped KV appends would corrupt the
    generation) and never refused (the request is serveable)."""
    prompt = np.arange(MAX_LEN - 2, dtype=np.int32) % 16      # capacity 3
    batched_loop.scheduler = "dynamic"
    batched_loop.history = LoopHistory()
    reqs = [Request(rid=0, prompt=prompt, max_new=8)]
    out = batched_loop.run(reqs)
    assert len(out[0]) == MAX_LEN - len(prompt) + 1            # clamped
    assert reqs[0].truncated
    assert batched_loop.last_stats["truncated"] == [0]
    assert -1 not in out[0]                    # no frozen-step padding


def test_prompt_alone_over_max_len_is_refused(batched_loop):
    """A prompt that cannot even fit the cache is not serveable at any
    budget: refuse loudly instead of truncating the PROMPT."""
    prompt = np.arange(MAX_LEN + 1, dtype=np.int32) % 16
    batched_loop.scheduler = "dynamic"
    batched_loop.history = LoopHistory()
    with pytest.raises(ValueError, match="max_len"):
        batched_loop.run([Request(rid=0, prompt=prompt, max_new=2)])


@pytest.mark.parametrize("decode_steps", [1, 8])
def test_max_len_mid_dispatch_truncation(cfg, decode_steps):
    """Regression: a slot whose cache fills MID-fused-dispatch (prompt
    near max_len, quantum spanning the cap) must freeze at capacity and
    report the truncation — same tokens at every dispatch quantum."""
    prompt = (np.arange(MAX_LEN - 3, dtype=np.int32) % 16)     # capacity 4
    loop = ServeLoop(cfg, slots=2, max_len=MAX_LEN,
                     decode_steps=decode_steps)
    reqs = [Request(rid=0, prompt=prompt, max_new=10)]
    out = loop.run(reqs)
    assert len(out[0]) == 4                    # capacity, not max_new
    assert reqs[0].truncated
    assert loop.last_stats["truncated"] == [0]
    assert int(np.asarray(loop.cache["len"])[0]) <= MAX_LEN


# ------------------------------------------------------------ fused decode
@pytest.fixture(scope="module")
def fused_loops(cfg):
    """One loop per dispatch quantum, shared across schedule families
    (compile once); scheduler and history are swapped per run."""
    return {t: ServeLoop(cfg, slots=SLOTS, max_len=MAX_LEN, decode_steps=t)
            for t in (4, 16)}


@pytest.mark.parametrize("decode_steps", [4, 16])
@pytest.mark.parametrize("clause", ["static", "guided,2", "awf"])
def test_fused_token_and_epoch_equivalence(clause, decode_steps,
                                           batched_loop, fused_loops):
    """The fused guarantee: the dispatch quantum is invisible — T tokens
    per jitted call serve exactly the tokens the stepwise engine (T=1)
    serves, under every builtin schedule family, and the measure stage
    still flushes one epoch per run with full token credit.  (Chunk→slot
    assignments may legitimately differ: admission happens at dispatch
    boundaries, so only tokens + telemetry epochs are contractual.)"""
    out_1, _ = run_with(batched_loop, clause, seed=42)
    fused = fused_loops[decode_steps]
    out_t, _ = run_with(fused, clause, seed=42)
    assert fused.decode_steps == decode_steps
    assert out_t == out_1                      # token-for-token identical
    assert fused.measured_epoch() == batched_loop.measured_epoch() == 1
    assert (fused.last_stats["decoded_tokens"]
            == batched_loop.last_stats["decoded_tokens"])
    # the point of fusing: strictly fewer host->device dispatches
    assert (fused.last_stats["decode_dispatches"]
            < batched_loop.last_stats["decode_dispatches"])


def test_stepwise_is_the_default_quantum(batched_loop):
    """decode_steps=1 (exactly today's engine) stays the default; the
    fused quantum is opt-in."""
    assert ServeLoop.__init__.__kwdefaults__["decode_steps"] == 1
    assert batched_loop.decode_steps == 1


def test_fused_eos_freezes_slot_mid_dispatch(cfg):
    """A slot that emits EOS inside a fused dispatch freezes in place (no
    tokens past EOS) while the stepwise run stops at the same point."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    base = ServeLoop(cfg, slots=1, max_len=MAX_LEN, decode_steps=1)
    ref = base.run([Request(rid=0, prompt=prompt.copy(), max_new=8)])[0]
    eos = ref[3]                    # force a stop 4 tokens in
    for steps in (1, 8):
        loop = ServeLoop(cfg, slots=1, max_len=MAX_LEN, decode_steps=steps,
                         eos_id=eos)
        out = loop.run([Request(rid=0, prompt=prompt.copy(), max_new=8)])
        assert out[0] == ref[:4], f"decode_steps={steps}"
        assert out[0][-1] == eos


# ------------------------------------------------------- prefill bucketing
def test_bucket_length():
    from repro.launch.serve import MIN_PREFILL_BUCKET, bucket_length
    assert bucket_length(1, 64) == MIN_PREFILL_BUCKET
    assert bucket_length(8, 64) == 8
    assert bucket_length(9, 64) == 16
    assert bucket_length(33, 64) == 64
    assert bucket_length(60, 64) == 64        # capped at max_len


def test_prefill_compiles_once_per_bucket(cfg):
    """Mixed prompt lengths must not recompile prefill per length: one
    compiled program per power-of-two bucket (the admission-latency fix).
    Lengths 4..12 span buckets {8, 16} -> exactly 2 compilations."""
    loop = ServeLoop(cfg, slots=SLOTS, max_len=MAX_LEN)
    reqs = make_requests(11, n=8)              # lengths in [4, 12)
    lengths = {int(r.prompt.size) for r in reqs}
    assert len(lengths) > 2                    # the test needs mixed lengths
    out = loop.run(reqs)
    assert sorted(out) == list(range(8))
    from repro.launch.serve import bucket_length
    buckets = {bucket_length(n, MAX_LEN) for n in lengths}
    assert loop.prefill_compiles == len(buckets) < len(lengths)


def test_partial_team_drain(batched_loop):
    """More slots than requests at the tail: the active-slot mask must let
    a partially-filled team drain without corrupting idle slots."""
    out, _ = run_with(batched_loop, "dynamic", seed=7)
    assert sorted(out) == list(range(N_REQUESTS))
    assert all(len(v) == MAX_NEW for v in out.values())


# --------------------------------------------------------------- telemetry
def test_batched_busy_times_bump_epoch_and_replan(cfg):
    """The measure stage survives batching: each run flushes per-slot busy
    times into the history (epoch bump), and the bumped epoch invalidates
    the engine's cached adaptive plan, so AWF admission replans from the
    measured data."""
    loop = ServeLoop(cfg, slots=2, max_len=MAX_LEN, scheduler="awf",
                     batched=True)
    assert loop.measured_epoch() == 0
    out1 = loop.run(make_requests(0))
    assert sorted(out1) == list(range(N_REQUESTS))
    assert loop.measured_epoch() == 1

    # per-slot attribution is intact: every slot that served a chunk has
    # positive measured busy time and generated-token credit
    per_worker = loop.last_stats["per_worker"]
    assert loop.last_stats["mode"] == "batched"
    served = [w for w, st in per_worker.items() if st["chunks"] > 0]
    assert served
    assert all(per_worker[w]["time_s"] > 0 for w in served)
    assert all(per_worker[w]["tokens"] > 0 for w in served)
    rates = loop.history.worker_rates(loop.loop_id)
    assert rates and all(r > 0 for r in rates.values())

    # epoch is the adaptive plan-cache key: the same (scheduler, loop)
    # query before and after the next flush must be a fresh plan object
    spec = LoopSpec(0, N_REQUESTS, num_workers=2, loop_id=loop.loop_id)
    plan1 = get_engine().plan(resolve("awf"), spec, history=loop.history)
    out2 = loop.run(make_requests(1))
    assert sorted(out2) == list(range(N_REQUESTS))
    assert loop.measured_epoch() == 2
    plan2 = get_engine().plan(resolve("awf"), spec, history=loop.history)
    assert plan1 is not plan2          # cache invalidated -> replanned
