"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (LoopSpec, make_scheduler, plan_schedule,
                        simulate_loop)
from repro.core.interface import chunks_cover
from repro.core.spec import ScheduleSpec, parse, resolve

SCHEDULERS = ["static", "dynamic", "guided", "tss", "tfss", "taper",
              "fac2", "wf2", "awf_b", "af", "rand", "fsc", "static_steal"]


# ---------------------------------------------------------------------------
# ScheduleSpec clause strategies (the PR-3 one-clause selection surface)
# ---------------------------------------------------------------------------
_ident = st.from_regex(r"[A-Za-z_][A-Za-z0-9_]{0,8}", fullmatch=True)
# string parameters must not re-parse as a bool/none scalar
_token = _ident.filter(lambda s: s.lower() not in ("true", "false", "none"))
_scalar = st.one_of(
    st.booleans(),
    st.integers(-10**6, 10**6),
    st.floats(allow_nan=False, allow_infinity=False),
    _token,
)


@st.composite
def schedule_specs(draw):
    """Random well-formed ScheduleSpec values — every field the OpenMP-style
    clause can carry (kind/uds namespace, chunk, positional params, named
    params, WF2-family weights)."""
    kind = draw(_ident.filter(lambda s: s != "runtime"))
    if draw(st.booleans()):
        kind = "uds:" + kind
    chunk = draw(st.none() | st.integers(1, 10**6))
    params = tuple(draw(st.lists(_scalar, max_size=3)))
    kwargs = draw(st.dictionaries(
        _ident.filter(lambda s: s != "weights"), _scalar, max_size=3))
    weights = draw(st.none() | st.lists(
        st.floats(min_value=1e-6, max_value=1e6), min_size=1, max_size=5))
    return ScheduleSpec(kind=kind, chunk=chunk, params=params,
                        kwargs=tuple(sorted(kwargs.items())),
                        weights=tuple(weights) if weights else None)


@st.composite
def resolvable_clauses(draw):
    """Random clause STRINGS that must resolve to a builtin scheduler:
    (clause, num_workers, declared-min-chunk-or-None)."""
    p = draw(st.integers(1, 16))
    family = draw(st.sampled_from(
        ["static", "dynamic", "guided", "taper", "tss", "fac2", "wf2",
         "rand"]))
    chunk = draw(st.none() | st.integers(1, 64))
    if family == "taper":
        mu = draw(st.floats(0.5, 4.0))
        sigma = draw(st.floats(0.0, 1.0))
        clause = f"taper(mu={mu!r},sigma={sigma!r})"
    elif family == "tss":
        first = draw(st.integers(1, 64))
        last = draw(st.integers(1, first))
        clause, chunk = f"tss({first},{last})", None
    elif family == "wf2":
        ws = ":".join(repr(draw(st.floats(0.5, 4.0))) for _ in range(p))
        clause, chunk = f"wf2(weights={ws})", None
    elif family == "rand":
        clause = f"rand(seed={draw(st.integers(0, 99))})"
    elif family == "fac2":
        clause, chunk = "fac2", None
    else:
        clause = family
    min_chunk = chunk if family in ("static", "dynamic", "guided",
                                    "taper") else None
    if chunk is not None:
        clause += f",{chunk}"
    return clause, p, min_chunk


@given(spec=schedule_specs())
@settings(max_examples=300, deadline=None)
def test_spec_clause_roundtrip(spec):
    """parse(str(spec)) == spec for EVERY representable clause: the canonical
    rendering is lossless through the PR-3 parser (specs are plan-cache
    identities, so a lossy render would silently split cached plans)."""
    assert parse(str(spec)) == spec
    # rendering is also a fixed point: one canonical string per spec
    assert str(parse(str(spec))) == str(spec)


@given(names=st.lists(st.sampled_from(SCHEDULERS), min_size=1, max_size=5,
                      unique=True),
       chunk=st.none() | st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_auto_candidate_clause_roundtrip(names, chunk):
    """auto(candidates=a:b:c)[,chunk] round-trips through the parser and
    resolves to a selector carrying exactly that portfolio in order."""
    clause = f"auto(candidates={':'.join(names)})"
    if chunk is not None:
        clause += f",{chunk}"
    spec = parse(clause)
    assert parse(str(spec)) == spec
    auto = resolve(spec)
    assert [str(c) for c in auto.candidates] == names
    assert auto.chunk == chunk


@given(clause_p=resolvable_clauses(),
       lb=st.integers(-50, 50),
       n=st.integers(0, 2000))
@settings(max_examples=150, deadline=None)
def test_clause_resolved_plans_cover_loop(clause_p, lb, n):
    """Any builtin clause string, any loop: the compiled plan's chunks
    exactly partition [lb, ub), every chunk lands on a real worker, and the
    clause's chunksize is respected as a minimum by every non-final chunk."""
    from repro.core.engine import PlanEngine
    clause, p, min_chunk = clause_p
    loop = LoopSpec(lb=lb, ub=lb + n, num_workers=p, loop_id="prop_clause")
    plan = PlanEngine().plan(resolve(clause), loop)
    assert chunks_cover(loop, plan.chunks)
    assert all(c.size >= 1 for c in plan.chunks)
    assert all(0 <= c.worker < p for c in plan.chunks)
    if min_chunk is not None:
        ordered = sorted(plan.chunks, key=lambda c: c.start)
        assert all(c.size >= min_chunk for c in ordered[:-1])


@given(name=st.sampled_from(SCHEDULERS),
       n=st.integers(0, 2000),
       p=st.integers(1, 48))
@settings(max_examples=120, deadline=None)
def test_vectorized_plan_identical_to_generic_driver(name, n, p):
    """The engine's compilation invariant, fuzzed: for every scheduler with
    a closed-form compiler and every (N, P), the vectorized chunk table is
    chunk-for-chunk identical to the generic three-op state machine."""
    from repro.core.engine import PlanEngine, has_compiler
    eng = PlanEngine()
    sched = make_scheduler(name)
    if not has_compiler(sched):
        return
    loop = LoopSpec(lb=0, ub=n, num_workers=p, loop_id="prop")
    vec = eng.plan(make_scheduler(name), loop, mode="vectorized")
    gen = eng.plan(make_scheduler(name), loop, mode="generic")
    assert vec.identical(gen)
    assert np.array_equal(vec.wave_ids, gen.wave_ids)


@given(name=st.sampled_from(SCHEDULERS),
       n=st.integers(0, 2000),
       p=st.integers(1, 48))
@settings(max_examples=120, deadline=None)
def test_todo_list_invariant(name, n, p):
    """Every scheduler, for every (N, P): chunks exactly tile [0, N) with no
    overlap and no loss — the paper's necessary condition on any UDS."""
    plan = plan_schedule(make_scheduler(name), n, p)
    assert chunks_cover(LoopSpec(lb=0, ub=n, num_workers=p), plan.chunks)
    assert all(c.size >= 1 for c in plan.chunks)
    assert all(0 <= c.worker < p for c in plan.chunks)


@given(name=st.sampled_from(SCHEDULERS),
       n=st.integers(1, 500),
       p=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_work_conservation(name, n, p, seed):
    """Virtual-time execution conserves work: total busy time equals the sum
    of iteration costs (no iteration run twice or dropped), and the makespan
    is bounded by [total/P, total + overheads]."""
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 2.0, n)
    res = simulate_loop(make_scheduler(name),
                        LoopSpec(0, n, num_workers=p, loop_id=name), costs)
    assert np.isclose(res.total_work, costs.sum(), rtol=1e-9)
    assert res.makespan >= costs.sum() / p - 1e-9
    assert res.makespan <= costs.sum() + 1e-9


@given(n=st.integers(1, 400), p=st.integers(1, 12),
       chunk=st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_static_chunk_round_robin_property(n, p, chunk):
    """schedule(static,c): chunk k (0-based, in iteration order) belongs to
    worker (k mod P) — the OpenMP spec property."""
    plan = plan_schedule(make_scheduler("static", chunk=chunk), n, p)
    ordered = sorted(plan.chunks, key=lambda c: c.start)
    for k, c in enumerate(ordered):
        assert c.worker == k % p
        assert c.size <= chunk


@given(n=st.integers(1, 1000), p=st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_guided_chunks_nonincreasing(n, p):
    plan = plan_schedule(make_scheduler("guided"), n, p)
    sizes = [c.size for c in plan.chunks]
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))


@given(n=st.integers(8, 800), p=st.integers(2, 12),
       seed=st.integers(0, 999))
@settings(max_examples=30, deadline=None)
def test_wave_plan_equals_executor_chunks(n, p, seed):
    """Batched (SPMD) dequeue must produce the same chunk-size SEQUENCE as
    the paper's per-thread dequeue for deterministic central-queue
    schedulers — the wave adaptation changes cadence, not the schedule."""
    from repro.core.schedulers import GuidedSS
    plan = plan_schedule(GuidedSS(), n, p)
    res = simulate_loop(GuidedSS(), LoopSpec(0, n, num_workers=p),
                        np.ones(n))
    # same multiset of chunk sizes (assignment to workers may differ)
    assert sorted(c.size for c in plan.chunks) == sorted(
        c.size for c in res.chunks)


# ---------------------------------------------------------------------------
# StragglerMitigator token shares (the multi-host batch splitter's input)
# ---------------------------------------------------------------------------
@st.composite
def host_histories(draw):
    """(num_hosts, [(per-host times, per-host token counts), ...]) —
    arbitrary observed step histories, including zero times, zero token
    counts, and no history at all (cold start)."""
    n = draw(st.integers(1, 8))
    steps = draw(st.lists(
        st.tuples(
            st.lists(st.floats(0.0, 100.0, allow_nan=False,
                               allow_infinity=False),
                     min_size=n, max_size=n),
            st.lists(st.integers(0, 5000), min_size=n, max_size=n)),
        max_size=5))
    return n, steps


@given(hh=host_histories(), total=st.integers(0, 50_000),
       min_share=st.floats(0.0, 1.0, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_token_shares_always_partition_total(hh, total, min_share):
    """For ANY host-time history, host count, and min-share floor:
    ``token_shares(total)`` partitions ``total`` exactly (sum-preserving),
    every share is non-negative, the floor is respected, and the AWF
    weights stay finite — the invariants the uneven batch splitter
    consumes blindly every step."""
    from repro.sched import StragglerMitigator
    n, steps = hh
    m = StragglerMitigator(num_hosts=n, min_share=min_share)
    for times, toks in steps:
        m.observe_step({h: times[h] for h in range(n)},
                       host_tokens={h: toks[h] for h in range(n)})
    w = m.weights()
    assert w.shape == (n,) and np.isfinite(w).all() and (w >= 0).all()
    shares = m.token_shares(total)
    assert shares.shape == (n,)
    assert int(shares.sum()) == total
    assert (shares >= 0).all()
    assert (shares >= m.min_share_floor(total)).all()


@given(b=st.integers(1, 3), h=st.integers(1, 3),
       t=st.integers(1, 40), dk=st.sampled_from([4, 8, 16]),
       dv=st.sampled_from([4, 8]), chunk=st.sampled_from([4, 8, 16]),
       inclusive=st.booleans(), seed=st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_chunked_linear_attention_matches_sequential(b, h, t, dk, dv, chunk,
                                                     inclusive, seed):
    """The chunked formulation equals the sequential recurrence for every
    shape/chunking — the kernel's mathematical foundation."""
    import jax.numpy as jnp
    from repro.kernels.linear_scan.ref import linear_attention_ref
    from repro.models.linear_scan import chunked_linear_attention
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dv)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0.01, 4.0, size=(b, h, t, dk)), jnp.float32)
    y, s = chunked_linear_attention(q, k, v, lw, inclusive=inclusive,
                                    chunk=chunk)
    yr, sr = linear_attention_ref(q, k, v, lw, inclusive=inclusive)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Hierarchical composition
# ---------------------------------------------------------------------------
@st.composite
def hier_clauses(draw):
    """Random 1–3-level hier clauses over resolvable flat clauses, with
    per-level worker counts pinned (weight-carrying levels need their own
    team size): returns (clause, [(p, min_chunk), ...] per level)."""
    n_levels = draw(st.integers(1, 3))
    names = ("host", "device", "tile")[:n_levels]
    parts, metas = [], []
    for nm in names:
        clause, p, mc = draw(resolvable_clauses())
        parts.append(f"{nm}={clause}")
        metas.append((p, mc))
    parts.append("workers=" + ":".join(str(p) for p, _ in metas))
    return "hier(" + ", ".join(parts) + ")", metas


@given(hc=hier_clauses())
@settings(max_examples=100, deadline=None)
def test_hier_clause_roundtrip(hc):
    """Random multi-level hier clauses are lossless through the parser:
    parse -> str -> parse is the identity and rendering is a fixed point
    (nested specs are plan-cache identities like flat ones)."""
    clause, _ = hc
    spec = parse(clause)
    assert spec.is_hier
    assert parse(str(spec)) == spec
    assert str(parse(str(spec))) == str(spec)


@given(hc=hier_clauses(), n=st.integers(0, 1500))
@settings(max_examples=80, deadline=None)
def test_hier_composed_plans_conserve_iterations(hc, n):
    """Any hier clause, any loop: the composed leaves exactly partition
    [0, n) (iteration count is conserved through every level), and each
    level's declared min-chunk holds for its non-final chunks."""
    from repro.core.engine import PlanEngine
    clause, metas = hc
    loop = LoopSpec(lb=0, ub=n, num_workers=metas[0][0],
                    loop_id="prop_hier")
    plan = PlanEngine().plan(resolve(clause), loop)
    leaves = plan.leaf_chunks()
    assert sum(leaf["size"] for leaf in leaves) == n
    ivals = sorted((leaf["start"], leaf["start"] + leaf["size"])
                   for leaf in leaves)
    for (_, stop), (start, _) in zip(ivals, ivals[1:]):
        assert stop == start, "leaves overlap or leave a gap"
    if n:
        assert ivals[0][0] == 0 and ivals[-1][1] == n

    def check_min_chunks(p, level):
        _, mc = metas[level]
        if mc is not None:
            by_start = sorted(zip(p.starts.tolist(), p.sizes.tolist()))
            assert all(size >= mc for _, size in by_start[:-1])
        for child in getattr(p, "children", ()):
            check_min_chunks(child, level + 1)

    check_min_chunks(plan, 0)
