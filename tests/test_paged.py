"""Paged-KV serving tests: allocator invariants + paged-vs-dense equivalence.

The block-table KV subsystem makes cache memory the scheduled resource, so
its correctness splits into two layers, each locked here:

* **Allocator invariants** (host side, ``repro.serve_mem``): a live block
  is owned by exactly one table and never on the free list (no aliasing),
  releasing everything returns the pool to full, used/free watermarks
  never go negative, and a refused allocation changes nothing
  (all-or-nothing).  Checked over long seeded-random op sequences always,
  and via hypothesis when the dev dependency is installed.

* **Engine equivalence** (device side): the paged engine — chunked
  prefill through block tables, fused paged decode, preemption with
  evict→readmit — serves token-for-token the SAME generations as the
  dense batched :class:`ServeLoop` for every schedule family, including
  runs where memory pressure forces at least one preemption (greedy
  decode is deterministic, so a readmitted request must resume exactly
  where an uninterrupted run would be).

Plus the chunked-prefill bucketing regression: prefill chunks are
bucket-padded, so compile count is bounded by the BUCKET count no matter
how many distinct prompt lengths (or UDS chunk sizes) the trace produces.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import (PagedServeLoop, Request, ServeLoop,
                                bucket_length, plan_prefill_chunks)
from repro.serve_mem import BlockPool, BlockTables, make_mixed_trace
from repro.serve_mem.blocks import blocks_for_tokens

MAX_LEN = 64
BLOCK_SIZE = 8
N_REQUESTS = 6


def make_requests(seed: int, n: int = N_REQUESTS, lo: int = 4, hi: int = 12,
                  max_new: int = 3):
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("qwen2.5-3b")
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(lo, hi))
                                        ).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------
def check_invariants(pool: BlockPool, tables: BlockTables, mirror) -> None:
    """The subsystem's safety net, checked after every op:

    no aliasing (every live block in exactly one table, none on the free
    list), conservation (used + free == pool size), non-negative
    watermarks, and table/mirror agreement."""
    held = [b for tab in mirror.values() for b in tab]
    assert len(held) == len(set(held)), "block aliased across tables"
    free = set(pool._free)
    assert not (set(held) & free), "live block on the free list"
    assert pool.used + pool.num_free == pool.num_blocks
    assert pool.used == len(held)
    assert 0 <= pool.used <= pool.num_blocks
    assert 0 <= pool.num_free <= pool.num_blocks
    assert 0 <= pool.peak_used <= pool.num_blocks
    assert pool.peak_used >= pool.used
    for rid, tab in mirror.items():
        assert list(tables.row(rid)[:len(tab)]) == tab
        assert all(b == -1 for b in tables.row(rid)[len(tab):])


def run_ops(ops, num_blocks: int, block_size: int, max_blocks: int) -> None:
    """Drive ensure/release ops against a pool while mirroring the
    expected table contents in plain python."""
    pool = BlockPool(num_blocks, block_size)
    tables = BlockTables(pool, max_blocks=max_blocks)
    mirror = {}
    for kind, rid, n_tokens in ops:
        if kind == "ensure":
            need = blocks_for_tokens(n_tokens, block_size)
            if need > max_blocks:
                with pytest.raises(ValueError):
                    tables.ensure(rid, n_tokens)
            else:
                before = pool.num_free
                have = len(mirror.get(rid, []))
                ok = tables.ensure(rid, n_tokens)
                grow = max(need - have, 0)
                if ok:
                    mirror.setdefault(rid, [])
                    got = tables.row(rid)[have:have + grow]
                    mirror[rid].extend(int(b) for b in got)
                    assert pool.num_free == before - grow
                else:   # all-or-nothing: refusal changes NOTHING
                    assert grow > before
                    assert pool.num_free == before
                    assert tables.num_blocks_of(rid) == have
        else:           # release
            freed = tables.release(rid)
            assert freed == len(mirror.pop(rid, []))
        check_invariants(pool, tables, mirror)
    for rid in list(mirror):
        tables.release(rid)
        mirror.pop(rid)
        check_invariants(pool, tables, mirror)
    assert pool.num_free == pool.num_blocks, "release did not drain pool"


def random_ops(rng, n_ops: int, n_rids: int, max_tokens: int):
    ops = []
    for _ in range(n_ops):
        rid = int(rng.integers(0, n_rids))
        if rng.random() < 0.7:
            ops.append(("ensure", rid, int(rng.integers(0, max_tokens))))
        else:
            ops.append(("release", rid, 0))
    return ops


@pytest.mark.parametrize("seed", range(8))
def test_allocator_random_sequences(seed):
    """Long random ensure/release sequences keep every invariant, with
    pools small enough that refusals and over-capacity asks both occur."""
    rng = np.random.default_rng(seed)
    num_blocks = int(rng.integers(1, 24))
    block_size = int(rng.integers(1, 16))
    max_blocks = int(rng.integers(1, 12))
    ops = random_ops(rng, 120, n_rids=6,
                     max_tokens=(max_blocks + 2) * block_size)
    run_ops(ops, num_blocks, block_size, max_blocks)


def test_allocator_hypothesis():
    """The same invariant checker under hypothesis-generated op
    sequences (dev dependency; the seeded suite above always runs)."""
    pytest.importorskip("hypothesis", reason="dev dependency "
                        "(pip install -r requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.sampled_from(["ensure", "release"]),
                   st.integers(0, 5), st.integers(0, 40))

    @settings(max_examples=60, deadline=None)
    @given(num_blocks=st.integers(1, 20), block_size=st.integers(1, 8),
           max_blocks=st.integers(1, 8), ops=st.lists(op, max_size=60))
    def inner(num_blocks, block_size, max_blocks, ops):
        run_ops(ops, num_blocks, block_size, max_blocks)

    inner()


def test_alloc_all_or_nothing_and_counters():
    pool = BlockPool(4, 8)
    got = pool.alloc(3)
    assert got is not None and len(got) == 3
    assert pool.alloc(2) is None            # only 1 free: refused whole
    assert pool.num_free == 1 and pool.failed_allocs == 1
    assert pool.peak_used == 3
    pool.free(got)
    assert pool.num_free == 4 and pool.peak_used == 3


def test_double_free_and_alien_free_refused():
    pool = BlockPool(4, 8)
    got = pool.alloc(2)
    pool.free(got)
    with pytest.raises(ValueError):
        pool.free([got[0]])                 # already free
    with pytest.raises(ValueError):
        pool.free([99])                     # not a pool block


def test_ensure_beyond_table_capacity_raises():
    pool = BlockPool(16, 8)
    tables = BlockTables(pool, max_blocks=2)
    assert tables.max_context == 16
    with pytest.raises(ValueError):
        tables.ensure(0, 17)
    assert pool.num_free == 16              # nothing leaked


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 8) == 0
    assert blocks_for_tokens(1, 8) == 1
    assert blocks_for_tokens(8, 8) == 1
    assert blocks_for_tokens(9, 8) == 2


# ---------------------------------------------------------------------------
# prefill chunk planning
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("clause", ["static", "dynamic", "guided,2"])
@pytest.mark.parametrize("n", [1, 7, 16, 53])
def test_plan_prefill_chunks_tiles_the_prompt(clause, n):
    sizes = plan_prefill_chunks(clause, n, max_chunk=16)
    assert sum(sizes) == n
    assert all(1 <= s <= 16 for s in sizes)


def test_plan_prefill_chunks_follows_the_clause():
    # static: one burst, capped at max_chunk -> equal-ish large chunks
    assert plan_prefill_chunks("static", 48, max_chunk=16) == [16, 16, 16]
    # dynamic,1: minimal chunks
    assert plan_prefill_chunks("dynamic,1", 5, max_chunk=16) == [1] * 5
    assert plan_prefill_chunks("static", 0, max_chunk=16) == []


# ---------------------------------------------------------------------------
# engine equivalence (module-scoped loops: compile once, swap schedulers)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen2.5-3b")


@pytest.fixture(scope="module")
def dense_loop(cfg):
    return ServeLoop(cfg, slots=3, max_len=MAX_LEN, batched=True,
                     decode_steps=2)


@pytest.fixture(scope="module")
def paged_loop(cfg):
    # pool >= N_REQUESTS * max_context: no pressure, pure equivalence
    return PagedServeLoop(cfg, num_blocks=64, block_size=BLOCK_SIZE,
                          max_context=MAX_LEN, concurrency=8,
                          decode_steps=2, prefill_chunk=16)


@pytest.fixture(scope="module")
def tight_loop(cfg):
    # pool far below the working set: decode growth MUST preempt
    return PagedServeLoop(cfg, num_blocks=10, block_size=BLOCK_SIZE,
                          max_context=MAX_LEN, concurrency=8,
                          decode_steps=2, prefill_chunk=16)


def run_loop(loop, scheduler, requests):
    from repro.core import LoopHistory
    loop.scheduler = scheduler
    loop.history = LoopHistory()
    return loop.run(requests)


@pytest.mark.parametrize("clause", ["static", "guided,2", "awf"])
def test_paged_dense_token_equivalence(clause, dense_loop, paged_loop):
    """The tentpole guarantee: where both engines fit the working set,
    the paged engine serves token-for-token the same generations as the
    dense batched engine, under every schedule family."""
    out_d = run_loop(dense_loop, clause, make_requests(42))
    out_p = run_loop(paged_loop, clause, make_requests(42))
    assert sorted(out_p) == list(range(N_REQUESTS))
    assert out_p == out_d
    assert paged_loop.last_stats["preemptions"] == 0
    assert paged_loop.pool.used == 0        # every block returned


def test_preemption_preserves_tokens(dense_loop, tight_loop):
    """Memory pressure forces eviction; the evicted request re-prefills
    its generated prefix on readmission and must resume EXACTLY where an
    uninterrupted (dense) run would be — token-for-token."""
    reqs = make_requests(7, lo=8, hi=32, max_new=12)
    out_d = run_loop(dense_loop, "dynamic", make_requests(7, lo=8, hi=32,
                                                          max_new=12))
    out_p = run_loop(tight_loop, "dynamic", reqs)
    assert tight_loop.last_stats["preemptions"] >= 1
    assert out_p == out_d
    assert any(r.preemptions > 0 for r in reqs)
    # preemption inflates the victim's e2e latency, never its tokens
    assert tight_loop.pool.used == 0


def test_prefill_compiles_bounded_by_buckets(cfg):
    """Chunked-prefill bucketing regression: a trace of many DISTINCT
    prompt lengths (and UDS chunk sizes) compiles one prefill program per
    bucket, not per length.  With max_chunk=16 the only padded widths are
    8 and 16."""
    loop = PagedServeLoop(cfg, num_blocks=64, block_size=BLOCK_SIZE,
                          max_context=MAX_LEN, concurrency=8,
                          decode_steps=4, prefill_chunk=16)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=1 + i * 3).astype(np.int32),
                    max_new=2)
            for i in range(12)]            # lengths 1, 4, 7, ..., 34
    out = loop.run(reqs)
    assert len(out) == 12
    buckets = {bucket_length(s, 16) for s in range(1, 17)}
    assert loop.prefill_compiles <= len(buckets)
    assert loop.prefill_compiles <= 2


def test_paged_observability(cfg):
    """Every request carries its lifecycle stamps and last_stats carries
    the latency percentiles, pool watermarks and preemption count."""
    loop = PagedServeLoop(cfg, num_blocks=64, block_size=BLOCK_SIZE,
                          max_context=MAX_LEN, concurrency=8,
                          decode_steps=2, prefill_chunk=16)
    reqs = make_requests(11)
    loop.run(reqs)
    for r in reqs:
        assert r.t_arrive is not None
        assert r.t_arrive <= r.t_admit <= r.t_first <= r.t_finish
    s = loop.last_stats
    for key in ("queue_p50_s", "queue_p99_s", "admission_p50_s",
                "admission_p99_s", "e2e_p99_s"):
        assert s[key] is not None and s[key] >= 0.0
    assert 0.0 <= s["kv_util_mean"] <= 1.0
    assert s["requests_finished"] == N_REQUESTS
    assert s["preemptions"] == 0
    assert 0 < s["peak_blocks_used"] <= 64
    assert s["peak_concurrency"] >= 1
    assert s["prefill_compiles"] >= 1
    # the serve_paged loop telemetry flushed into the history
    assert loop.measured_epoch() >= 1


def test_dense_loop_gains_meter(dense_loop):
    out = run_loop(dense_loop, "static", make_requests(13))
    assert len(out) == N_REQUESTS
    meter = dense_loop.last_stats["serve_meter"]
    assert meter["requests_finished"] == N_REQUESTS
    assert meter["queue_p99_s"] is not None
    assert meter["admission_p99_s"] is not None
    assert meter["preemptions"] == 0


def test_truncation_matches_dense(cfg, dense_loop, paged_loop):
    """A request whose prompt + max_new overflows max_context is admitted
    with its budget clamped and REPORTED truncated — same rule, same
    tokens as the dense engine."""
    def mk():
        rng = np.random.default_rng(5)
        return [Request(rid=0,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=60).astype(np.int32),
                        max_new=20)]
    out_d = run_loop(dense_loop, "static", mk())
    reqs = mk()
    out_p = run_loop(paged_loop, "static", reqs)
    assert out_p == out_d
    assert reqs[0].truncated and reqs[0].budget == MAX_LEN - 60 + 1
    assert paged_loop.last_stats["truncated"] == [0]


def test_prompt_exceeding_max_context_refused(cfg, paged_loop):
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=MAX_LEN + 1).astype(np.int32), max_new=2)
    with pytest.raises(ValueError, match="exceeds max_context"):
        run_loop(paged_loop, "static", [req])


def test_pool_smaller_than_one_prompt_refused(cfg):
    loop = PagedServeLoop(cfg, num_blocks=2, block_size=BLOCK_SIZE,
                          max_context=MAX_LEN, concurrency=4,
                          decode_steps=1, prefill_chunk=16)
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=40).astype(np.int32), max_new=2)
    with pytest.raises(ValueError, match="raise num_blocks"):
        loop.run([req])


def test_ssm_family_has_no_paged_path():
    from repro.models import get_model
    cfg = get_smoke_config("rwkv6-3b")
    assert get_model(cfg).fused_paged_decode is None
    with pytest.raises(ValueError, match="no paged-KV path"):
        PagedServeLoop(cfg, num_blocks=8, block_size=8, max_context=64)


# ---------------------------------------------------------------------------
# shared trace generator (tests and benchmarks must agree on the workload)
# ---------------------------------------------------------------------------
def test_mixed_trace_deterministic_and_mixed():
    a = make_mixed_trace(40, vocab_size=256, seed=9)
    b = make_mixed_trace(40, vocab_size=256, seed=9)
    assert len(a) == 40
    assert all(np.array_equal(x.prompt, y.prompt) and x.max_new == y.max_new
               for x, y in zip(a, b))
    longs = [t for i, t in enumerate(a) if i % 4 == 0]
    shorts = [t for i, t in enumerate(a) if i % 4 != 0]
    assert min(t.prompt.size for t in longs) > max(
        t.prompt.size for t in shorts)
    c = make_mixed_trace(40, vocab_size=256, seed=10)
    assert any(not np.array_equal(x.prompt, y.prompt) for x, y in zip(a, c))
