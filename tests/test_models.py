"""Per-arch smoke tests + model-math consistency (prefill/decode agreement,
blockwise==full attention, chunked CE == direct CE, MoE capacity)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.steps import chunked_softmax_ce
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_inputs(cfg, batch=B, seq=S, key=KEY):
    if cfg.frontend != "none":
        inputs = {"embeds": jax.random.normal(key, (batch, seq, cfg.d_model))}
    else:
        inputs = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                               cfg.vocab_size)}
    if cfg.mrope_sections is not None:
        pos = jnp.tile(jnp.arange(seq, dtype=jnp.int32)[None], (batch, 1))
        inputs["positions_3d"] = jnp.stack([pos, pos, pos])
    return inputs


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.launch.steps import make_train_step
    from repro.optim import cosine_schedule, make_optimizer
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, specs = model.init(KEY, jnp.float32)
    inputs = make_inputs(cfg)
    logits, _ = model.forward(params, inputs)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_init, opt_update = make_optimizer("adamw", cosine_schedule(1e-3, 2, 50))
    opt_state = opt_init(params)
    step_fn = make_train_step(model, opt_update)
    batch = dict(inputs)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                         cfg.vocab_size)
    if cfg.is_moe:
        from repro.models.moe import moe_capacity
        batch["cap_e"] = jnp.full((cfg.num_experts,),
                                  moe_capacity(cfg, S), jnp.int32)
    # step 3: cosine warmup means lr(0) == 0 (a zero-delta step by design)
    params2, opt2, metrics = step_fn(params, opt_state,
                                     jnp.asarray(3, jnp.int32), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, params2))
    assert delta > 0


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_dims_match_assignment(arch):
    """The full config's dims are pinned; param_count matches the analytic
    formula and the published scale."""
    cfg = get_config(arch)
    model = get_model(cfg)
    params_abs, specs = model.init(KEY, jnp.bfloat16, abstract=True)
    total = sum(np.prod(p.shape) for p in jax.tree.leaves(params_abs))
    analytic = cfg.param_count()
    assert abs(total - analytic) / analytic < 0.05, (total, analytic)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-32b", "musicgen-large",
                                  "qwen2-vl-7b", "grok-1-314b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(t[:k]) + decode steps == forward(t) logits, per position."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, _ = model.init(KEY, jnp.float32)
    n = 12
    inputs = make_inputs(cfg, batch=2, seq=n, key=jax.random.PRNGKey(3))
    cap = None
    if cfg.is_moe:
        # capacity drops are per-invocation: prefill(S) and decode(1) see
        # different todo lists, so agreement requires drop-free capacity
        cap = jnp.full((cfg.num_experts,), 10_000, jnp.int32)
    full, _ = model.forward(params, inputs, cap_e=cap)

    k = 8
    pre_inputs = {kk: (v[:, :k] if kk != "positions_3d" else v[:, :, :k])
                  for kk, v in inputs.items()}
    logits, cache = model.prefill(params, pre_inputs, max_len=n, cap_e=cap)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, k - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(k, n):
        step_inputs = {kk: (v[:, t:t + 1] if kk != "positions_3d"
                            else v[:, :, t:t + 1])
                       for kk, v in inputs.items()}
        logits, cache = model.decode(params, step_inputs, cache, cap_e=cap)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-2.7b"])
def test_ssm_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, _ = model.init(KEY, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    logits, state = model.prefill(params, {"tokens": toks[:, :8]}, 16)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 7]),
                               rtol=2e-3, atol=2e-3)
    for t in range(8, 12):
        logits, state = model.decode(params, {"tokens": toks[:, t:t + 1]},
                                     state)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=4e-3, atol=4e-3)


def test_blockwise_attention_equals_full():
    from repro.models.common import attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    full = attention(q, k, v, causal=True, flash_threshold=10_000)
    flash = attention(q, k, v, causal=True, flash_threshold=1,
                      block_q=16, block_kv=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(flash),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_attention_segment_ids():
    from repro.models.common import attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    segs = jnp.asarray(np.repeat([1, 2], 16)[None, :])
    full = attention(q, k, v, causal=True, segment_ids=segs,
                     flash_threshold=10_000)
    flash = attention(q, k, v, causal=True, segment_ids=segs,
                      flash_threshold=1, block_q=8, block_kv=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(flash),
                               rtol=2e-4, atol=2e-4)


def test_chunked_ce_equals_direct():
    rng = np.random.default_rng(0)
    B_, S_, D_, V_ = 2, 24, 16, 37
    x = jnp.asarray(rng.normal(size=(B_, S_, D_)), jnp.float32)
    head = jnp.asarray(rng.normal(size=(D_, V_)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V_, size=(B_, S_)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(B_, S_)), jnp.float32)
    loss_sum, cnt = chunked_softmax_ce(x, head, labels, mask, chunk=7)
    logits = x @ head
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    direct = jnp.sum((lse - ll) * mask)
    np.testing.assert_allclose(float(loss_sum), float(direct), rtol=1e-5)
    assert float(cnt) == float(mask.sum())


def test_chunked_ce_gradients_match():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    head = jnp.asarray(rng.normal(size=(8, 11)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 11, size=(2, 16)), jnp.int32)

    def loss_chunked(x, h):
        s, c = chunked_softmax_ce(x, h, labels, chunk=4)
        return s / c

    def loss_direct(x, h):
        logits = x @ h
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return jnp.mean(lse - ll)

    g1 = jax.grad(loss_chunked, argnums=(0, 1))(x, head)
    g2 = jax.grad(loss_direct, argnums=(0, 1))(x, head)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_and_loads():
    from repro.models.moe import moe_ffn
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    model = get_model(cfg)
    params, _ = model.init(KEY, jnp.float32)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    from repro.models.moe import moe_capacity
    out_full, load = moe_ffn(x, lp["moe"]["router"], lp["moe"]["w_gate"],
                             lp["moe"]["w_up"], lp["moe"]["w_down"], cfg)
    assert np.isclose(float(load.sum()), 1.0, atol=1e-5)
    # capacity 0 for all experts -> every token dropped -> zero output
    zero_cap = jnp.zeros((cfg.num_experts,), jnp.int32)
    out_zero, _ = moe_ffn(x, lp["moe"]["router"], lp["moe"]["w_gate"],
                          lp["moe"]["w_up"], lp["moe"]["w_down"], cfg,
                          cap_e=zero_cap)
    assert float(jnp.abs(out_zero).sum()) == 0.0
    # explicit uniform budget == the cap_e=None default
    uni = jnp.full((cfg.num_experts,), moe_capacity(cfg, 16), jnp.int32)
    out_uni, _ = moe_ffn(x, lp["moe"]["router"], lp["moe"]["w_gate"],
                         lp["moe"]["w_up"], lp["moe"]["w_down"], cfg,
                         cap_e=uni)
    np.testing.assert_allclose(np.asarray(out_uni), np.asarray(out_full),
                               rtol=1e-5, atol=1e-5)
    # raising hot-expert capacity within the buffer changes (reduces) drops
    big_cap = jnp.full((cfg.num_experts,), 10_000, jnp.int32)
    out_big, _ = moe_ffn(x, lp["moe"]["router"], lp["moe"]["w_gate"],
                         lp["moe"]["w_up"], lp["moe"]["w_down"], cfg,
                         cap_e=big_cap)
    assert np.isfinite(np.asarray(out_big)).all()


def test_remat_matches_no_remat():
    cfg = get_smoke_config("phi3-mini-3.8b")
    model = get_model(cfg)
    params, _ = model.init(KEY, jnp.float32)
    inputs = make_inputs(cfg)
    a, _ = model.forward(params, inputs, remat="full")
    b, _ = model.forward(params, inputs, remat="none")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fp8_kv_cache_decode_close():
    """fp8 KV cache (serving memory lever): decode logits stay within ~2%%."""
    cfg8 = dataclasses.replace(get_smoke_config("qwen2.5-3b"),
                               kv_cache_dtype="fp8")
    model = get_model(cfg8)
    params, _ = model.init(KEY, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg8.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :8]}, 16)
    assert cache["k"].dtype == jnp.float8_e4m3fn
    l, cache = model.decode(params, {"tokens": toks[:, 8:9]}, cache)
    rel = float(jnp.abs(l - full[:, 8]).max()
                / (jnp.abs(full[:, 8]).max() + 1e-9))
    assert rel < 0.05
