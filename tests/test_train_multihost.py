"""Multi-host training: equivalence, uneven splitting, and replanning.

The splitter and validation tests run on any device count.  The e2e tests
need 4 emulated hosts — run them (and the CI leg does) with

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -q tests/test_train_multihost.py
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import split_batch_by_shares

needs_hosts = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(the multi-host CI leg)")


def _mk_batch(B, S, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(1, 100, (B, S)).astype(np.int32)
    return {"tokens": jnp.asarray(tokens),
            "labels": jnp.asarray(tokens.copy()),
            "segment_ids": jnp.asarray(np.ones((B, S), np.int32))}


# ------------------------------------------------------------- the splitter
def test_split_uniform_shares_is_exact_noop():
    """Full/uniform shares return the batch UNTOUCHED (same arrays) — the
    identity the multi-host loss-equivalence guarantee rests on."""
    batch = _mk_batch(4, 8)
    out, host_tokens = split_batch_by_shares(batch, [16, 16], 2)
    assert out is batch
    assert host_tokens.tolist() == [16, 16]


def test_split_uneven_shares_mask_block_tails():
    batch = _mk_batch(4, 8)
    out, host_tokens = split_batch_by_shares(batch, [8, 8, 8, 3], 4)
    assert host_tokens.tolist() == [8, 8, 8, 3]
    # host 3 = row 3: first 3 positions kept, the tail is padding
    lab = np.asarray(out["labels"])
    assert (lab[3, :3] >= 0).all() and (lab[3, 3:] == -100).all()
    assert (np.asarray(out["tokens"])[3, 3:] == 0).all()
    assert (np.asarray(out["segment_ids"])[3, 3:] == 0).all()
    # other hosts untouched
    np.testing.assert_array_equal(lab[:3], np.asarray(batch["labels"])[:3])


def test_split_masks_row_major_within_multi_row_blocks():
    """2 rows per host: a budget below one row's capacity keeps only the
    block's leading positions (row-major), cutting the whole tail row."""
    batch = _mk_batch(4, 8)
    out, host_tokens = split_batch_by_shares(batch, [10, 16], 2)
    lab = np.asarray(out["labels"])
    assert (lab[0] >= 0).all()                       # row 0: positions 0..7
    assert (lab[1, :2] >= 0).all() and (lab[1, 2:] == -100).all()
    assert (lab[2:] >= 0).all()                      # host 1 untouched
    assert host_tokens.tolist() == [10, 16]


def test_split_clamps_shares_to_host_capacity():
    batch = _mk_batch(4, 8)
    out, host_tokens = split_batch_by_shares(batch, [100, 0], 2)
    assert host_tokens.tolist() == [16, 0]
    assert (np.asarray(out["labels"])[2:] == -100).all()


def test_split_masks_embeds_and_passes_extras_through():
    batch = _mk_batch(4, 8)
    batch["embeds"] = jnp.ones((4, 8, 3), jnp.float32)
    batch["cap_e"] = jnp.arange(5, dtype=jnp.int32)
    out, _ = split_batch_by_shares(batch, [8, 8, 8, 0], 4)
    emb = np.asarray(out["embeds"])
    assert (emb[3] == 0).all() and (emb[:3] == 1).all()
    np.testing.assert_array_equal(np.asarray(out["cap_e"]), np.arange(5))


def test_split_rejects_non_divisible_batch():
    with pytest.raises(ValueError, match="divisible"):
        split_batch_by_shares(_mk_batch(4, 8), [16, 16, 16], 3)


def test_split_rejects_wrong_share_count():
    with pytest.raises(ValueError, match="shares"):
        split_batch_by_shares(_mk_batch(4, 8), [16, 16, 16], 2)
    with pytest.raises(ValueError, match="shares"):
        split_batch_by_shares(_mk_batch(4, 8), [16], 2)


# --------------------------------------------------- TrainLoop validation
def test_train_loop_rejects_bad_host_args():
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    with pytest.raises(ValueError, match="divisible"):
        TrainLoop(cfg, batch=5, seq_len=32, hosts=2)
    with pytest.raises(ValueError, match="positive"):
        TrainLoop(cfg, batch=4, seq_len=32, hosts=2, host_skew=[1.0, -1.0])
    if jax.device_count() < 8:
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            TrainLoop(cfg, batch=8, seq_len=32, hosts=8)


# -------------------------------------------------------------- e2e (4 hosts)
@needs_hosts
def test_host_mesh_and_batch_shardings():
    from repro.launch.mesh import (base_rules, batch_shardings,
                                   make_host_mesh)
    mesh = make_host_mesh(4)
    assert mesh.axis_names == ("host", "model")
    assert mesh.devices.shape == (4, 1)
    rules = base_rules(mesh)
    assert rules["batch"] == ("host",)
    batch = _mk_batch(8, 16)
    shards = batch_shardings(mesh, rules, batch)
    assert shards["tokens"].spec == jax.sharding.PartitionSpec("host", None)


@needs_hosts
def test_multihost_uniform_shares_match_single_host_losses():
    """N emulated hosts under uniform shares == single host, token for
    token: same seed, same packed batches (the uniform split is a no-op),
    loss trajectories equal up to cross-device reduction order."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    multi = TrainLoop(cfg, batch=4, seq_len=64, seed=3, hosts=4)
    a = multi.run(5, log_every=100)
    single = TrainLoop(cfg, batch=4, seq_len=64, seed=3, mesh_shape=(1, 1))
    b = single.run(5, log_every=100)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=2e-3)
    # shares stayed exactly uniform (no masking happened): equal measured
    # per-host rates must NOT perturb the split
    assert multi.last_shares.tolist() == [64, 64, 64, 64]
    # per-host attribution reached the telemetry: all 4 hosts have time,
    # and every step flushed as its own measured epoch
    per_worker = multi.telemetry.summary()["per_worker"]
    assert all(per_worker[h]["time_s"] > 0 for h in range(4))
    assert multi.telemetry.epoch() == 5


@needs_hosts
def test_injected_slow_host_rebalances_and_invalidates_plans():
    """A 2x-slow host (injected through the per-host time attribution)
    loses token share after telemetry flushes, each step bumps the
    measured epoch (the adaptive plan-cache invalidation edge), and the
    engine replans the shares from the new weights."""
    from repro.core import get_engine
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    loop = TrainLoop(cfg, batch=8, seq_len=64, seed=0, hosts=4,
                     host_skew=[1.0, 1.0, 1.0, 2.0])
    # cold start: before any measurement the split is exactly uniform
    loop.next_batch()
    assert loop.last_shares.tolist() == [128, 128, 128, 128]
    assert loop.mitigator.epoch() == 0

    misses0 = get_engine().cache_info().misses
    losses = loop.run(6, log_every=100)
    assert np.isfinite(losses).all()
    # every step flushed one measured epoch -> cached adaptive plans for
    # this history are invalidated and the shares replanned
    assert loop.mitigator.epoch() == 6
    assert get_engine().cache_info().misses > misses0
    # the slow host was flagged and its token share dropped off uniform
    assert 3 in loop.mitigator.stragglers()
    frac = loop.last_shares[3] / loop.last_shares.sum()
    assert frac < 0.20, f"slow host still holds {frac:.3f} of the tokens"
    w = loop.mitigator.weights()
    assert w[3] < min(w[:3]) and np.isfinite(w).all()


def test_fused_microbatches_match_unfused_losses():
    """The fused K-microbatch step (UDS permutation applied ON DEVICE
    inside one jitted dispatch) is numerically identical to the unfused
    path (host-side eager permutation + jitted step): same seed, same
    schedule, equal loss trajectories."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    kw = dict(batch=4, seq_len=32, seed=0, num_microbatches=2,
              microbatch_scheduler="dynamic,1", mesh_shape=(1, 1))
    unfused = TrainLoop(cfg, **kw)
    a = unfused.run(3, log_every=100)
    fused = TrainLoop(cfg, fused_microbatches=True, **kw)
    b = fused.run(3, log_every=100)
    assert fused.fused_microbatches
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_fused_microbatches_noop_at_one_microbatch():
    """fused_microbatches without gradient accumulation has nothing to
    fuse: the flag is ignored, not an error."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    loop = TrainLoop(cfg, batch=2, seq_len=32, num_microbatches=1,
                     fused_microbatches=True, mesh_shape=(1, 1))
    assert not loop.fused_microbatches


def test_multihost_rejects_microbatching_for_flat_clauses():
    """Physical row ownership under the (M, B/M, S) microbatch reshape is
    not the splitter's contiguous-block host model, so for a FLAT clause
    the combination is refused instead of silently mis-attributing work.
    A hierarchical clause composes: its host level owns the blocks and
    the microbatch permutation is planned per block."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    with pytest.raises(ValueError, match="microbatches"):
        TrainLoop(cfg, batch=8, seq_len=32, hosts=4, num_microbatches=2)
    if jax.device_count() >= 4:
        loop = TrainLoop(cfg, batch=8, seq_len=32, hosts=4,
                         num_microbatches=2,
                         scheduler="hier(host=awf, device=static)")
        assert loop.hier is not None
        # the device level took over the microbatch assignment
        assert loop.microbatch_sched == loop.hier.level("device")
    # hier still validates the block geometry: every host block must
    # split evenly into microbatches
    with pytest.raises(ValueError, match="not divisible"):
        TrainLoop(cfg, batch=8, seq_len=32, hosts=4, num_microbatches=3,
                  scheduler="hier(host=awf, device=static)")


@needs_hosts
def test_multihost_hier_microbatching_matches_single_host_losses():
    """The acceptance equivalence: a 4-host hier(host=awf, device=static)
    loop WITH gradient accumulation matches the single-host trajectory —
    uniform shares make the split a no-op, the block-aligned permutation
    keeps every microbatch shard inside its host's block, and the
    grouping-invariant accumulation makes the grouping loss-neutral."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    kw = dict(batch=8, seq_len=32, seed=3, num_microbatches=2,
              scheduler="hier(host=awf, device=static)")
    multi = TrainLoop(cfg, hosts=4, **kw)
    a = multi.run(5, log_every=100)
    single = TrainLoop(cfg, mesh_shape=(1, 1), **kw)
    b = single.run(5, log_every=100)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=2e-3)
    assert multi.last_shares.tolist() == [64, 64, 64, 64]
    # fused on-device permutation is the same numbers again
    fused = TrainLoop(cfg, hosts=4, fused_microbatches=True, **kw)
    c = fused.run(5, log_every=100)
    np.testing.assert_allclose(c, a, rtol=1e-3, atol=2e-3)


@needs_hosts
def test_membership_requeues_only_dead_hosts_blocks():
    """Elastic churn on a composed plan: the dead hosts' contiguous row
    blocks (and ONLY those) are requeued over the survivors — the host
    level's chunk→worker provenance is the recovery map."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    loop = TrainLoop(cfg, batch=8, seq_len=64, seed=0, hosts=4,
                     num_microbatches=2,
                     scheduler="hier(host=awf, device=static)",
                     host_skew=[1.0, 1.0, 1.0, 2.0],
                     elastic=True, kill_hosts=[2, 3], kill_at_step=3)
    losses = loop.run(5, log_every=10 ** 9)
    assert len(losses) == 5 and np.isfinite(losses).all()
    assert loop.hosts == 2
    assert loop.requeue_audits, (
        "skewed shares must come from a live composed plan, so the kill "
        "must exercise the requeue path")
    audit = loop.requeue_audits[-1]
    from repro.core.plan import ComposedPlan
    plan = loop.mitigator.last_plan
    assert plan is None or isinstance(plan, ComposedPlan)
    # the requeued ranges are exactly the union of the dead hosts' blocks:
    # blocks sit in host-id order, so hosts 2+3 own one contiguous tail
    total = loop.batch * loop.seq_len
    requeued = {i for lo, hi in audit["ranges"] for i in range(lo, hi)}
    assert audit["lost"] == [2, 3]
    assert len(requeued) == audit["requeued_iters"]
    assert max(requeued) + 1 == total
    assert requeued == set(range(min(requeued), total))
    # survivors carried their own budgets untouched; requeued tokens are
    # redistributed on top, covering the full budget
    assert len(audit["carried"]) == 2
    assert sum(audit["carried"]) + len(requeued) == total
    assert sum(audit["shares"]) == total
