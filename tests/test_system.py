"""End-to-end behaviour tests: real training runs on reduced configs, with
UDS scheduling, checkpoint/restart, and serving."""

import numpy as np

from repro.configs import get_smoke_config


def test_train_loop_loss_decreases(tmp_path):
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    loop = TrainLoop(cfg, batch=4, seq_len=64, scheduler="fac2",
                     ckpt_dir=str(tmp_path), seed=0)
    losses = loop.run(15, log_every=100)
    assert np.isfinite(losses).all()
    # learning happened: compare smoothed windows, not two noisy samples
    # (each step draws a fresh synthetic batch)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 10    # checkpoint committed


def test_train_loop_moe_with_capacity_planner():
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    loop = TrainLoop(cfg, batch=4, seq_len=32, scheduler="wf2")
    losses = loop.run(6, log_every=100)
    assert np.isfinite(losses).all()


def test_train_loop_microbatched_matches_shapes():
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("phi3-mini-3.8b")
    loop = TrainLoop(cfg, batch=4, seq_len=32, num_microbatches=2)
    losses = loop.run(4, log_every=100)
    assert np.isfinite(losses).all()


def test_serving_completes_all_requests():
    from repro.launch.serve import Request, ServeLoop
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 12))
                                    ).astype(np.int32),
                max_new=3)
        for i in range(5)
    ]
    loop = ServeLoop(cfg, slots=2, scheduler="dynamic")
    out = loop.run(reqs)
    assert sorted(out) == list(range(5))
    assert all(len(v) == 3 for v in out.values())


def test_serving_guided_schedule():
    from repro.launch.serve import Request, ServeLoop
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new=2) for i in range(6)]
    out = ServeLoop(cfg, slots=3, scheduler="guided").run(reqs)
    assert sorted(out) == list(range(6))


def test_rng_determinism_across_restart():
    """Same seed => numerically identical trajectory (restart soundness)."""
    from repro.launch.train import TrainLoop
    cfg = get_smoke_config("qwen2.5-3b")
    l1 = TrainLoop(cfg, batch=2, seq_len=32, seed=7)
    a = l1.run(6, log_every=100)
    l2 = TrainLoop(cfg, batch=2, seq_len=32, seed=7)
    b = l2.run(6, log_every=100)
    np.testing.assert_allclose(a, b, rtol=1e-5)
