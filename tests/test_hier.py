"""Cross-level conformance suite for hierarchical schedule composition.

``hier(host=..., device=..., tile=...)`` compiles to a ComposedPlan whose
outermost level partitions the loop into contiguous blocks and whose
inner levels re-plan every block.  The suite pins the composition laws:

* single-level identity — ``hier(host=X)`` is chunk-for-chunk identical
  to flat ``X`` for EVERY registered builtin family;
* exact partition — composed leaves cover ``[lb, ub)`` with no overlap;
* provenance — every leaf chunk maps back through its host block;
* leaf orders — ``tile_order`` is an exact permutation, host-block-major;
* membership — requeue on a composed plan recovers exactly the dead
  host's contiguous block.
"""

import numpy as np
import pytest

from repro.core import ComposedPlan, HierSchedule, LoopSpec
from repro.core.engine import PlanEngine
from repro.core.spec import parse, registered_names, resolve
from repro.sched.microbatch import plan_hier_microbatch_permutation

P = 4

# one representative clause per registered builtin family (weights sized
# for P workers).  The completeness assertion below keeps this map honest:
# a newly registered family fails the suite until it gets a row here.
FAMILY_CLAUSES = {
    "af": "af",
    "auto": "auto(candidates=guided:fac2)",
    "awf": "awf",
    "awf_b": "awf_b",
    "awf_c": "awf_c",
    "awf_d": "awf_d",
    "awf_e": "awf_e",
    "dynamic": "dynamic,2",
    "fac": "fac",
    "fac2": "fac2",
    "fsc": "fsc",
    "gss": "gss",
    "guided": "guided,4",
    "rand": "rand(seed=7)",
    "ss": "ss",
    "static": "static",
    "static_block": "static_block",
    "static_cyclic": "static_cyclic",
    "static_steal": "static_steal",
    "taper": "taper(mu=2.0,sigma=0.5)",
    "tfss": "tfss",
    "tss": "tss(64,8)",
    "wf2": "wf2(weights=2.0:1.0:1.0:1.0)",
}


def test_family_map_covers_registry():
    builtin = set(registered_names(source="builtin")) - {"hier"}
    assert builtin == set(FAMILY_CLAUSES), (
        "FAMILY_CLAUSES out of sync with the builtin registry — add a "
        "representative clause for every new family")


@pytest.mark.parametrize("family", sorted(FAMILY_CLAUSES))
def test_single_level_hier_identical_to_flat(family):
    """hier(host=X) == flat X, chunk for chunk, for every builtin."""
    clause = FAMILY_CLAUSES[family]
    loop = LoopSpec(lb=0, ub=1000, num_workers=P, loop_id="hier_id")
    eng = PlanEngine()
    flat = eng.plan(resolve(clause), loop)
    hier = eng.plan(resolve(f"hier(host={clause})"), loop)
    assert isinstance(hier, ComposedPlan) and not hier.children
    assert hier.identical(flat), f"hier(host={clause}) diverged from flat"
    # and the leaf order every kernel front-end consumes matches too
    np.testing.assert_array_equal(hier.tile_order(order="worker"),
                                  flat.tile_order(order="worker"))


def test_composed_plan_partitions_exactly():
    loop = LoopSpec(lb=7, ub=1007, num_workers=P, loop_id="hier_part")
    plan = PlanEngine().plan(
        resolve("hier(host=wf2(weights=4.0:2.0:1.0:1.0), device=guided,4, "
                "tile=static)"), loop)
    assert isinstance(plan, ComposedPlan)
    assert plan.num_levels == 3
    assert plan.level_names == ("host", "device", "tile")
    leaves = plan.leaf_chunks()
    assert sum(leaf["size"] for leaf in leaves) == loop.trip_count
    intervals = sorted((leaf["start"], leaf["start"] + leaf["size"])
                       for leaf in leaves)
    assert intervals[0][0] == loop.lb
    assert intervals[-1][1] == loop.ub
    for (_, stop), (start, _) in zip(intervals, intervals[1:]):
        assert stop == start, "composed leaves overlap or leave a gap"


def test_leaf_provenance_maps_through_host_blocks():
    loop = LoopSpec(lb=0, ub=997, num_workers=P, loop_id="hier_prov")
    plan = PlanEngine().plan(
        resolve("hier(host=static, device=fac2, tile=static)"), loop)
    seen_per_host = {h: 0 for h in range(P)}
    for leaf in plan.leaf_chunks():
        owners = leaf["owners"]
        assert set(owners) == {"host", "device", "tile"}
        h = owners["host"]
        lo, hi = plan.host_block(h)
        assert lo <= leaf["start"] and leaf["start"] + leaf["size"] <= hi
        seen_per_host[h] += leaf["size"]
    for h in range(P):
        lo, hi = plan.host_block(h)
        assert seen_per_host[h] == hi - lo, (
            f"host {h}'s leaves do not reassemble its block")
    # blocks themselves tile the loop in host-id order
    assert plan.host_block(0)[0] == loop.lb
    assert plan.host_block(P - 1)[1] == loop.ub
    for h in range(P - 1):
        assert plan.host_block(h)[1] == plan.host_block(h + 1)[0]


def test_composed_tile_order_is_block_major_permutation():
    loop = LoopSpec(lb=0, ub=257, num_workers=P, loop_id="hier_tiles")
    plan = PlanEngine().plan(
        resolve("hier(host=static, device=guided,2)"), loop)
    for order in ("dequeue", "worker"):
        got = plan.tile_order(order=order)
        assert sorted(got.tolist()) == list(range(257))
        # host-block-major: block h's tiles appear as one contiguous run
        pos = 0
        for h in range(P):
            lo, hi = plan.host_block(h)
            run = got[pos:pos + (hi - lo)]
            assert sorted(run.tolist()) == list(range(lo, hi))
            pos += hi - lo


def test_level_workers_pin_per_level_team_sizes():
    loop = LoopSpec(lb=0, ub=600, num_workers=P, loop_id="hier_workers")
    plan = PlanEngine().plan(
        resolve("hier(host=static, device=dynamic, workers=2:3)"), loop)
    assert plan.loop.num_workers == 2          # host level pinned to 2
    assert len(plan.children) == 2
    for child in plan.children:
        assert child.loop.num_workers == 3     # device level pinned to 3
        assert set(child.workers.tolist()) <= {0, 1, 2}


def test_composed_plan_is_cacheable():
    eng = PlanEngine()
    loop = LoopSpec(lb=0, ub=1000, num_workers=P, loop_id="hier_cache")
    a = eng.plan(resolve("hier(host=static, device=guided,4)"), loop)
    b = eng.plan(resolve("hier(host=static, device=guided,4)"), loop)
    assert a is b, "equal hier clauses must hit the plan cache"


def test_requeue_recovers_exactly_the_dead_hosts_block():
    eng = PlanEngine()
    loop = LoopSpec(lb=0, ub=1000, num_workers=P, loop_id="hier_requeue")
    clause = "hier(host=wf2(weights=1.0:1.0:2.0:4.0), device=static)"
    plan = eng.plan(resolve(clause), loop)
    lost = [2]
    lo, hi = plan.host_block(2)
    assert plan.unfinished_ranges(lost) == [(lo, hi)]
    new_plan, iter_map = eng.requeue_plan(
        plan, clause, lost_workers=lost, num_workers=P - 1)
    assert len(iter_map) == hi - lo
    assert sorted(iter_map) == list(range(lo, hi)), (
        "requeue must move ONLY the dead host's contiguous block")
    # survivors' blocks are untouched by construction (their ids never
    # appear in the requeued iteration map)
    for h in (0, 1, 3):
        slo, shi = plan.host_block(h)
        assert not (set(range(slo, shi)) & set(iter_map))


def test_hier_spec_roundtrip_and_accessors():
    clause = "hier(host=awf, device=guided,4, tile=static, workers=4:2:2)"
    spec = parse(clause)
    assert spec.is_hier
    assert parse(str(spec)) == spec
    assert [n for n, _ in spec.levels] == ["host", "device", "tile"]
    assert spec.level_workers == (4, 2, 2)
    sched = resolve(spec)
    assert isinstance(sched, HierSchedule)
    assert sched.level("device") == parse("guided,4")
    assert sched.adaptive          # awf host level => epoch-keyed plans
    assert not resolve("hier(host=static)").adaptive


@pytest.mark.parametrize("clause,msg", [
    ("hier()", "at least one level"),
    ("hier(host=static, host=guided)", "duplicate"),
    ("hier(pod=static)", "unknown hier level"),
    ("hier(host=runtime)", "concrete schedule"),
    ("hier(host=hier(device=static))", "cannot nest"),
    ("hier(host=static, workers=2:2)", "workers"),
    ("hier(host=static),8", "chunksize"),
])
def test_hier_grammar_rejections(clause, msg):
    with pytest.raises(ValueError, match=msg):
        parse(clause)


def test_hier_microbatch_permutation_is_block_aligned():
    rng = np.random.default_rng(0)
    B, M, H = 32, 4, 4
    costs = rng.integers(1, 100, size=B).astype(float)
    perm = plan_hier_microbatch_permutation("dynamic,1", costs, M, H)
    assert sorted(perm.tolist()) == list(range(B))
    rows_per_host, rpm = B // H, B // (M * H)
    for m in range(M):
        for h in range(H):
            sl = perm[m * (B // M) + h * rpm:
                      m * (B // M) + (h + 1) * rpm]
            assert all(h * rows_per_host <= r < (h + 1) * rows_per_host
                       for r in sl), (
                "microbatch shard rows crossed a host block")
    with pytest.raises(ValueError, match="divide evenly"):
        plan_hier_microbatch_permutation("static", costs, 3, H)
