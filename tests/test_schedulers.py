"""Scheduler correctness: chunk sequences vs published closed forms, OpenMP
semantics, and the qualitative load-balancing claims the paper builds on."""

import math

import numpy as np
import pytest

from repro.core import (LoopSpec, SchedulerContext, get_engine,
                        make_scheduler, plan_schedule, simulate_loop,
                        LoopHistory)
from repro.core.interface import ceil_div, chunks_cover
from repro.core.schedulers import (FAC2, AWF, GuidedSS, SelfScheduling,
                                   TrapezoidSS)


def dequeue_all(sched, n, p, loop_id="t"):
    """Single-worker drain: the raw chunk-size sequence."""
    loop = LoopSpec(lb=0, ub=n, num_workers=p, loop_id=loop_id)
    stream = get_engine().open_stream(sched, SchedulerContext(loop=loop))
    sizes = []
    while (c := stream.next(0, None)) is not None:
        sizes.append(c.size)
    stream.close()
    return sizes


# ------------------------------------------------------------- closed forms
def test_static_block_matches_openmp():
    # schedule(static): P blocks of ceil(N/P), round-robin
    plan = plan_schedule(make_scheduler("static_block"), 1000, 8)
    per = plan.per_worker()
    assert all(len(v) == 1 for v in per.values())
    assert [per[w][0].size for w in range(8)] == [125] * 8
    # non-divisible: last worker takes the remainder
    plan = plan_schedule(make_scheduler("static_block"), 1001, 8)
    sizes = [sum(c.size for c in per) for per in plan.per_worker().values()]
    assert sizes == [126] * 7 + [119]


def test_static_cyclic_assignment():
    # schedule(static,1): iteration i -> worker i mod P
    plan = plan_schedule(make_scheduler("static_cyclic"), 64, 4)
    for c in plan.chunks:
        assert c.size == 1
        assert c.worker == c.start % 4


def test_dynamic_chunk_semantics():
    # schedule(dynamic,k): every chunk is k except possibly the last
    sizes = dequeue_all(SelfScheduling(chunk=7), 100, 4)
    assert sizes[:-1] == [7] * (len(sizes) - 1)
    assert sizes[-1] == 100 - 7 * (len(sizes) - 1)


def test_guided_sequence_closed_form():
    # GSS: chunk_j = ceil(R_j / P)
    n, p = 1000, 4
    sizes = dequeue_all(GuidedSS(), n, p)
    r = n
    for s in sizes:
        assert s == max(1, ceil_div(r, p))
        r -= s
    assert r == 0


def test_tss_linear_decrement():
    # TSS(f, l): chunk_k = f - k*delta, delta = (f-l)/(steps-1)
    n, p = 1000, 4
    f, l = ceil_div(n, 2 * p), 1      # defaults: f=125, l=1
    steps = ceil_div(2 * n, f + l)
    delta = (f - l) / (steps - 1)
    sizes = dequeue_all(TrapezoidSS(), n, p)
    for k, s in enumerate(sizes[:-1]):   # last chunk is the remainder
        assert s == max(int(math.floor(f - k * delta + 0.5)), l)


def test_fac2_halving_batches():
    # FAC2: batch j of P chunks sized ceil(R_j / 2P)
    n, p = 1024, 4
    plan = plan_schedule(make_scheduler("fac2"), n, p)
    r = n
    for wave in plan.waves:
        expect = max(1, ceil_div(r, 2 * p))
        for c in wave:
            assert c.size in (expect, r - (len(wave) - 1) * expect,
                              min(expect, r))
        r -= sum(c.size for c in wave)
    assert r == 0
    # first batch schedules exactly half
    first = sum(c.size for c in plan.waves[0])
    assert first == n // 2


def test_fsc_kruskal_weiss_formula():
    n, p, h, sigma = 10_000, 8, 1e-4, 2e-3
    sched = make_scheduler("fsc", overhead=h, sigma=sigma)
    sizes = dequeue_all(sched, n, p)
    expect = int(math.ceil((math.sqrt(2) * n * h
                            / (sigma * p * math.sqrt(math.log(p)))) ** (2 / 3)))
    assert sizes[0] == expect
    assert all(s == expect for s in sizes[:-1])


def test_wf2_respects_weights():
    w = {0: 2.0, 1: 0.5, 2: 1.0, 3: 0.5}
    sched = make_scheduler("wf2", weights=w)
    plan = plan_schedule(sched, 4000, 4)
    first_wave = {c.worker: c.size for c in plan.waves[0]}
    base = 4000 // 8  # fac2 batch chunk
    assert first_wave[0] == 2 * base
    assert first_wave[1] == base // 2


# --------------------------------------------------------------- invariants
@pytest.mark.parametrize("name", ["static", "static_cyclic", "dynamic",
                                  "guided", "tss", "tfss", "taper", "fac",
                                  "fac2", "wf2",
                                  "awf", "awf_b", "awf_c", "awf_d", "awf_e",
                                  "af", "rand", "fsc", "static_steal"])
@pytest.mark.parametrize("n,p", [(1, 1), (7, 3), (100, 8), (1000, 16),
                                 (37, 64)])
def test_exact_coverage(name, n, p):
    plan = plan_schedule(make_scheduler(name), n, p, loop_id=f"{name}")
    assert chunks_cover(LoopSpec(lb=0, ub=n, num_workers=p), plan.chunks)


def test_strided_loop_indices():
    # lb=10, ub=50, incr=4 -> iterations 10,14,...,46
    loop = LoopSpec(lb=10, ub=50, incr=4, num_workers=2)
    assert loop.trip_count == 10
    plan_chunks = plan_schedule(make_scheduler("dynamic"), 10, 2).chunks
    src = [i for c in plan_chunks for i in c.indices(loop)]
    assert sorted(src) == list(range(10, 50, 4))


# ----------------------------------------------------- adaptive strategies
def test_awf_learns_heterogeneous_speeds():
    """AWF (timestep variant) must learn 2:1 worker speeds from history and
    then assign ~2x iterations to the fast worker."""
    hist = LoopHistory()
    n, p = 800, 2
    speeds = [2.0, 1.0]
    costs = np.ones(n)
    sched = AWF(variant="timestep")
    # invocation 1: uniform weights (no history)
    r1 = simulate_loop(sched, LoopSpec(0, n, num_workers=p, loop_id="aw"),
                       costs, speeds=speeds, history=hist)
    # invocation 2: weights from measured rates
    r2 = simulate_loop(sched, LoopSpec(0, n, num_workers=p, loop_id="aw"),
                       costs, speeds=speeds, history=hist)
    w0_iters_2 = sum(c.size for c in r2.chunks if c.worker == 0)
    assert w0_iters_2 > 0.58 * n          # fast worker takes ~2/3
    assert r2.makespan <= r1.makespan + 1e-9


def test_af_adapts_chunk_sizes_to_variance():
    # high-variance worker should receive smaller chunks once measured
    rng = np.random.default_rng(3)
    n, p = 2000, 4
    costs = rng.exponential(1.0, n)
    res = simulate_loop(make_scheduler("af"), LoopSpec(0, n, num_workers=p),
                        costs)
    assert chunks_cover(LoopSpec(0, n, num_workers=p), res.chunks)
    assert res.imbalance < 0.2


# --------------------------------------------- qualitative literature claims
def test_dynamic_beats_static_under_imbalance():
    """The claim motivating the whole paper: under irregular iteration costs
    the three standard schedules are dominated by factoring-family UDS."""
    rng = np.random.default_rng(0)
    n, p = 2000, 8
    costs = rng.lognormal(0.0, 1.5, n)    # heavy-tailed imbalance
    mk = {}
    for name in ("static", "dynamic", "guided", "fac2", "awf_b"):
        res = simulate_loop(make_scheduler(name),
                            LoopSpec(0, n, num_workers=p, loop_id=name),
                            costs, overhead=1e-4)
        mk[name] = res.makespan
    assert mk["fac2"] < mk["static"]
    assert mk["dynamic"] < mk["static"]
    assert mk["awf_b"] <= mk["fac2"] * 1.05


def test_overhead_tradeoff_dynamic1_vs_chunked():
    """With large per-dequeue overhead, dynamic,1 loses to chunked dynamic —
    the scheduling-overhead tradeoff (GSS/FSC motivation)."""
    n, p = 4000, 8
    costs = np.ones(n) * 1e-4
    fine = simulate_loop(SelfScheduling(chunk=1),
                         LoopSpec(0, n, num_workers=p), costs, overhead=1e-3)
    coarse = simulate_loop(SelfScheduling(chunk=64),
                           LoopSpec(0, n, num_workers=p), costs,
                           overhead=1e-3)
    assert coarse.makespan < fine.makespan


def test_heterogeneous_machines_wf2_beats_fac2():
    n, p = 4000, 4
    costs = np.ones(n)
    speeds = [4.0, 1.0, 1.0, 1.0]
    fac2 = simulate_loop(FAC2(), LoopSpec(0, n, num_workers=p), costs,
                         speeds=speeds)
    wf2 = simulate_loop(make_scheduler("wf2", weights={0: 4, 1: 1, 2: 1, 3: 1}),
                        LoopSpec(0, n, num_workers=p), costs, speeds=speeds,
                        overhead=0.0)
    assert wf2.makespan <= fac2.makespan
