"""The paper's interface claims, executed:

* the lambda-style and declare-style specifications of ``mystatic`` (Fig. 2)
  produce IDENTICAL schedules to each other and to the built-in
  ``schedule(static, chunk)``;
* the six-operation set reduces to the three-operation set without changing
  any schedule (the paper's merge argument);
* templates can be partially overridden at the use site;
* the monotonic modifier is enforced.
"""

import pytest

from repro.core import LoopSpec, SchedulerContext, get_engine, plan_waves
from repro.core.interface import three_op_from_six
from repro.core.schedulers import StaticChunk, GuidedSS
from repro.core import declare
from repro.core.declare import (ARG, OMP_CHUNKSZ, OMP_INCR, OMP_LB,
                                OMP_LB_CHUNK, OMP_NUM_WORKERS, OMP_UB,
                                OMP_UB_CHUNK, Ref, call, declare_schedule,
                                omp_get_thread_num, use_schedule)
from repro.core import lambda_style as ls


def plan_of(sched, n=103, p=4, chunk=8):
    loop = LoopSpec(lb=0, ub=n, num_workers=p, chunk=chunk, loop_id="x")
    return plan_waves(sched, loop)


# ------------------------------------------------ declare-style (paper §4.2)
class LoopRecord:
    """The paper's loop_record_t."""
    def __init__(self):
        self.lb = self.ub = self.incr = self.chunksz = 0
        self.next_lb = []


def my_init(lb, ub, incr, chunksz, nw, lr):
    lr.lb, lr.ub, lr.incr, lr.chunksz = lb, ub, incr, chunksz
    lr.next_lb = [lb + tid * chunksz * incr for tid in range(nw)]
    lr.nw = nw


def my_next(lower: Ref, upper: Ref, step: Ref, lr):
    tid = omp_get_thread_num()
    if lr.next_lb[tid] >= lr.ub:
        return 0
    lower.set(lr.next_lb[tid])
    upper.set(min(lr.next_lb[tid] + lr.chunksz * lr.incr, lr.ub))
    step.set(lr.incr)
    lr.next_lb[tid] += lr.nw * lr.chunksz * lr.incr
    return 1


def my_fini(lr):
    lr.next_lb = []


@pytest.fixture()
def declared_mystatic():
    if "mystatic" not in declare.registered_schedules():
        declare_schedule(
            "mystatic", arguments=1,
            init=call(my_init, OMP_LB, OMP_UB, OMP_INCR, OMP_CHUNKSZ,
                      OMP_NUM_WORKERS, ARG(0)),
            next=call(my_next, OMP_LB_CHUNK, OMP_UB_CHUNK,
                      declare.OMP_CHUNK_INCR, ARG(0)),
            fini=call(my_fini, ARG(0)))
    return "mystatic"


# ------------------------------------------------- lambda-style (paper §4.1)
@pytest.fixture()
def lambda_mystatic():
    name = "mystatic_lambda"
    if name not in ls.registered_templates():

        def init():
            ptr = ls.OMP_UDS_user_ptr()
            c = ls.OMP_UDS_chunksize()
            ptr["next_lb"] = [ls.OMP_UDS_loop_start() + t * c
                              for t in range(ls.OMP_UDS_num_workers())]

        def dequeue():
            ptr = ls.OMP_UDS_user_ptr()
            tid = ls.omp_get_thread_num()
            if ptr["next_lb"][tid] >= ls.OMP_UDS_loop_end():
                return 0                      # paper: "return 0"
            c = ls.OMP_UDS_chunksize()
            ls.OMP_UDS_loop_chunk_start(ptr["next_lb"][tid])
            ls.OMP_UDS_loop_chunk_end(
                min(ptr["next_lb"][tid] + c, ls.OMP_UDS_loop_end()))
            ls.OMP_UDS_loop_chunk_step(ls.OMP_UDS_loop_step())
            ptr["next_lb"][tid] += ls.OMP_UDS_num_workers() * c
            return 1

        def finalize():
            ls.OMP_UDS_user_ptr().pop("next_lb", None)

        ls.schedule_template(name, init=init, dequeue=dequeue,
                             finalize=finalize)
    return name


# ------------------------------------------------------------------- claims
def test_fig2_lambda_equals_declare_equals_builtin(declared_mystatic,
                                                   lambda_mystatic):
    lr = LoopRecord()
    dec = plan_of(use_schedule(declared_mystatic, lr))
    lam = plan_of(ls.UDS(template=lambda_mystatic, chunk=8, uds_data={}))
    builtin = plan_of(StaticChunk(chunk=8))
    assert dec.chunks == builtin.chunks
    assert lam.chunks == builtin.chunks


def test_six_op_reduction_is_lossless():
    """three_op_from_six(GSS-as-six-ops) == GSS via its own reduced API."""
    six = GuidedSS()
    reduced = three_op_from_six(GuidedSS())
    assert plan_of(reduced).chunks == plan_of(six).chunks


def test_template_partial_override(lambda_mystatic):
    """Paper §4.1: 'overwrite specific elements of an existing UDS template'."""
    calls = []

    def noisy_finalize():
        calls.append("fini")

    sched = ls.UDS(template=lambda_mystatic, chunk=8, uds_data={},
                   finalize=noisy_finalize)
    plan_of(sched)
    assert calls == ["fini"]


def test_monotonic_violation_detected():
    state = {"emitted": False}

    def dequeue():
        if state["emitted"]:
            ls.OMP_UDS_loop_chunk_start(0)   # goes backwards!
            ls.OMP_UDS_loop_chunk_end(4)
            return 1
        state["emitted"] = True
        ls.OMP_UDS_loop_chunk_start(8)
        ls.OMP_UDS_loop_chunk_end(16)
        return 1

    sched = ls.UDS(dequeue=dequeue, monotonic=True)
    loop = LoopSpec(lb=0, ub=32, num_workers=1)
    stream = get_engine().open_stream(sched, SchedulerContext(loop=loop))
    stream.next(0)
    with pytest.raises(RuntimeError, match="monotonic"):
        stream.next(0)


def test_declare_argument_count_enforced(declared_mystatic):
    with pytest.raises(TypeError):
        use_schedule(declared_mystatic)          # missing omp_arg0


def test_inline_uds_without_template():
    done = {"n": 0}

    def dequeue():
        if done["n"] >= 2:
            ls.OMP_UDS_loop_dequeue_done()
            return None
        ls.OMP_UDS_loop_chunk_start(done["n"] * 5)
        ls.OMP_UDS_loop_chunk_end(min((done["n"] + 1) * 5, 10))
        done["n"] += 1
        return 1

    plan = plan_waves(ls.UDS(dequeue=dequeue),
                      LoopSpec(lb=0, ub=10, num_workers=1))
    assert [c.size for c in plan.chunks] == [5, 5]
