"""The unified ScheduleSpec API: parsing, registry, resolution, caching."""

import numpy as np
import pytest

from repro.core import (LoopSpec, ScheduleSpec, make_scheduler,
                        parse_schedule, plan_schedule, register_schedule,
                        registered_names, resolve, simulate_loop)
from repro.core import declare, lambda_style as ls
from repro.core.engine import PlanEngine, scheduler_plan_key
from repro.core.schedulers import (AWF, FAC2, GuidedSS, SelfScheduling,
                                   StaticChunk, Taper, WeightedFactoring)
from repro.core.spec import RUNTIME_ENV_VAR, describe, unregister_schedule


# =========================================================================
# parsing
# =========================================================================
@pytest.mark.parametrize("clause,kind,chunk", [
    ("static", "static", None),
    ("guided,4", "guided", 4),
    ("dynamic, 8", "dynamic", 8),
    ("fac2", "fac2", None),
    ("uds:mystatic", "uds:mystatic", None),
    ("uds:mytemplate,16", "uds:mytemplate", 16),
])
def test_parse_kind_chunk(clause, kind, chunk):
    spec = parse_schedule(clause)
    assert spec.kind == kind
    assert spec.chunk == chunk


def test_parse_params_and_kwargs():
    spec = parse_schedule("uds:mystatic(2,3)")
    assert spec.params == (2, 3) and spec.is_uds and spec.name == "mystatic"
    spec = parse_schedule("taper(mu=1.0,sigma=0.5),8")
    assert spec.kwargs_dict() == {"mu": 1.0, "sigma": 0.5}
    assert spec.chunk == 8
    spec = parse_schedule("wf2(weights=2:1:0.5)")
    assert spec.weights == (2.0, 1.0, 0.5)


@pytest.mark.parametrize("clause", [
    "guided,4",
    "fac2",
    "uds:mystatic(2,3)",
    "taper(mu=1.0,sigma=0.5),8",
    "wf2(weights=2:1:0.5)",
    "awf(variant=B)",
    "rand(seed=7),2",
    "uds:tmpl,16",
])
def test_parse_str_roundtrip(clause):
    spec = parse_schedule(clause)
    assert parse_schedule(str(spec)) == spec
    # and re-rendering is a fixed point
    assert str(parse_schedule(str(spec))) == str(spec)


@pytest.mark.parametrize("bad", [
    "",                      # empty
    "guided,0",              # chunk must be >= 1
    "guided,-3",             # negative chunk
    "guided,x",              # non-integer chunk
    "guided,4.5",            # non-integer chunk
    "taper(mu=1.0",          # unbalanced paren
    "wf2(weights=)",         # empty weights
    "wf2(weights=a:b)",      # non-numeric weights
    "wf2(weights=2:-1)",     # non-positive weight
    "runtime,4",             # runtime takes no parameters
    "taper(mu=1.0,2)",       # positional after named
    "(4)",                   # no kind
    "uds:f(g(1,2),3)",       # nested parens: the grammar has no nesting
    "wf2(weights=1:2,weights=3:4)",   # duplicate weights
    "taper(mu=1,mu=2)",      # duplicate named parameter
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_schedule(bad)


def test_spec_is_frozen_and_hashable():
    a = parse_schedule("guided,4")
    b = parse_schedule("guided,4")
    assert a == b and hash(a) == hash(b)
    assert a != parse_schedule("guided,8")
    with pytest.raises(Exception):
        a.chunk = 2
    assert ScheduleSpec.make("guided", chunk=4) == a


def test_spec_make_weights_mapping():
    spec = ScheduleSpec.make("wf2", weights={0: 4, 2: 2})
    assert spec.weights == (4.0, 1.0, 2.0)   # gaps fill with weight 1.0


def test_spec_rejects_clause_unsafe_string_values():
    # values that could not survive parse(str(spec)) are rejected upfront
    for bad in ("a,b", "a(b", "a b", "k=v"):
        with pytest.raises(ValueError):
            ScheduleSpec.make("guided", label=bad)
    spec = ScheduleSpec.make("awf", variant="B")       # safe token: fine
    assert parse_schedule(str(spec)) == spec
    # ':' is a safe token char (auto candidate lists) and round-trips
    spec = ScheduleSpec.make("auto", candidates="guided:fac2:awf")
    assert parse_schedule(str(spec)) == spec


def test_chunk_param_mapping_lives_on_the_class():
    r = resolve("rand(seed=7),2")
    assert r.min_chunk == 2 and r.seed == 7
    # awf_* variant lambdas take no chunksize: clause form rejected
    with pytest.raises(ValueError):
        resolve("awf_b,4")


# =========================================================================
# resolution
# =========================================================================
def test_resolve_builtin_forms():
    assert isinstance(resolve("guided,4"), GuidedSS)
    assert resolve("guided,4").min_chunk == 4
    assert isinstance(resolve("dynamic"), SelfScheduling)
    assert isinstance(resolve(parse_schedule("fac2")), FAC2)
    t = resolve("taper(mu=1.0,sigma=0.5),8")
    assert isinstance(t, Taper) and t.min_chunk == 8
    w = resolve("wf2(weights=2:1:1)")
    assert isinstance(w, WeightedFactoring)
    assert w.weights == {0: 2.0, 1: 1.0, 2: 1.0}
    a = resolve("awf(variant=B)")
    assert isinstance(a, AWF) and a.variant == "B"


def test_resolve_instance_and_callable():
    inst = GuidedSS(chunk=2)
    assert resolve(inst) is inst
    made = resolve(lambda: StaticChunk(chunk=3))
    assert isinstance(made, StaticChunk) and made.chunk == 3
    with pytest.raises(TypeError):
        resolve(inst, chunk=5)       # overrides need a spec, not an instance
    with pytest.raises(TypeError):
        resolve(lambda: StaticChunk(chunk=3), chunk=5)   # ... nor a factory
    with pytest.raises(TypeError):
        resolve(12345)


def test_resolve_overrides_merge():
    s = resolve("guided", chunk=4)
    assert s.min_chunk == 4
    assert s._spec == parse_schedule("guided,4")


def test_resolve_rejects_chunk_where_unsupported():
    with pytest.raises(ValueError):
        resolve("fac2,4")            # factoring has no chunksize parameter


def test_unknown_name_lists_all_registrations():
    if "spec_test_tmpl" not in ls.registered_templates():
        ls.schedule_template("spec_test_tmpl",
                             dequeue=lambda: ls.OMP_UDS_loop_dequeue_done())
    with pytest.raises(KeyError) as ei:
        resolve("definitely_not_registered")
    msg = str(ei.value)
    assert "guided" in msg and "fac2" in msg          # builtins listed
    assert "spec_test_tmpl" in msg                    # UDS registrations too
    with pytest.raises(KeyError) as ei:
        make_scheduler("definitely_not_registered")   # shim shares the error
    assert "spec_test_tmpl" in str(ei.value)


def test_uds_namespace_excludes_builtins():
    with pytest.raises(KeyError):
        resolve("uds:guided")


def test_runtime_env_var_resolution(monkeypatch):
    monkeypatch.setenv(RUNTIME_ENV_VAR, "guided,4")
    s = resolve("runtime")
    assert isinstance(s, GuidedSS) and s.min_chunk == 4
    assert s._spec == parse_schedule("guided,4")
    monkeypatch.setenv(RUNTIME_ENV_VAR, "runtime")
    with pytest.raises(ValueError):
        resolve("runtime")           # must late-bind to a concrete clause
    monkeypatch.delenv(RUNTIME_ENV_VAR)
    assert resolve("runtime") is not None   # documented default applies


def test_runtime_spec_rejects_parameters():
    with pytest.raises(ValueError):
        parse_schedule("runtime,4")


def test_describe():
    assert describe("guided, 4") == "guided,4"
    assert describe(GuidedSS(chunk=2)) == "guided"


def test_builtin_shadow_rejection_leaves_no_half_registration():
    # a declaration that shadows a builtin must fail atomically: neither
    # the declare registry nor the template registry may keep the name
    with pytest.raises(ValueError):
        declare.declare_schedule(
            "guided", arguments=0,
            next=declare.call(lambda lo, hi, st: 0, declare.OMP_LB_CHUNK,
                              declare.OMP_UB_CHUNK, declare.OMP_CHUNK_INCR))
    assert "guided" not in declare.registered_schedules()
    with pytest.raises(KeyError):
        declare.use_schedule("guided")
    with pytest.raises(ValueError):
        ls.schedule_template("guided",
                             dequeue=lambda: ls.OMP_UDS_loop_dequeue_done())
    assert "guided" not in ls.registered_templates()
    assert isinstance(resolve("guided"), GuidedSS)     # builtin untouched


def test_mutated_resolved_scheduler_misses_stale_plan():
    eng = PlanEngine()
    loop = LoopSpec(0, 1024, num_workers=4, loop_id="spec_mutate")
    s = resolve("guided,4")
    p1 = eng.plan(s, loop)
    s.min_chunk = 8                       # off-API, but must not corrupt
    p2 = eng.plan(s, loop)
    assert p2 is not p1
    assert max(c.size for c in p2.chunks[-2:]) <= 8
    assert min(c.size for c in p2.chunks[:-1]) >= 8


def test_register_schedule_decorator_and_conflicts():
    @register_schedule("spec_test_custom", chunk_param="chunk")
    def _factory(chunk=5):
        return StaticChunk(chunk=chunk)

    try:
        s = resolve("spec_test_custom,7")
        assert isinstance(s, StaticChunk) and s.chunk == 7
        assert "spec_test_custom" in registered_names(source="user")
        with pytest.raises(ValueError):
            register_schedule("spec_test_custom")(_factory)   # duplicate
        with pytest.raises(ValueError):
            register_schedule("guided")(_factory)             # builtin clash
        with pytest.raises(ValueError):
            # replace=True must not cross sources (builtin shadowing)
            register_schedule("guided", replace=True)(_factory)
        assert isinstance(resolve("guided"), GuidedSS)
    finally:
        unregister_schedule("spec_test_custom")


def test_make_scheduler_shim_equivalence():
    a = make_scheduler("guided", chunk=4)
    b = resolve("guided,4")
    assert type(a) is type(b) and a.min_chunk == b.min_chunk
    assert a._spec == b._spec
    w = make_scheduler("wf2", weights={0: 4, 1: 1})
    assert isinstance(w, WeightedFactoring)


def test_make_scheduler_shim_validates_like_resolve():
    # spec validation is not silently bypassed by the fallback path
    with pytest.raises(ValueError):
        make_scheduler("dynamic", chunk=0)
    with pytest.raises(ValueError):
        make_scheduler("dynamic", chunk=-5)


def test_declare_cannot_shadow_user_registration():
    @register_schedule("spec_user_owned")
    def _factory():
        return StaticChunk(chunk=2)

    try:
        with pytest.raises(ValueError):
            declare.declare_schedule(
                "spec_user_owned", arguments=0,
                next=declare.call(lambda lo, hi, st: 0,
                                  declare.OMP_LB_CHUNK,
                                  declare.OMP_UB_CHUNK,
                                  declare.OMP_CHUNK_INCR))
        assert "spec_user_owned" not in declare.registered_schedules()
        with pytest.raises(ValueError):
            ls.schedule_template(
                "spec_user_owned",
                dequeue=lambda: ls.OMP_UDS_loop_dequeue_done())
        assert "spec_user_owned" not in ls.registered_templates()
        # the user's registration is untouched
        assert isinstance(resolve("uds:spec_user_owned"), StaticChunk)
    finally:
        unregister_schedule("spec_user_owned")


# =========================================================================
# plan-cache identity
# =========================================================================
def test_plan_key_equal_for_equivalent_specs():
    k1 = scheduler_plan_key(resolve("guided,4"))
    k2 = scheduler_plan_key(resolve(ScheduleSpec.make("guided", chunk=4)))
    assert k1 == k2 and k1 is not None


def test_plan_cache_hit_across_equivalent_specs():
    eng = PlanEngine()
    loop = LoopSpec(0, 4096, num_workers=8, loop_id="spec_cache")
    p1 = eng.plan(resolve("guided,4"), loop)
    # structurally-equal spec built independently, different instance
    p2 = eng.plan(resolve(ScheduleSpec.make("guided", chunk=4)), loop)
    assert p2 is p1
    assert eng.cache_info().hits == 1
    # the deprecated shim shares the same cache entries
    p3 = eng.plan(make_scheduler("guided", chunk=4), loop)
    assert p3 is p1
    # a different chunk is a different spec -> miss
    eng.plan(resolve("guided,8"), loop)
    assert eng.cache_info().misses == 2


def test_plan_cache_distinguishes_param_specs():
    eng = PlanEngine()
    loop = LoopSpec(0, 2048, num_workers=4, loop_id="spec_cache2")
    eng.plan(resolve("taper(mu=1.0,sigma=0.5)"), loop)
    p = eng.plan(resolve("taper(sigma=0.5,mu=1.0)"), loop)  # order-insensitive
    assert eng.cache_info().hits == 1
    eng.plan(resolve("taper(mu=1.0,sigma=0.9)"), loop)
    assert eng.cache_info().misses == 2
    assert p.coverage_ok()


# =========================================================================
# UDS registries absorbed: by-name through substrates
# =========================================================================
def _declare_quarters():
    """Fig.-2-style declare-style schedule with a conjurable loop record."""
    class Rec:
        next = 0
        ub = 0
        chunk = 1

    def init(lb, ub, inc, chunk, rec):
        rec.next, rec.ub = lb, ub
        rec.chunk = max(chunk, 1)

    def nxt(lower, upper, step, rec):
        if rec.next >= rec.ub:
            return 0
        lower.set(rec.next)
        upper.set(min(rec.next + rec.chunk, rec.ub))
        rec.next = upper.value
        return 1

    if "spec_quarters" not in declare.registered_schedules():
        declare.declare_schedule(
            "spec_quarters", arguments=1,
            init=declare.call(init, declare.OMP_LB, declare.OMP_UB,
                              declare.OMP_INCR, declare.OMP_CHUNKSZ,
                              declare.ARG(0)),
            next=declare.call(nxt, declare.OMP_LB_CHUNK,
                              declare.OMP_UB_CHUNK,
                              declare.OMP_CHUNK_INCR, declare.ARG(0)),
            make_args=lambda: (Rec(),))


def test_declare_style_resolved_by_name():
    _declare_quarters()
    sched = resolve("uds:spec_quarters,8")
    plan = plan_schedule(sched, 100, 4)
    sizes = [c.size for c in plan.chunks]
    assert sizes == [8] * 12 + [4]
    # by name through a host loop
    res = simulate_loop(resolve("uds:spec_quarters,8"),
                        LoopSpec(0, 64, num_workers=4), np.ones(64))
    assert res.makespan > 0


def test_declare_style_by_name_through_packing_substrate():
    _declare_quarters()
    from repro.sched import pack_with_scheduler
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 50, size=int(n)).astype(np.int32)
            for n in rng.integers(8, 120, 32)]
    packed = pack_with_scheduler("uds:spec_quarters,2", docs, 4, 512)
    assert 0.0 < packed.fill_fraction <= 1.0


def test_lambda_style_by_name_through_packing_substrate():
    def t_init():
        ls.OMP_UDS_user_ptr()["next"] = ls.OMP_UDS_loop_start()

    def t_dequeue():
        ptr = ls.OMP_UDS_user_ptr()
        if ptr["next"] >= ls.OMP_UDS_loop_end():
            return 0
        c = ls.OMP_UDS_chunksize()
        ls.OMP_UDS_loop_chunk_start(ptr["next"])
        ls.OMP_UDS_loop_chunk_end(min(ptr["next"] + c,
                                      ls.OMP_UDS_loop_end()))
        ptr["next"] += c
        return 1

    if "spec_ltmpl" not in ls.registered_templates():
        ls.schedule_template("spec_ltmpl", init=t_init, dequeue=t_dequeue,
                             uds_data={"next": 0})
    from repro.sched import pack_with_scheduler
    rng = np.random.default_rng(1)
    docs = [rng.integers(1, 50, size=int(n)).astype(np.int32)
            for n in rng.integers(8, 120, 32)]
    packed = pack_with_scheduler("uds:spec_ltmpl,4", docs, 4, 512)
    assert 0.0 < packed.fill_fraction <= 1.0
    # the template instance honors the clause chunksize
    uds = resolve("uds:spec_ltmpl,4")
    assert uds.chunk == 4


def test_uds_by_name_usable_as_train_pack_scheduler():
    """The acceptance path: a declare-style schedule selected by clause
    string drives the training-batch packing substrate (the same resolve
    call ``launch/train.py --scheduler`` goes through)."""
    _declare_quarters()
    from repro.sched import pack_with_scheduler
    sched = resolve("uds:spec_quarters")     # what TrainLoop.__init__ does
    rng = np.random.default_rng(2)
    docs = [rng.integers(1, 50, size=int(n)).astype(np.int32)
            for n in rng.integers(8, 120, 24)]
    for _ in range(2):                       # reusable across steps
        packed = pack_with_scheduler(sched, docs, 4, 512)
        assert 0.0 < packed.fill_fraction <= 1.0


def test_uds_schedules_are_not_plan_cached():
    _declare_quarters()
    assert scheduler_plan_key(resolve("uds:spec_quarters")) is None


def test_resolve_scheduler_class_is_instantiated():
    s = resolve(StaticChunk)               # zero-arg class as factory
    assert isinstance(s, StaticChunk) and not isinstance(s, type)
    plan = plan_schedule(s, 64, 4)
    assert plan.coverage_ok()


def test_template_by_name_gets_fresh_state_per_resolve():
    # no init hook: the cursor lives purely in uds_data, so a shared dict
    # across resolutions would leave the second loop with nothing to do
    def dequeue():
        ptr = ls.OMP_UDS_user_ptr()
        if ptr["next"] >= ls.OMP_UDS_loop_end():
            return 0
        c = ls.OMP_UDS_chunksize()
        ls.OMP_UDS_loop_chunk_start(ptr["next"])
        ls.OMP_UDS_loop_chunk_end(min(ptr["next"] + c,
                                      ls.OMP_UDS_loop_end()))
        ptr["next"] += c
        return 1

    if "spec_noinit" not in ls.registered_templates():
        ls.schedule_template("spec_noinit", dequeue=dequeue,
                             uds_data={"next": 0})
    for _ in range(2):       # second resolution must start from scratch
        plan = plan_schedule(resolve("uds:spec_noinit,4"), 32, 2)
        assert plan.coverage_ok()


def test_template_rejects_positional_clause_params():
    if "spec_noargs" not in ls.registered_templates():
        ls.schedule_template("spec_noargs",
                             dequeue=lambda: ls.OMP_UDS_loop_dequeue_done())
    with pytest.raises(ValueError):
        resolve("uds:spec_noargs(0)")      # chunk must come via ',chunk'
    with pytest.raises(ValueError):
        resolve("uds:spec_noargs,0")       # and is validated there


def test_failed_uds_module_import_is_retried(monkeypatch):
    from repro.core import spec as spec_mod
    monkeypatch.setattr(spec_mod, "_uds_modules_state", "unloaded")
    monkeypatch.setenv(spec_mod.UDS_MODULES_ENV_VAR, "no_such_module_xyz")
    with pytest.raises(ImportError):
        registered_names()
    # the flag was not committed: the configured module is retried (and
    # the real error keeps surfacing) instead of being silently skipped
    with pytest.raises(ImportError):
        resolve("uds:whatever")


# =========================================================================
# substrates that previously hardcoded WeightedFactoring
# =========================================================================
def test_straggler_accepts_scheduler_spec():
    from repro.sched import StragglerMitigator
    default = StragglerMitigator(num_hosts=4)
    alt = StragglerMitigator(num_hosts=4, scheduler="fac2")
    for m in (default, alt):
        for _ in range(3):
            m.observe_step({0: 1.0, 1: 1.0, 2: 1.5, 3: 1.0})
    s_def = default.token_shares(1000)
    s_alt = alt.token_shares(1000)
    assert s_def.sum() == 1000 and s_alt.sum() == 1000
    # default (wf2) respects the AWF weights: the slow host gets less
    assert s_def[2] < s_def[0]
    # fac2 ignores weights: near-equal shares
    assert abs(int(s_alt[2]) - int(s_alt[0])) <= 1


def test_capacity_planner_accepts_scheduler_spec():
    from repro.configs import get_smoke_config
    from repro.sched import CapacityPlanner
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    for spec in ("wf2", "fac2"):
        pl = CapacityPlanner(cfg, 64, scheduler=spec)
        E = cfg.num_experts
        load = np.ones(E)
        load[0] *= 4.0
        load /= load.sum()
        pl.observe(np.tile(load, (2, 1)))
        cap = pl.plan()
        assert cap.shape == (E,) and (cap >= 1).all()
    # the default (wf2) gives the hot expert more slots
    pl = CapacityPlanner(cfg, 64)
    pl.observe(np.tile(load, (2, 1)))
    cap = pl.plan()
    assert cap[0] > cap[1]
