"""PlanEngine: vectorized-vs-generic identity, plan caching + invalidation,
plan views, and plan replay."""

import numpy as np
import pytest

from repro.core import (LoopHistory, LoopSpec, SCHEDULER_FACTORIES,
                        execute_plan, make_scheduler, plan_schedule,
                        simulate_loop)
from repro.core.engine import PlanEngine, has_compiler, scheduler_plan_key
from repro.core.schedulers import AWF, GuidedSS, WeightedFactoring

SHAPES = [(0, 3), (1, 1), (7, 3), (100, 8), (1000, 16), (37, 64), (4096, 5)]


# ---------------------------------------------------- compilation invariant
@pytest.mark.parametrize("name", sorted(SCHEDULER_FACTORIES))
def test_vectorized_identical_to_generic(name):
    """The tentpole invariant: for every scheduler in the registry with a
    closed-form compiler, the vectorized chunk table is chunk-for-chunk
    identical (starts, sizes, workers, waves) to the generic three-op
    state-machine driver."""
    eng = PlanEngine()
    sched = make_scheduler(name)
    if not has_compiler(sched):
        pytest.skip(f"{name} has no closed form (adaptive/stealing)")
    for n, p in SHAPES:
        loop = LoopSpec(lb=0, ub=n, num_workers=p, loop_id=f"{name}/{n}/{p}")
        vec = eng.plan(make_scheduler(name), loop, mode="vectorized")
        gen = eng.plan(make_scheduler(name), loop, mode="generic")
        assert vec.provenance.source == "vectorized"
        assert gen.provenance.source == "generic"
        assert vec.identical(gen), (name, n, p)
        assert np.array_equal(vec.wave_ids, gen.wave_ids), (name, n, p)


def test_validate_mode_cross_checks_every_plan():
    eng = PlanEngine(validate=True)
    for name in ("guided", "fac2", "tss", "rand", "wf2", "taper"):
        plan = eng.plan(make_scheduler(name),
                        LoopSpec(0, 777, num_workers=6, loop_id=name))
        assert plan.coverage_ok()


def test_generic_fallback_for_adaptive_and_stealing():
    eng = PlanEngine()
    for name in ("awf", "awf_c", "af", "static_steal"):
        plan = eng.plan(make_scheduler(name),
                        LoopSpec(0, 300, num_workers=4, loop_id=name))
        assert plan.provenance.source == "generic"
        assert plan.coverage_ok()


# ------------------------------------------------------------------- cache
def test_cache_hit_returns_same_plan_object():
    eng = PlanEngine()
    loop = LoopSpec(0, 1000, num_workers=8, loop_id="hit")
    p1 = eng.plan(make_scheduler("fac2"), loop)
    p2 = eng.plan(make_scheduler("fac2"), loop)   # fresh instance, same config
    assert p1 is p2
    assert eng.cache_info().hits == 1 and eng.cache_info().misses == 1


def test_cache_keys_distinguish_scheduler_params_and_loops():
    eng = PlanEngine()
    loop = LoopSpec(0, 1000, num_workers=8, loop_id="k")
    eng.plan(make_scheduler("dynamic", chunk=4), loop)
    eng.plan(make_scheduler("dynamic", chunk=8), loop)          # param change
    eng.plan(make_scheduler("dynamic", chunk=4),
             LoopSpec(0, 1000, num_workers=4, loop_id="k"))     # loop change
    assert eng.cache_info().misses == 3
    assert eng.cache_info().hits == 0


def test_cache_invalidated_by_weight_change():
    eng = PlanEngine()
    loop = LoopSpec(0, 4000, num_workers=4, loop_id="w")
    p1 = eng.plan(WeightedFactoring(), loop, weights=[2.0, 0.5, 1.0, 0.5])
    p2 = eng.plan(WeightedFactoring(), loop, weights=[2.0, 0.5, 1.0, 0.5])
    p3 = eng.plan(WeightedFactoring(), loop, weights=[1.0, 1.0, 1.0, 1.0])
    assert p1 is p2
    assert p3 is not p1
    assert not np.array_equal(p1.sizes, p3.sizes)


def test_cache_invalidated_by_history_epoch_bump():
    """Adaptive schedulers key on the measurement epoch: recording a new
    invocation of measurements must invalidate the cached plan."""
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 800, num_workers=2, loop_id="aw")
    sched = AWF(variant="timestep")
    p1 = eng.plan(sched, loop, history=hist)
    # a measured invocation: worker 0 twice as fast as worker 1
    simulate_loop(AWF(variant="timestep"), loop, np.ones(800),
                  speeds=[2.0, 1.0], history=hist)
    p2 = eng.plan(sched, loop, history=hist)
    assert p2 is not p1                       # epoch bumped -> replanned
    w0_before = int(p1.worker_iters()[0])
    w0_after = int(p2.worker_iters()[0])
    assert w0_after > w0_before               # learned the 2:1 speeds


def test_adaptive_plans_hit_without_new_measurements():
    """Planning itself (elapsed=None records) must not invalidate an
    adaptive plan: only *measured* invocations bump the cache epoch."""
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 600, num_workers=3, loop_id="ac")
    p1 = eng.plan(make_scheduler("awf_c"), loop, history=hist)
    p2 = eng.plan(make_scheduler("awf_c"), loop, history=hist)
    p3 = eng.plan(make_scheduler("awf_c"), loop, history=hist)
    assert p1 is p2 is p3
    assert eng.cache_info().hits == 2 and eng.cache_info().misses == 1


def test_every_plan_path_opens_an_invocation():
    """Generic, vectorized, and cache-hit plans all mark an invocation
    boundary, so post-execution records keep per-step granularity."""
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 100, num_workers=4, loop_id="inv")
    eng.plan(make_scheduler("guided"), loop, history=hist)   # vectorized
    eng.plan(make_scheduler("guided"), loop, history=hist)   # cache hit
    eng.plan(make_scheduler("static_steal"), loop, history=hist)  # generic
    assert hist.num_invocations("inv") == 3
    assert hist.measured_invocations("inv") == 0


def test_adaptive_plans_not_shared_across_distinct_histories():
    """Two histories with the same loop_id and equal measured-epoch counts
    but opposite learned speeds must not share cache entries."""
    eng = PlanEngine()
    loop = LoopSpec(0, 800, num_workers=2, loop_id="aw")
    h1, h2 = LoopHistory(), LoopHistory()
    simulate_loop(AWF(variant="timestep"), loop, np.ones(800),
                  speeds=[4.0, 1.0], history=h1)
    simulate_loop(AWF(variant="timestep"), loop, np.ones(800),
                  speeds=[1.0, 4.0], history=h2)
    p1 = eng.plan(AWF(variant="timestep"), loop, history=h1)
    p2 = eng.plan(AWF(variant="timestep"), loop, history=h2)
    assert p1 is not p2
    assert p1.worker_iters()[0] > p1.worker_iters()[1]   # h1: worker 0 fast
    assert p2.worker_iters()[0] < p2.worker_iters()[1]   # h2: worker 1 fast


def test_non_adaptive_plans_hit_across_history_epochs():
    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, 500, num_workers=4, loop_id="g")
    p1 = eng.plan(GuidedSS(), loop, history=hist)
    hist.open_invocation("g")                 # epoch bump is irrelevant here
    p2 = eng.plan(GuidedSS(), loop, history=hist)
    assert p1 is p2


def test_unhashable_schedules_are_planned_fresh():
    from repro.core import lambda_style as ls

    calls = []

    def dequeue():
        if calls and calls[-1] == "done":
            return None
        ls.OMP_UDS_loop_chunk_start(0)
        ls.OMP_UDS_loop_chunk_end(10)
        calls.append("done")
        return 1

    eng = PlanEngine()
    sched = ls.UDS(dequeue=dequeue)
    assert scheduler_plan_key(sched) is None
    eng.plan(sched, LoopSpec(0, 10, num_workers=1, loop_id="u"))
    assert eng.cache_info().uncacheable == 1
    assert len(eng) == 0


def test_cache_lru_eviction():
    eng = PlanEngine(cache_size=2)
    for i in range(4):
        eng.plan(make_scheduler("guided"),
                 LoopSpec(0, 100 + i, num_workers=2, loop_id="lru"))
    assert len(eng) == 2
    assert eng.cache_info().evictions == 2


# ------------------------------------------------------------ plan views
def test_plan_views_are_consistent():
    plan = plan_schedule(make_scheduler("fac2"), 1003, 8)
    assert plan.num_chunks == len(plan.chunks)
    assert int(plan.sizes.sum()) == 1003
    assert int(plan.worker_iters().sum()) == 1003
    # waves regroup to the same chunks in dequeue order
    flat = [c for wave in plan.waves for c in wave]
    assert flat == plan.chunks
    tab = plan.padded_worker_table()
    assert tab["starts"].shape == tab["sizes"].shape
    assert tab["sizes"].sum() == 1003
    order = plan.tile_order()
    assert sorted(order.tolist()) == list(range(1003))
    # worker-major expansion: a valid permutation, each worker's tiles
    # contiguous, and (for a multi-worker central-queue plan) non-identity
    worder = plan.tile_order(order="worker")
    assert sorted(worder.tolist()) == list(range(1003))
    assert worder.tolist() != list(range(1003))
    per = plan.per_worker()
    expect = [i for w in range(8) for c in per[w]
              for i in range(c.start, c.stop)]
    assert worder.tolist() == expect


def test_plan_arrays_are_frozen():
    plan = plan_schedule(make_scheduler("guided"), 100, 4)
    with pytest.raises(ValueError):
        plan.sizes[0] = 99


# ------------------------------------------------------------ plan replay
def test_execute_plan_conserves_work_and_matches_static_makespan():
    rng = np.random.default_rng(0)
    n, p = 1000, 8
    costs = rng.uniform(0.1, 2.0, n)
    plan = plan_schedule(make_scheduler("static_block"), n, p)
    res = execute_plan(plan, costs, overhead=1e-4)
    assert np.isclose(res.total_work, costs.sum())
    # static assignment is identical under replay and under simulation
    sim = simulate_loop(make_scheduler("static_block"),
                        LoopSpec(0, n, num_workers=p), costs, overhead=1e-4)
    assert np.isclose(res.makespan, sim.makespan)
    assert sorted(c.size for c in res.chunks) == sorted(
        c.size for c in sim.chunks)


def test_execute_plan_respects_speeds():
    plan = plan_schedule(make_scheduler("static_block"), 100, 2)
    res = execute_plan(plan, np.ones(100), speeds=[2.0, 1.0])
    assert res.worker_time[0] == pytest.approx(res.worker_time[1] / 2)
