"""Substrate tests: optimizers, schedules, packing, microbatching, MoE
capacity planning, checkpointing, fault tolerance, elasticity."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, make_optimizer, wsd_schedule
from repro.optim.specs import opt_state_specs


# ---------------------------------------------------------------- optimizers
def _fit_quadratic(opt_name, steps=300, **kw):
    init_fn, update_fn = make_optimizer(
        opt_name, lambda s: jnp.asarray(0.05), **kw)
    target = jnp.asarray([[1.5, -2.0], [0.5, 3.0]])
    params = {"w": jnp.zeros((2, 2))}
    state = init_fn(params)
    for i in range(steps):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        updates, state, _ = update_fn(grads, state, params,
                                      jnp.asarray(i, jnp.int32))
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    return float(jnp.abs(params["w"] - target).max())


def test_adamw_converges():
    assert _fit_quadratic("adamw", weight_decay=0.0) < 0.05


def test_adafactor_converges():
    assert _fit_quadratic("adafactor") < 0.1


def test_adafactor_state_is_factored():
    init_fn, _ = adafactor(lambda s: 1e-3)
    params = {"big": jnp.zeros((64, 128)), "vec": jnp.zeros((64,)),
              "stack": jnp.zeros((4, 32, 16))}
    state = init_fn(params)
    assert set(state["v"]["big"]) == {"vr", "vc"}
    assert state["v"]["big"]["vr"].shape == (64,)
    assert state["v"]["big"]["vc"].shape == (128,)
    assert set(state["v"]["vec"]) == {"v"}          # 1-D: unfactored
    assert state["v"]["stack"]["vr"].shape == (4, 32)
    assert state["v"]["stack"]["vc"].shape == (4, 16)


def test_opt_state_specs_follow_params():
    params = {"w": jnp.zeros((8, 16))}
    specs = {"w": ("embed", "mlp")}
    s = opt_state_specs("adamw", params, specs)
    assert s["m"]["w"] == ("embed", "mlp")
    s = opt_state_specs("adafactor", params, specs)
    assert s["v"]["w"] == {"vr": ("embed",), "vc": ("mlp",)}


def test_wsd_schedule_shape():
    fn = wsd_schedule(1.0, warmup_steps=10, stable_steps=50, decay_steps=20)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0)
    assert float(fn(40)) == pytest.approx(1.0)       # stable plateau
    assert float(fn(80)) < 0.05                       # fully decayed
    assert float(fn(65)) > float(fn(70)) > float(fn(80))


# ------------------------------------------------------------------- packing
def test_uds_packing_beats_first_fit_on_skew():
    from repro.core import make_scheduler
    from repro.data import pack_documents
    from repro.sched import pack_with_scheduler
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 100, size=int(n)).astype(np.int32)
            for n in np.clip(rng.lognormal(5.0, 1.0, 96), 8, 1024)]
    first_fit = pack_documents(docs, batch=8, seq_len=1024)
    uds = pack_with_scheduler(make_scheduler("static_steal", chunk=1),
                              docs, batch=8, seq_len=1024)
    assert uds.fill_fraction >= first_fit.fill_fraction - 0.02
    assert uds.fill_fraction > 0.9


def test_packed_labels_and_segments():
    from repro.data import pack_documents
    docs = [np.arange(1, 9, dtype=np.int32), np.arange(10, 14, dtype=np.int32)]
    pb = pack_documents(docs, batch=1, seq_len=16)
    assert pb.segment_ids[0, 0] == 1 and pb.segment_ids[0, 8] == 2
    # next-token labels within the doc, -100 at doc boundary/padding
    assert pb.labels[0, 0] == 2 and pb.labels[0, 7] == -100
    assert pb.labels[0, 15] == -100


def test_microbatch_permutation_balances_cost():
    from repro.core import make_scheduler
    from repro.sched import plan_microbatch_permutation
    rng = np.random.default_rng(1)
    costs = rng.lognormal(0, 1.0, 32)
    perm = plan_microbatch_permutation(
        make_scheduler("dynamic", chunk=1), costs, 4)
    assert sorted(perm.tolist()) == list(range(32))
    loads = costs[perm].reshape(4, 8).sum(axis=1)
    naive = costs.reshape(4, 8).sum(axis=1)
    assert loads.max() / loads.mean() <= naive.max() / naive.mean() + 1e-9
    assert loads.max() / loads.mean() < 1.15


def test_capacity_planner_tracks_hot_experts():
    from repro.configs import get_config
    from repro.sched import CapacityPlanner
    cfg = get_config("qwen3-moe-235b-a22b")
    pl = CapacityPlanner(cfg, seq_len=4096)
    E = cfg.num_experts
    skew = np.ones(E) / E
    skew[0] *= 8                       # expert 0 is hot
    skew /= skew.sum()
    for _ in range(5):
        pl.observe(np.tile(skew, (4, 1)))
    cap = pl.plan()
    assert cap[0] == pl.C_buf                    # hot expert saturates buffer
    assert cap[0] > cap[1:].mean() * (pl.C_buf / pl.C) * 0.9
    assert cap.max() <= pl.C_buf                 # within the buffer bound
    assert cap.sum() <= pl.C * E * 1.01          # within the slot budget
    # planned capacity reduces expected drops vs uniform
    uniform = np.full(E, pl.C, np.int32)
    assert pl.drop_rate(np.tile(skew, (4, 1)), cap) <= \
        pl.drop_rate(np.tile(skew, (4, 1)), uniform) + 1e-9


def test_straggler_detection_and_weights():
    from repro.sched import StragglerMitigator
    m = StragglerMitigator(num_hosts=4)
    for _ in range(8):
        m.observe_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.6})   # host 3 slow
    assert m.stragglers() == [3]
    w = m.weights()
    assert w[3] < w[0]
    shares = m.token_shares(1000)
    assert shares.sum() == 1000 and shares[3] < shares[0]


def test_straggler_cold_start_exact_uniform_shares():
    """Before any observe_step, weights()/token_shares() are EXACTLY
    uniform — no NaN/div-by-zero on the empty history, and no chunk-shaped
    approximation of uniformity from the share scheduler (the multi-host
    equivalence guarantee depends on the exact partition)."""
    from repro.sched import StragglerMitigator
    m = StragglerMitigator(num_hosts=5)
    w = m.weights()
    assert np.array_equal(w, np.ones(5))
    shares = m.token_shares(1003)
    assert shares.tolist() == [201, 201, 201, 200, 200]
    assert m.token_shares(0).tolist() == [0] * 5
    # degenerate all-zero measurements stay finite and uniform
    m.observe_step({h: 0.0 for h in range(5)})
    w = m.weights()
    assert np.isfinite(w).all() and np.array_equal(w, np.ones(5))
    assert m.token_shares(10).sum() == 10
    # equal measured RATES (times proportional to tokens — the train
    # loop's attribution under no skew) keep the partition exactly even
    m2 = StragglerMitigator(num_hosts=4)
    for _ in range(3):
        m2.observe_step({h: 0.1 * (7 + h) for h in range(4)},
                        host_tokens={h: 7 + h for h in range(4)})
    assert m2.token_shares(1024).tolist() == [256] * 4


def test_straggler_min_share_floor_is_sum_preserving():
    from repro.sched import StragglerMitigator
    m = StragglerMitigator(num_hosts=4, min_share=0.5)
    for _ in range(6):
        m.observe_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 100.0})
    shares = m.token_shares(1000)
    floor = m.min_share_floor(1000)
    assert floor == 125                       # half the even share
    assert int(shares.sum()) == 1000
    assert (shares >= floor).all()
    assert shares[3] < shares[0]              # still below the fast hosts


# --------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree, extras={"loss": 1.25})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, extras = restore_checkpoint(str(tmp_path), like)
    assert step == 7 and extras["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_and_gc(tmp_path):
    from repro.checkpoint import AsyncCheckpointer, latest_step
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, {"x": jnp.full((4,), s, jnp.float32)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 30
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2                      # gc keeps last 2


def test_restore_reshards_to_new_mesh(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, _, _ = restore_checkpoint(str(tmp_path), tree, shardings)
    assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)


# ------------------------------------------------------------ fault tolerance
def test_supervisor_restarts_from_checkpoint(tmp_path):
    from repro.runtime import FailureInjector, TrainSupervisor

    def init_state():
        return {"w": jnp.zeros((2,)), }

    def step_fn(state, step):
        return {"w": state["w"] + 1.0}, {"loss": float(2.0 / (step + 1))}

    injector = FailureInjector({7: "transient", 13: "transient"})
    sup = TrainSupervisor(step_fn, init_state, str(tmp_path),
                          ckpt_every=5, injector=injector)
    report = sup.run(20)
    assert report.steps_completed == 20
    assert report.restarts == 2
    assert report.restores == [5, 10]          # resumed from committed ckpts
    assert injector.fired == [7, 13]


def test_supervisor_elastic_downsize(tmp_path):
    from repro.runtime import FailureInjector, TrainSupervisor
    events = []

    def init_state():
        return {"w": jnp.zeros(())}

    def step_fn(state, step):
        return {"w": state["w"] + 1}, {"loss": 1.0}

    injector = FailureInjector({3: "device", 4: "device"})
    sup = TrainSupervisor(step_fn, init_state, str(tmp_path), ckpt_every=2,
                          injector=injector, num_hosts=4,
                          on_elastic=lambda n: events.append(n),
                          elastic_after_failures=2)
    report = sup.run(8)
    assert report.steps_completed == 8
    assert events == [2]                       # downsized 4 -> 2 hosts
    assert report.elastic_events and report.elastic_events[0][1] == 2


def test_degraded_mesh_planning():
    from repro.runtime import plan_degraded_mesh
    assert plan_degraded_mesh(256, 16) == (16, 16)
    assert plan_degraded_mesh(240, 16) == (8, 16)    # lost a row -> pow2 data
    assert plan_degraded_mesh(17, 16) == (1, 16)
    with pytest.raises(ValueError):
        plan_degraded_mesh(8, 16)


def test_history_survives_serialization():
    from repro.core import ChunkRecord, LoopHistory
    h = LoopHistory()
    h.record("loop", ChunkRecord(worker=0, start=0, stop=10, elapsed=0.5))
    h.record("loop", ChunkRecord(worker=1, start=10, stop=20, elapsed=1.0))
    h2 = LoopHistory.from_json(h.to_json())
    assert h2.worker_rates("loop") == h.worker_rates("loop")
    # adaptive weights derived from restored history — checkpointable UDS
    assert h2.awf_weights("loop", 2)[0] > h2.awf_weights("loop", 2)[1]
