"""Pallas kernels vs pure-jnp oracles (interpret=True on the CPU host;
TPU is the compile target).  Shape/dtype sweeps via hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import make_scheduler, plan_schedule
from repro.kernels.sched_matmul.ops import (scheduled_matmul,
                                            tile_order_from_plan)
from repro.kernels.sched_matmul.ref import sched_matmul_ref
from repro.kernels.flash_attention.ops import mha
from repro.kernels.linear_scan.ops import ssd, wkv
from repro.kernels.linear_scan.ref import linear_attention_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ sched_matmul
@given(mt=st.integers(1, 4), k=st.sampled_from([64, 128, 192]),
       n=st.sampled_from([128, 256]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_sched_matmul_sweep(mt, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    m = mt * 128
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    order = jnp.asarray(rng.permutation(mt), jnp.int32)
    out = scheduled_matmul(a, b, order, block_k=64, interpret=True)
    ref = sched_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("sched", ["guided", "fac2", "tss"])
def test_sched_matmul_with_uds_plans(sched):
    """Tile orders straight from UDS plans — the integration the kernel
    exists for."""
    m_tiles = 8
    plan = plan_schedule(make_scheduler(sched), m_tiles, 2)
    order = tile_order_from_plan(plan, m_tiles)
    a = jnp.asarray(RNG.normal(size=(m_tiles * 128, 64)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(64, 128)), jnp.float32)
    out = scheduled_matmul(a, b, jnp.asarray(order), block_k=64,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(sched_matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_sched_matmul_padding_path():
    a = jnp.asarray(RNG.normal(size=(200, 96)), jnp.float32)   # non-multiples
    b = jnp.asarray(RNG.normal(size=(96, 130)), jnp.float32)
    out = scheduled_matmul(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a @ b), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------- flash attention
@given(b=st.integers(1, 2), s=st.sampled_from([32, 64, 96, 128]),
       h=st.sampled_from([1, 2, 4]), kv=st.sampled_from([1, 2]),
       d=st.sampled_from([16, 32, 64]), causal=st.booleans(),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=16, deadline=None)
def test_flash_attention_sweep(b, s, h, kv, d, causal, dtype, seed):
    if h % kv:
        kv = 1
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), dtype)
    out = mha(q, k, v, causal=causal, block_q=32, block_kv=32,
              interpret=True)
    ref = mha(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_blockwise():
    """Kernel == model's pure-jnp blockwise path == naive reference."""
    from repro.models.common import blockwise_attention
    q = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    kern = mha(q, k, v, causal=True, block_q=32, block_kv=64, interpret=True)
    blockwise = blockwise_attention(q, k, v, causal=True, block_q=32,
                                    block_kv=64)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(blockwise),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------- linear scan
@given(b=st.integers(1, 2), h=st.integers(1, 3),
       t=st.sampled_from([16, 32, 48, 64]),
       n=st.sampled_from([8, 16]), hd=st.sampled_from([8, 16]),
       chunk=st.sampled_from([8, 16]), seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_ssd_kernel_sweep(b, h, t, n, hd, chunk, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32)
    la = jnp.asarray(-rng.uniform(0.01, 3.0, size=(b, h, t)), jnp.float32)
    y, s = ssd(c, bb, x, la, chunk=chunk, interpret=True)
    yr, sr = linear_attention_ref(c, bb, x, la, inclusive=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=3e-4, atol=3e-4)


@given(b=st.integers(1, 2), h=st.integers(1, 2),
       t=st.sampled_from([16, 32, 48]), n=st.sampled_from([8, 16]),
       chunk=st.sampled_from([8, 16]), seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_wkv_kernel_sweep(b, h, t, n, chunk, seed):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0.01, 5.0, size=(b, h, t, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y, s = wkv(r, k, v, lw, u, chunk=chunk, interpret=True)
    yr, sr = linear_attention_ref(r, k, v, lw, u=u, inclusive=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=3e-4, atol=3e-4)


def test_wkv_strong_decay_no_overflow():
    """The factored GLA form overflows for strong data-dependent decay; the
    safe formulation must not (this is the kernel's raison d'être)."""
    b, h, t, n = 1, 1, 64, 16
    rng = np.random.default_rng(7)
    r = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    lw = jnp.full((b, h, t, n), -7.0, jnp.float32)   # w = e^-7 per step
    u = jnp.zeros((h, n), jnp.float32)
    y, s = wkv(r, k, v, lw, u, chunk=32, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
    yr, _ = linear_attention_ref(r, k, v, lw, u=u, inclusive=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
