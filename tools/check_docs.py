"""CI docs gate: docs/SCHEDULING.md must document every live schedule.

Fails (exit 1) when a name in the unified registry
(``repro.core.spec.registered_names()``) has no row in the guide's
schedule table, or when the table documents a name the registry no
longer carries — the two drift directions of a hand-written table.

Deliberately importable with numpy alone (``repro.core.spec`` pulls in
no jax), so the CI *lint* job can run it without the full toolchain:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
GUIDE = REPO / "docs" / "SCHEDULING.md"

# names the guide documents outside the table by design
NON_REGISTRY_KINDS = {"runtime"}


def documented_names(text: str) -> set:
    """Backticked first-cell names of the guide's schedule table rows."""
    return set(re.findall(r"(?m)^\|\s*`([a-z0-9_]+)`\s*\|", text))


def main() -> int:
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.spec import registered_names

    live = set(registered_names(source="builtin"))
    if not GUIDE.exists():
        print(f"FAIL: {GUIDE} does not exist")
        return 1
    documented = documented_names(GUIDE.read_text())

    missing = sorted(live - documented)
    stale = sorted(documented - live - NON_REGISTRY_KINDS)
    if missing:
        print(f"FAIL: registered schedules missing from {GUIDE.name}'s "
              f"table: {missing}")
    if stale:
        print(f"FAIL: {GUIDE.name} documents unregistered schedules: "
              f"{stale}")
    if missing or stale:
        return 1
    print(f"OK: {len(live)} registered schedules all documented in "
          f"{GUIDE.name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
