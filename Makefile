PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast bench bench-plan deps deps-dev

test:           ## tier-1 verify (full suite, fail-fast)
	$(PYTHON) -m pytest -x -q

test-fast:      ## core scheduling + engine tests only
	$(PYTHON) -m pytest -x -q tests/test_interfaces.py \
	    tests/test_schedulers.py tests/test_engine.py

bench:          ## full benchmark harness (CSV to stdout)
	$(PYTHON) benchmarks/run.py

bench-plan:     ## plan-engine speedup + cache-hit acceptance check
	$(PYTHON) benchmarks/plan_engine.py

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
