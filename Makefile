PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast test-validate test-multihost coverage lint smoke bench bench-plan bench-gate deps deps-dev

test:           ## tier-1 verify (full suite, fail-fast)
	$(PYTHON) -m pytest -x -q

test-fast:      ## core scheduling + engine + telemetry tests only
	$(PYTHON) -m pytest -x -q tests/test_interfaces.py \
	    tests/test_schedulers.py tests/test_engine.py tests/test_telemetry.py

# REPRO_PLAN_VALIDATE=1 makes the engine cross-check every vectorized plan
# chunk-for-chunk against the generic three-op driver (slow, exhaustive)
test-validate:  ## tier-1 with plan validation on
	REPRO_PLAN_VALIDATE=1 $(PYTHON) -m pytest -x -q

test-multihost: ## multi-host equivalence + replan suite (4 emulated hosts)
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m pytest -q tests/test_train_multihost.py

coverage:       ## tier-1 under coverage (4 emulated hosts); CI floor 82%
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m pytest -q --cov=repro --cov-report=term-missing \
	    --cov-report=xml --cov-fail-under=82

lint:           ## ruff over the whole tree (ruff.toml) + docs registry sync
	ruff check .
	$(PYTHON) tools/check_docs.py

smoke:          ## public-API smoke: quickstart + clause-string dry runs (CI job)
	$(PYTHON) examples/quickstart.py
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 4 --slots 2 --scheduler "guided,4" --max-new 4
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 4 --slots 2 --scheduler "guided,4" --max-new 4 --per-slot
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 4 --slots 2 --scheduler "guided,4" --max-new 8 \
	    --decode-steps 8
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 8 --scheduler "guided,4" --max-new 8 --paged-kv \
	    --num-blocks 24 --block-size 8 --max-concurrency 8 \
	    --decode-steps 4
	$(PYTHON) -m pytest -q tests/test_serve.py
	$(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 4 --seq-len 64 --scheduler "guided,4"
	$(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 4 --seq-len 64 --scheduler "guided,4" \
	    --microbatches 2 --fused-microbatches
	REPRO_UDS_MODULES=examples.uds_blocks PYTHONPATH=src:. \
	    $(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 4 --seq-len 64 --scheduler "uds:blocks,8"
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m pytest -q tests/test_train_multihost.py
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 4 --seq-len 64 --hosts 4 \
	    --straggler-scheduler "wf2"
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 8 --seq-len 64 --hosts 4 --microbatches 2 \
	    --scheduler "hier(host=awf, device=guided,4)"
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 4 --slots 2 --scheduler auto --max-new 4
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 2 --batch 4 --seq-len 64 --hosts 4 \
	    --straggler-scheduler auto
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) -m repro.launch.train --arch qwen2.5-3b --smoke \
	    --steps 4 --batch 8 --seq-len 64 --hosts 4 --elastic \
	    --kill-hosts 2,3 --kill-at 2
	$(PYTHON) -m repro.launch.serve --arch qwen2.5-3b --smoke \
	    --requests 8 --scheduler dynamic --max-new 6 --paged-kv \
	    --num-blocks 48 --block-size 8 --max-concurrency 8 \
	    --kill-rows 3 --kill-at-dispatch 2
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PYTHON) examples/fault_tolerant_train.py

bench:          ## full benchmark harness (CSV stdout, JSON to benchmarks/results/)
	$(PYTHON) benchmarks/run.py

bench-plan:     ## plan-engine speedup + cache-hit acceptance check
	$(PYTHON) benchmarks/plan_engine.py

bench-gate:     ## CI regression gates: write BENCH_*.json, fail on regression
	$(PYTHON) benchmarks/plan_engine.py --json BENCH_plan_engine.json --gate
	$(PYTHON) benchmarks/serve_adapt.py --json BENCH_serve.json --gate
	$(PYTHON) benchmarks/train_straggler.py --json BENCH_train.json --gate
	# elastic_recovery MERGES into the bench records the two lines above
	# overwrite — it must run last
	$(PYTHON) benchmarks/elastic_recovery.py --json-train BENCH_train.json \
	    --json-serve BENCH_serve.json --gate

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
