"""Plan-engine benchmark: vectorized compilation speedup + cache hit rate.

Demonstrates the two performance claims of the schedule planning engine:

1. **Vectorized closed-form compilation** emits the full chunk table of a
   1M-iteration loop ≥10× faster than the generic three-op state-machine
   driver (target named in the engine issue for GSS/FAC2; the table below
   covers every compiled family).
2. **Plan caching** makes repeated invocations of the same loop — the
   common case in training steps and serving — O(µs) dictionary lookups
   that skip Python dequeue entirely.

Run directly (``python benchmarks/plan_engine.py``) or through the harness
(``python benchmarks/run.py``), which prints the same
``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

N_ITER = 1_000_000        # the issue's 1M-iteration loop
WORKERS = 256             # a pod-scale team (one worker per chip)
SCHEDULERS = ("guided", "fac2", "tss", "static", "dynamic_64", "wf2",
              "rand", "taper", "fsc")


def _make(name):
    from repro.core import make_scheduler
    if name == "dynamic_64":
        return make_scheduler("dynamic", chunk=64)
    return make_scheduler(name)


def _timeit(fn, n):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def planning_speedup(n_iter: int = N_ITER, workers: int = WORKERS) -> list:
    """Vectorized vs generic planning wall time per scheduler family."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    rows = []
    table = {}
    for name in SCHEDULERS:
        loop = LoopSpec(0, n_iter, num_workers=workers, loop_id=name)
        t_gen = _timeit(
            lambda: eng.plan(_make(name), loop, mode="generic"), 2)
        t_vec = _timeit(
            lambda: eng.plan(_make(name), loop, mode="vectorized"), 5)
        plan = eng.plan(_make(name), loop, mode="vectorized")
        speedup = t_gen / t_vec
        table[name] = {"chunks": plan.num_chunks,
                       "generic_ms": round(t_gen * 1e3, 3),
                       "vectorized_ms": round(t_vec * 1e3, 3),
                       "speedup": round(speedup, 1)}
        rows.append((f"plan_engine/vectorize/{name}", t_vec * 1e6,
                     f"speedup={speedup:.1f}x;chunks={plan.num_chunks};"
                     f"generic_us={t_gen*1e6:.0f}"))
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "plan_engine.json").write_text(json.dumps(table, indent=1))
    return rows


def cache_hit_rate(steps: int = 200, n_iter: int = N_ITER,
                   workers: int = WORKERS) -> list:
    """Repeated invocations of the same loop (a training/serving steady
    state): all but the first plan come from the cache."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    loop = LoopSpec(0, n_iter, num_workers=workers, loop_id="train_step")

    t0 = time.perf_counter()
    for _ in range(steps):
        eng.plan(_make("fac2"), loop)
    dt = time.perf_counter() - t0
    info = eng.cache_info()
    t_hit = _timeit(lambda: eng.plan(_make("fac2"), loop), 50)
    t_miss = _timeit(lambda: eng.plan(_make("fac2"), loop,
                                      mode="generic"), 2)
    return [(
        "plan_engine/cache", t_hit * 1e6,
        f"hit_rate={info.hit_rate:.3f};hits={info.hits};"
        f"misses={info.misses};hit_us={t_hit*1e6:.1f};"
        f"replan_us={t_miss*1e6:.0f};steps={steps};"
        f"total_s={dt:.4f}")]


def main() -> None:
    rows = planning_speedup() + cache_hit_rate()
    print("name,us_per_call,derived")
    worst = None
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
        if "speedup=" in derived and any(
                k in name for k in ("guided", "fac2")):
            s = float(derived.split("speedup=")[1].split("x")[0])
            worst = s if worst is None else min(worst, s)
    if worst is not None:
        status = "PASS" if worst >= 10.0 else "FAIL"
        print(f"# acceptance: min(GSS,FAC2) speedup = {worst:.1f}x "
              f"(target >=10x) -> {status}")


if __name__ == "__main__":
    main()
