"""Plan-engine benchmark: vectorized compilation speedup + cache hit rate.

Demonstrates the two performance claims of the schedule planning engine:

1. **Vectorized closed-form compilation** emits the full chunk table of a
   1M-iteration loop ≥10× faster than the generic three-op state-machine
   driver (target named in the engine issue for GSS/FAC2; the table below
   covers every compiled family).
2. **Plan caching** makes repeated invocations of the same loop — the
   common case in training steps and serving — O(µs) dictionary lookups
   that skip Python dequeue entirely.

Run directly (``python benchmarks/plan_engine.py``) or through the harness
(``python benchmarks/run.py``), which prints the same
``name,us_per_call,derived`` CSV rows.

CI runs this with ``--json BENCH_plan_engine.json --gate``: the JSON is
the machine-readable benchmark trajectory (per-family speedups, cache hit
rate) uploaded as an artifact, and ``--gate`` turns the acceptance floors
(min speedup >= 8x on the gated families, cache hit rate >= 95%) into the
process exit code — a perf regression fails the build.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

SPEEDUP_FLOOR = 8.0       # CI gate: min vectorized-vs-generic speedup
HIT_RATE_FLOOR = 0.95     # CI gate: steady-state plan cache hit rate
# families the speedup gate is enforced on (the issue's named targets);
# every compiled family is still measured and reported
GATED = ("guided", "fac2", "taper")

N_ITER = 1_000_000        # the issue's 1M-iteration loop
WORKERS = 256             # a pod-scale team (one worker per chip)
SCHEDULERS = ("guided", "fac2", "tss", "static", "dynamic_64", "wf2",
              "rand", "taper", "fsc")


def _make(name):
    from repro.core import resolve
    if name == "dynamic_64":
        return resolve("dynamic,64")
    return resolve(name)


def _timeit(fn, n):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def _planning_speedup(n_iter: int = N_ITER, workers: int = WORKERS):
    """Vectorized vs generic planning wall time per scheduler family."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    rows = []
    table = {}
    for name in SCHEDULERS:
        loop = LoopSpec(0, n_iter, num_workers=workers, loop_id=name)
        t_gen = _timeit(
            lambda: eng.plan(_make(name), loop, mode="generic"), 2)
        t_vec = _timeit(
            lambda: eng.plan(_make(name), loop, mode="vectorized"), 5)
        plan = eng.plan(_make(name), loop, mode="vectorized")
        speedup = t_gen / t_vec
        table[name] = {"chunks": plan.num_chunks,
                       "generic_ms": round(t_gen * 1e3, 3),
                       "vectorized_ms": round(t_vec * 1e3, 3),
                       "speedup": round(speedup, 1)}
        rows.append((f"plan_engine/vectorize/{name}", t_vec * 1e6,
                     f"speedup={speedup:.1f}x;chunks={plan.num_chunks};"
                     f"generic_us={t_gen*1e6:.0f}"))
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "plan_engine.json").write_text(json.dumps(table, indent=1))
    return rows, table


def planning_speedup(n_iter: int = N_ITER, workers: int = WORKERS) -> list:
    return _planning_speedup(n_iter, workers)[0]


def _cache_hit_rate(steps: int = 200, n_iter: int = N_ITER,
                    workers: int = WORKERS):
    """Repeated invocations of the same loop (a training/serving steady
    state): all but the first plan come from the cache."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    loop = LoopSpec(0, n_iter, num_workers=workers, loop_id="train_step")

    t0 = time.perf_counter()
    for _ in range(steps):
        eng.plan(_make("fac2"), loop)
    dt = time.perf_counter() - t0
    info = eng.cache_info()
    t_hit = _timeit(lambda: eng.plan(_make("fac2"), loop), 50)
    t_miss = _timeit(lambda: eng.plan(_make("fac2"), loop,
                                      mode="generic"), 2)
    cache = {"hit_rate": round(info.hit_rate, 4), "hits": info.hits,
             "misses": info.misses, "steps": steps,
             "hit_us": round(t_hit * 1e6, 2),
             "replan_us": round(t_miss * 1e6, 1),
             "total_s": round(dt, 4)}
    rows = [(
        "plan_engine/cache", t_hit * 1e6,
        f"hit_rate={info.hit_rate:.3f};hits={info.hits};"
        f"misses={info.misses};hit_us={t_hit*1e6:.1f};"
        f"replan_us={t_miss*1e6:.0f};steps={steps};"
        f"total_s={dt:.4f}")]
    return rows, cache


def cache_hit_rate(steps: int = 200, n_iter: int = N_ITER,
                   workers: int = WORKERS) -> list:
    return _cache_hit_rate(steps, n_iter, workers)[0]


def collect(n_iter: int = N_ITER, workers: int = WORKERS) -> dict:
    """Full machine-readable benchmark record (what CI serializes)."""
    speed_rows, table = _planning_speedup(n_iter, workers)
    cache_rows, cache = _cache_hit_rate(n_iter=n_iter, workers=workers)
    gated = {k: table[k]["speedup"] for k in GATED if k in table}
    min_speedup = min(gated.values()) if gated else 0.0
    gate = {
        "gated_families": sorted(gated),
        "min_speedup": min_speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "hit_rate": cache["hit_rate"],
        "hit_rate_floor": HIT_RATE_FLOOR,
        "pass": bool(min_speedup >= SPEEDUP_FLOOR
                     and cache["hit_rate"] >= HIT_RATE_FLOOR),
    }
    return {
        "bench": "plan_engine",
        "n_iter": n_iter,
        "workers": workers,
        "schedulers": table,
        "cache": cache,
        "gate": gate,
        "rows": [list(r) for r in speed_rows + cache_rows],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the machine-readable record here "
                         "(CI: BENCH_plan_engine.json)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if min gated speedup < "
                         f"{SPEEDUP_FLOOR}x or hit rate < {HIT_RATE_FLOOR}")
    ap.add_argument("--iters", type=int, default=N_ITER)
    ap.add_argument("--workers", type=int, default=WORKERS)
    args = ap.parse_args(argv)

    record = collect(args.iters, args.workers)
    print("name,us_per_call,derived")
    for name, us, derived in record["rows"]:
        print(f"{name},{us:.2f},{derived}")
    gate = record["gate"]
    status = "PASS" if gate["pass"] else "FAIL"
    print(f"# gate: min({','.join(gate['gated_families'])}) speedup = "
          f"{gate['min_speedup']:.1f}x (floor {gate['speedup_floor']}x), "
          f"cache hit rate = {gate['hit_rate']:.3f} "
          f"(floor {gate['hit_rate_floor']}) -> {status}")
    if args.json is not None:
        args.json.write_text(json.dumps(record, indent=1))
        print(f"# wrote {args.json}")
    return 0 if (gate["pass"] or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
