"""Plan-engine benchmark: vectorized compilation speedup + cache hit rate.

Demonstrates the two performance claims of the schedule planning engine:

1. **Vectorized closed-form compilation** emits the full chunk table of a
   1M-iteration loop ≥10× faster than the generic three-op state-machine
   driver (target named in the engine issue for GSS/FAC2; the table below
   covers every compiled family).
2. **Plan caching** makes repeated invocations of the same loop — the
   common case in training steps and serving — O(µs) dictionary lookups
   that skip Python dequeue entirely.

Run directly (``python benchmarks/plan_engine.py``) or through the harness
(``python benchmarks/run.py``), which prints the same
``name,us_per_call,derived`` CSV rows.

A third stage times **hierarchical composition**: a cold ``hier(...)``
plan against the sum of the flat plans it comprises (host plan plus one
child plan per block, recursively) — the ratio is pure composition
overhead and must stay small for ``hier`` to be a free abstraction.

CI runs this with ``--json BENCH_plan_engine.json --gate``: the JSON is
the machine-readable benchmark trajectory (per-family speedups, cache hit
rate, composition overhead) uploaded as an artifact, and ``--gate`` turns
the acceptance floors (min speedup >= 8x on the gated families, cache hit
rate >= 95%, hier overhead <= 2x its flat levels) into the process exit
code — a perf regression fails the build.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

SPEEDUP_FLOOR = 8.0       # CI gate: min vectorized-vs-generic speedup
HIT_RATE_FLOOR = 0.95     # CI gate: steady-state plan cache hit rate
HIER_OVERHEAD_CEIL = 2.0  # CI gate: composed plan <= 2x its flat levels
# families the speedup gate is enforced on (the issue's named targets);
# every compiled family is still measured and reported
GATED = ("guided", "fac2", "taper")

# composed-plan stage: the hier clauses measured, with the flat plans
# each composition comprises (level clause, level team size)
HIER_CASES = {
    "hier(host=awf, device=guided,4, workers=8:32)":
        (("awf", 8), ("guided,4", 32)),
    "hier(host=awf, device=guided,4, tile=static, workers=8:8:4)":
        (("awf", 8), ("guided,4", 8), ("static", 4)),
}

N_ITER = 1_000_000        # the issue's 1M-iteration loop
WORKERS = 256             # a pod-scale team (one worker per chip)
SCHEDULERS = ("guided", "fac2", "tss", "static", "dynamic_64", "wf2",
              "rand", "taper", "fsc")


def _make(name):
    from repro.core import resolve
    if name == "dynamic_64":
        return resolve("dynamic,64")
    return resolve(name)


def _timeit(fn, n):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def _planning_speedup(n_iter: int = N_ITER, workers: int = WORKERS):
    """Vectorized vs generic planning wall time per scheduler family."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    rows = []
    table = {}
    for name in SCHEDULERS:
        loop = LoopSpec(0, n_iter, num_workers=workers, loop_id=name)
        t_gen = _timeit(
            lambda: eng.plan(_make(name), loop, mode="generic"), 2)
        t_vec = _timeit(
            lambda: eng.plan(_make(name), loop, mode="vectorized"), 5)
        plan = eng.plan(_make(name), loop, mode="vectorized")
        speedup = t_gen / t_vec
        table[name] = {"chunks": plan.num_chunks,
                       "generic_ms": round(t_gen * 1e3, 3),
                       "vectorized_ms": round(t_vec * 1e3, 3),
                       "speedup": round(speedup, 1)}
        rows.append((f"plan_engine/vectorize/{name}", t_vec * 1e6,
                     f"speedup={speedup:.1f}x;chunks={plan.num_chunks};"
                     f"generic_us={t_gen*1e6:.0f}"))
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "plan_engine.json").write_text(json.dumps(table, indent=1))
    return rows, table


def planning_speedup(n_iter: int = N_ITER, workers: int = WORKERS) -> list:
    return _planning_speedup(n_iter, workers)[0]


def _cache_hit_rate(steps: int = 200, n_iter: int = N_ITER,
                    workers: int = WORKERS):
    """Repeated invocations of the same loop (a training/serving steady
    state): all but the first plan come from the cache."""
    from repro.core import LoopSpec
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    loop = LoopSpec(0, n_iter, num_workers=workers, loop_id="train_step")

    t0 = time.perf_counter()
    for _ in range(steps):
        eng.plan(_make("fac2"), loop)
    dt = time.perf_counter() - t0
    info = eng.cache_info()
    t_hit = _timeit(lambda: eng.plan(_make("fac2"), loop), 50)
    t_miss = _timeit(lambda: eng.plan(_make("fac2"), loop,
                                      mode="generic"), 2)
    cache = {"hit_rate": round(info.hit_rate, 4), "hits": info.hits,
             "misses": info.misses, "steps": steps,
             "hit_us": round(t_hit * 1e6, 2),
             "replan_us": round(t_miss * 1e6, 1),
             "total_s": round(dt, 4)}
    rows = [(
        "plan_engine/cache", t_hit * 1e6,
        f"hit_rate={info.hit_rate:.3f};hits={info.hits};"
        f"misses={info.misses};hit_us={t_hit*1e6:.1f};"
        f"replan_us={t_miss*1e6:.0f};steps={steps};"
        f"total_s={dt:.4f}")]
    return rows, cache


def cache_hit_rate(steps: int = 200, n_iter: int = N_ITER,
                   workers: int = WORKERS) -> list:
    return _cache_hit_rate(steps, n_iter, workers)[0]


def _composed_overhead(n_iter: int = N_ITER, reps: int = 3):
    """Cold ``hier(...)`` composition cost vs the sum of the flat plans
    it comprises (the host plan over [0, n) plus one child plan per
    outer block, recursively).  The ratio is pure composition overhead —
    ComposedPlan assembly, blockify, recursion — and CI gates it at
    ``HIER_OVERHEAD_CEIL``.  Every timing uses a fresh engine so nothing
    comes from the plan cache; the steady state is a cache hit anyway
    (``plan_key`` covers the whole spec tree), reported alongside."""
    from repro.core import LoopSpec, resolve
    from repro.core.engine import PlanEngine

    def cold(clause, n, workers):
        best = None
        plan = None
        for _ in range(reps):
            eng = PlanEngine()
            loop = LoopSpec(0, n, num_workers=workers, loop_id="bench")
            t0 = time.perf_counter()
            plan = eng.plan(resolve(clause), loop)
            best = min(best or 1e9, time.perf_counter() - t0)
        return best, plan

    def constituents(levels, n):
        (clause, p), rest = levels[0], levels[1:]
        t, plan = cold(clause, n, p)
        for blk in (plan.worker_iters() if rest else ()):
            t += constituents(rest, int(blk))
        return t

    rows = []
    table = {}
    for clause, levels in HIER_CASES.items():
        t_hier, plan = cold(clause, n_iter, levels[0][1])
        t_flat = constituents(list(levels), n_iter)
        eng = PlanEngine()
        loop = LoopSpec(0, n_iter, num_workers=levels[0][1],
                        loop_id="bench")
        eng.plan(resolve(clause), loop)
        t_hit = _timeit(lambda: eng.plan(resolve(clause), loop), 20)
        ratio = t_hier / t_flat
        short = f"hier{len(levels)}"
        table[clause] = {"levels": len(levels),
                         "hier_ms": round(t_hier * 1e3, 3),
                         "flat_levels_ms": round(t_flat * 1e3, 3),
                         "overhead": round(ratio, 2),
                         "hit_us": round(t_hit * 1e6, 2)}
        rows.append((f"plan_engine/composed/{short}", t_hier * 1e6,
                     f"overhead={ratio:.2f}x;levels={len(levels)};"
                     f"flat_us={t_flat*1e6:.0f};hit_us={t_hit*1e6:.1f}"))
    return rows, table


def composed_overhead(n_iter: int = N_ITER) -> list:
    return _composed_overhead(n_iter)[0]


def collect(n_iter: int = N_ITER, workers: int = WORKERS) -> dict:
    """Full machine-readable benchmark record (what CI serializes)."""
    speed_rows, table = _planning_speedup(n_iter, workers)
    cache_rows, cache = _cache_hit_rate(n_iter=n_iter, workers=workers)
    hier_rows, hier = _composed_overhead(n_iter)
    gated = {k: table[k]["speedup"] for k in GATED if k in table}
    min_speedup = min(gated.values()) if gated else 0.0
    max_overhead = max(v["overhead"] for v in hier.values())
    gate = {
        "gated_families": sorted(gated),
        "min_speedup": min_speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "hit_rate": cache["hit_rate"],
        "hit_rate_floor": HIT_RATE_FLOOR,
        "max_hier_overhead": max_overhead,
        "hier_overhead_ceil": HIER_OVERHEAD_CEIL,
        "pass": bool(min_speedup >= SPEEDUP_FLOOR
                     and cache["hit_rate"] >= HIT_RATE_FLOOR
                     and max_overhead <= HIER_OVERHEAD_CEIL),
    }
    return {
        "bench": "plan_engine",
        "n_iter": n_iter,
        "workers": workers,
        "schedulers": table,
        "cache": cache,
        "composed": hier,
        "gate": gate,
        "rows": [list(r) for r in speed_rows + cache_rows + hier_rows],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the machine-readable record here "
                         "(CI: BENCH_plan_engine.json)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if min gated speedup < "
                         f"{SPEEDUP_FLOOR}x or hit rate < {HIT_RATE_FLOOR}")
    ap.add_argument("--iters", type=int, default=N_ITER)
    ap.add_argument("--workers", type=int, default=WORKERS)
    args = ap.parse_args(argv)

    record = collect(args.iters, args.workers)
    print("name,us_per_call,derived")
    for name, us, derived in record["rows"]:
        print(f"{name},{us:.2f},{derived}")
    gate = record["gate"]
    status = "PASS" if gate["pass"] else "FAIL"
    print(f"# gate: min({','.join(gate['gated_families'])}) speedup = "
          f"{gate['min_speedup']:.1f}x (floor {gate['speedup_floor']}x), "
          f"cache hit rate = {gate['hit_rate']:.3f} "
          f"(floor {gate['hit_rate_floor']}), "
          f"max hier overhead = {gate['max_hier_overhead']:.2f}x "
          f"(ceil {gate['hier_overhead_ceil']}x) -> {status}")
    if args.json is not None:
        args.json.write_text(json.dumps(record, indent=1))
        print(f"# wrote {args.json}")
    return 0 if (gate["pass"] or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
