import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

# NOTE: the env var above MUST be set before any jax import (jax locks the
# device count on first init) — the same contract as train_straggler.py.

_DOC = """Elastic recovery benchmark: an injected worker kill loses nothing.

The acceptance criterion of PR 9's membership-replan path as numbers: a
mid-run host/slot kill must lose ZERO steps and ZERO requests, and the
survivors must recover the pre-kill throughput.  Two stages, serialized
machine-readably (CI: ``--json-train`` / ``--json-serve`` MERGE an
``elastic_recovery`` section into the existing BENCH_train.json /
BENCH_serve.json — run this bench AFTER train_straggler / serve_adapt,
which overwrite those files whole):

1. **Train** (real model, 4 emulated CPU hosts): a ``TrainLoop`` with
   ``elastic=True`` and an injected kill of hosts {2, 3} mid-run.  The
   kill becomes a :class:`~repro.core.MembershipEvent`: the held batch is
   re-split over the survivors (no step dropped), the mesh/steps rebuild,
   the mitigator resizes, and the dead hosts' unfinished token chunks are
   requeued from the last share plan's chunk->worker provenance.  Gates:
   every step completes with finite loss, exactly one membership event,
   the mitigator team matches the loop team after the kill, the requeue
   audit conserves the token budget, and post-kill throughput (raw tok/s
   over the emulated hosts, which share ONE physical CPU — total compute
   is unchanged by the downsize) recovers >= 90% of pre-kill within
   ``SETTLE`` steps (the first post-kill step is the rebuild+recompile
   and is excluded, as is the initial compile step).

2. **Serve** (paged KV): TWO ``PagedServeLoop`` runs over the SAME
   request set — one unkilled, one with 3 of 8 dispatch rows killed at
   the 2nd decode dispatch (drain-and-readmit through the evict-requeue
   machinery).  Gates: the killed run returns token-for-token identical
   results for EVERY request (greedy decode + replay-prefix readmission),
   zero requests lost, >= 1 preemption actually drained, one membership
   event, and post-kill per-LIVE-ROW throughput >= 80% of pre-kill (the
   fused dispatch keeps its compiled (C, W) shape, so raw tok/s drops
   with the dead rows by design — per-row normalization isolates the
   recovery from the capacity loss).
"""
# ^ a named constant, not __doc__: the XLA env setup must be the module's
# first statements, and a docstring cannot follow them

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"

HOSTS = 4
KILL_HOSTS = (2, 3)
KILL_AT_STEP = 6
TRAIN_STEPS = 12
SETTLE = 1                  # post-kill steps excluded as rebuild/recompile
TRAIN_RECOVERY_GATE = 0.9   # post-kill tok/s vs pre-kill

SERVE_CONCURRENCY = 8
SERVE_KILL_ROWS = 3
SERVE_KILL_AT = 4
# per-live-row tok/s, post vs pre: measures ~0.93-1.0 on an idle machine;
# the floor leaves headroom for shared CI runners
SERVE_RECOVERY_GATE = 0.8


def train_recovery(arch: str = "qwen2.5-3b", steps: int = TRAIN_STEPS,
                   batch: int = 16, seq_len: int = 128) -> dict:
    """Elastic TrainLoop with an injected mid-run host kill."""
    import jax

    if jax.device_count() < HOSTS:
        raise SystemExit(f"needs {HOSTS} devices; run with XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={HOSTS}")
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainLoop

    cfg = get_smoke_config(arch)
    loop = TrainLoop(cfg, batch=batch, seq_len=seq_len, seed=0,
                     hosts=HOSTS, elastic=True,
                     kill_hosts=list(KILL_HOSTS),
                     kill_at_step=KILL_AT_STEP)
    losses = loop.run(steps, log_every=10 ** 9)

    log = loop.step_log
    # exclude the initial compile step and the rebuild+recompile step(s)
    # right after the kill: both are one-time costs, not steady state
    pre = [e for e in log[1:] if e["step"] < KILL_AT_STEP]
    post = [e for e in log if e["step"] >= KILL_AT_STEP + SETTLE]
    tok_s = lambda es: (sum(e["tokens"] for e in es)
                        / max(sum(e["dt_s"] for e in es), 1e-9))
    pre_tok_s, post_tok_s = tok_s(pre), tok_s(post)
    recovery = round(post_tok_s / max(pre_tok_s, 1e-9), 3)

    audits = loop.requeue_audits
    audit_ok = all(sum(a["shares"]) == sum(a["carried"])
                   + a["requeued_iters"] for a in audits) if audits else True
    ev = loop.membership_events
    return {
        "arch": arch,
        "hosts": HOSTS,
        "kill_hosts": list(KILL_HOSTS),
        "kill_at_step": KILL_AT_STEP,
        "steps": steps,
        "batch": batch,
        "seq_len": seq_len,
        "steps_completed": len(losses),
        "losses_finite": bool(np.isfinite(losses).all()),
        "final_loss": round(float(losses[-1]), 4),
        "membership_events": [
            {"kind": e.kind, "old_size": e.old_size, "new_size": e.new_size,
             "lost": list(e.lost), "step": e.step} for e in ev],
        "final_hosts": loop.hosts,
        "mitigator_hosts": loop.mitigator.num_hosts,
        "hosts_per_step": [e["hosts"] for e in log],
        "requeue_audits": audits,
        "requeue_budget_conserved": bool(audit_ok),
        "pre_kill_tok_s": round(pre_tok_s, 1),
        "post_kill_tok_s": round(post_tok_s, 1),
        "recovery": recovery,
        "recovery_gate": TRAIN_RECOVERY_GATE,
    }


def serve_recovery(arch: str = "qwen2.5-3b", requests: int = 12,
                   max_new: int = 8) -> dict:
    """Killed vs unkilled PagedServeLoop over the same request set."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import PagedServeLoop, Request

    cfg = get_smoke_config(arch)

    def mk_requests():
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=rng.integers(4, 24)
                                            ).astype(np.int32),
                        max_new=max_new)
                for i in range(requests)]

    def mk_loop(**kw):
        return PagedServeLoop(cfg, num_blocks=48, block_size=8,
                              max_context=64,
                              concurrency=SERVE_CONCURRENCY,
                              scheduler="dynamic", prefill_chunk=16, **kw)

    base = mk_loop()
    t0 = time.perf_counter()
    ref = base.run(mk_requests())
    base_wall = time.perf_counter() - t0

    kill = mk_loop(kill_rows=SERVE_KILL_ROWS,
                   kill_at_dispatch=SERVE_KILL_AT)
    t0 = time.perf_counter()
    out = kill.run(mk_requests())
    kill_wall = time.perf_counter() - t0

    lost = sorted(set(ref) - set(out))
    mismatched = sorted(r for r in ref if r in out and out[r] != ref[r])
    # per-live-row throughput, pre vs post kill (exclude dispatch 0 —
    # the decode compile — and the kill dispatch itself: it runs at full
    # width but the drain already fired)
    log = kill.dispatch_log
    pre = [e for e in log if 0 < e["dispatch"] < SERVE_KILL_AT]
    post = [e for e in log if e["dispatch"] > SERVE_KILL_AT]
    row_tok_s = lambda es: (sum(e["tokens"] / e["live_rows"] for e in es)
                            / max(sum(e["dt_s"] for e in es), 1e-9))
    pre_rate, post_rate = row_tok_s(pre), row_tok_s(post)
    recovery = round(post_rate / max(pre_rate, 1e-9), 3)
    s = kill.last_stats
    return {
        "arch": arch,
        "requests": requests,
        "max_new": max_new,
        "concurrency": SERVE_CONCURRENCY,
        "kill_rows": SERVE_KILL_ROWS,
        "kill_at_dispatch": SERVE_KILL_AT,
        "requests_lost": lost,
        "mismatched": mismatched,
        "token_for_token": not lost and not mismatched,
        "preemptions": s.get("preemptions"),
        "membership_events": s["membership_events"],
        "dead_rows": s["dead_rows"],
        "live_rows": s["live_rows"],
        "base_tok_s": round(sum(len(v) for v in ref.values())
                            / max(base_wall, 1e-9), 1),
        "killed_tok_s": round(sum(len(v) for v in out.values())
                              / max(kill_wall, 1e-9), 1),
        "pre_kill_row_tok_s": round(pre_rate, 1),
        "post_kill_row_tok_s": round(post_rate, 1),
        "recovery_per_row": recovery,
        "recovery_gate": SERVE_RECOVERY_GATE,
    }


def collect() -> dict:
    record: dict = {"bench": "elastic_recovery",
                    "train": train_recovery(),
                    "serve": serve_recovery()}
    tr, sv = record["train"], record["serve"]
    checks = {
        "train_zero_steps_lost": tr["steps_completed"] == tr["steps"],
        "train_losses_finite": tr["losses_finite"],
        "train_membership_event": len(tr["membership_events"]) == 1,
        "train_mitigator_resized": (tr["mitigator_hosts"]
                                    == tr["final_hosts"]),
        "train_requeue_conserved": tr["requeue_budget_conserved"],
        "train_recovery_gate": tr["recovery"] >= TRAIN_RECOVERY_GATE,
        "serve_zero_requests_lost": not sv["requests_lost"],
        "serve_token_for_token": sv["token_for_token"],
        "serve_drained": (sv["preemptions"] or 0) >= 1,
        "serve_membership_event": len(sv["membership_events"]) == 1,
        "serve_recovery_gate": (sv["recovery_per_row"]
                                >= SERVE_RECOVERY_GATE),
    }
    record["gate"] = {"checks": checks, "pass": all(checks.values())}
    return record


def _merge(path: Path, record: dict) -> None:
    """Add/replace the elastic_recovery section of an existing bench file
    (train_straggler / serve_adapt overwrite those files whole — this
    bench must run after them and merge, not clobber)."""
    data = json.loads(path.read_text()) if path.exists() else {}
    data["elastic_recovery"] = record
    path.write_text(json.dumps(data, indent=1))


def rows() -> list:
    """Harness contract: ``name,us_per_call,derived`` rows for run.py."""
    rec = collect()
    tr, sv = rec["train"], rec["serve"]
    return [
        ("elastic_recovery/train", 0.0,
         f"recovery={tr['recovery']};hosts={tr['hosts']}->"
         f"{tr['final_hosts']};steps={tr['steps_completed']}"),
        ("elastic_recovery/serve", 0.0,
         f"recovery_per_row={sv['recovery_per_row']};"
         f"token_for_token={sv['token_for_token']};"
         f"preemptions={sv['preemptions']}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--json-train", type=Path, default=None, metavar="PATH",
                    help="merge the train record into this bench file "
                         "(CI: BENCH_train.json; must run after "
                         "train_straggler, which overwrites it whole)")
    ap.add_argument("--json-serve", type=Path, default=None, metavar="PATH",
                    help="merge the serve record into this bench file "
                         "(CI: BENCH_serve.json; must run after "
                         "serve_adapt, which overwrites it whole)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 unless the injected kill lost zero "
                         "steps/requests and throughput recovered")
    args = ap.parse_args(argv)

    record = collect()
    tr, sv = record["train"], record["serve"]
    ev = tr["membership_events"][0] if tr["membership_events"] else {}
    print(f"train: {tr['steps_completed']}/{tr['steps']} steps, kill at "
          f"step {tr['kill_at_step']} ({ev.get('old_size')} -> "
          f"{ev.get('new_size')} hosts), tok/s "
          f"{tr['pre_kill_tok_s']} -> {tr['post_kill_tok_s']} = "
          f"{tr['recovery']}x recovery (gate >= {TRAIN_RECOVERY_GATE}x)")
    print(f"serve: {sv['requests']} requests, {sv['kill_rows']} of "
          f"{sv['concurrency']} rows killed at dispatch "
          f"{sv['kill_at_dispatch']}; token-for-token="
          f"{sv['token_for_token']}, {sv['preemptions']} drained, "
          f"per-row tok/s {sv['pre_kill_row_tok_s']} -> "
          f"{sv['post_kill_row_tok_s']} = {sv['recovery_per_row']}x "
          f"(gate >= {SERVE_RECOVERY_GATE}x)")
    status = "PASS" if record["gate"]["pass"] else "FAIL"
    print(f"# gate: {record['gate']['checks']} -> {status}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "elastic_recovery.json").write_text(
        json.dumps(record, indent=1))
    if args.json_train is not None:
        _merge(args.json_train, record["train"] | {"gate": record["gate"]})
        print(f"# merged into {args.json_train}")
    if args.json_serve is not None:
        _merge(args.json_serve, record["serve"] | {"gate": record["gate"]})
        print(f"# merged into {args.json_serve}")
    return 0 if (record["gate"]["pass"] or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
