"""Adaptive-replan benchmark: the telemetry -> history -> replan loop.

Two stages, both serialized machine-readably (CI: ``--json
BENCH_serve.json`` uploaded as an artifact, ``--gate`` as the exit code):

1. **Executor steady state** (pure host, no JAX): an AWF loop over a team
   with one deliberately slow worker.  Each step plans through the
   ``PlanEngine`` (cached), replays via ``execute_plan`` with telemetry
   attached, and flushes — the flush bumps the history's measured epoch,
   the next ``plan()`` misses the adaptive cache and replans from the
   measured rates.  Reported: the slow worker's share trajectory, makespan
   improvement, epoch advances, and cache invalidations — the acceptance
   criterion "an AWF run demonstrably replans from measured data" as
   numbers.

2. **Serve smoke** (real model, CPU-runnable smoke config): two
   ``ServeLoop.run()`` invocations under AWF admission.  The first run's
   per-chunk wall times (prefill + decode, the fixed feedback bug) flush
   at stream close; the second run plans admission from the learned slot
   rates.  Reported: tok/s, measured epoch, per-slot telemetry.

3. **Batched-decode throughput** (real model): warm ``ServeLoop.run()``
   timings of the batched engine (one jitted decode call per token across
   all slots, stacked KV cache) against the per-slot escape hatch (one
   call per active slot per token).  The gate enforces
   ``batched_vs_per_slot_speedup >= 3`` — the serve-throughput acceptance
   criterion for the batched rebuild.

4. **Fused-decode throughput** (real model): warm tok/s of the fused
   engine at ``decode_steps=8`` (one jitted dispatch per 8 tokens, the
   on-device ``lax.scan`` loop) against the stepwise ``decode_steps=1``
   engine, plus the measured ``dispatches_per_token`` of each.  The gate
   enforces ``fused_speedup >= 1.5`` — the dispatch-amortization
   acceptance criterion for the fused rebuild.

5. **Auto selection** (pure host, no JAX): the same skewed-worker loop
   driven by ``schedule(auto)`` and by every fixed candidate clause.
   Reported: each clause's steady-state makespan, auto's selection
   trajectory, and ``auto_vs_best_fixed_ratio`` (best fixed steady
   makespan / auto's).  The gate enforces ``>= 0.9`` — the acceptance
   criterion that auto converges within 10% of the best hand-picked
   clause without being told which.

6. **Paged concurrency** (real model): the paged-KV continuous-batching
   engine over the deterministic long/short mixed trace that
   ``tests/test_paged.py`` also exercises (``serve_mem.make_mixed_trace``
   — tests and bench gate the same workload).  Two sub-runs: an *open*
   pool serving O(100) concurrent requests (gates: every request
   completes, ``peak_concurrency >= 100``, a warm tok/s floor, and a p99
   admission-latency ceiling) and a *pressured* pool far below the
   working set (gates: every request still completes, ``preemptions >=
   1`` — eviction/readmission demonstrably exercised end to end).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

N_ITER = 8192
WORKERS = 8
STEPS = 10
SLOW_WORKER = WORKERS - 1
SLOW_SPEED = 0.25
SPEEDUP_GATE = 3.0     # batched decode must be >= 3x per-slot tok/s
FUSED_GATE = 1.5       # fused decode_steps=8 must be >= 1.5x stepwise tok/s
FUSED_STEPS = 8
AUTO_RATIO_GATE = 0.9  # auto must reach >= 90% of the best fixed clause
PAGED_REQUESTS = 120
PAGED_CONCURRENCY_GATE = 100   # paged engine must hold O(100) in flight
PAGED_TOKS_GATE = 10.0         # warm tok/s floor (conservative: CI CPU)
PAGED_ADM_P99_GATE = 5.0       # p99 admission latency ceiling, seconds


def executor_steady_state(n_iter: int = N_ITER, workers: int = WORKERS,
                          steps: int = STEPS) -> dict:
    """plan -> execute_plan -> flush, ``steps`` times, under skewed speeds."""
    import numpy as np
    from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                            execute_plan, resolve)
    from repro.core.engine import PlanEngine

    eng = PlanEngine()
    hist = LoopHistory()
    loop = LoopSpec(0, n_iter, num_workers=workers, loop_id="serve_adapt")
    sched = resolve("awf")
    speeds = [1.0] * workers
    speeds[SLOW_WORKER] = SLOW_SPEED
    costs = np.ones(n_iter)

    epochs = [hist.measured_invocations(loop.loop_id)]
    slow_share = []
    makespans = []
    t0 = time.perf_counter()
    for _ in range(steps):
        tel = LoopTelemetry(hist, loop_id=loop.loop_id, num_workers=workers)
        plan = eng.plan(sched, loop, history=hist)
        res = execute_plan(plan, costs, speeds=speeds, telemetry=tel)
        slow_share.append(int(plan.worker_iters()[SLOW_WORKER]))
        makespans.append(round(res.makespan, 2))
        epochs.append(hist.measured_invocations(loop.loop_id))
    wall = time.perf_counter() - t0

    info = eng.cache_info()
    return {
        "n_iter": n_iter,
        "workers": workers,
        "steps": steps,
        "slow_worker": SLOW_WORKER,
        "slow_speed": SLOW_SPEED,
        "slow_share": slow_share,            # iterations given to slow host
        "makespan": makespans,               # virtual seconds per step
        "epochs": epochs,                    # measured-invocation trajectory
        "epoch_advances": epochs[-1] - epochs[0],
        "cache_invalidations": info.misses - 1,   # replans beyond the first
        "cache_hits": info.hits,
        "makespan_improvement": round(makespans[0] / makespans[-1], 3),
        "rebalanced": bool(slow_share[-1] < slow_share[0]),
        "wall_s": round(wall, 3),
    }


def auto_selection(n_iter: int = N_ITER, workers: int = WORKERS,
                   steps: int = STEPS, steady_k: int = 3) -> dict:
    """schedule(auto) vs every fixed candidate on the skewed executor.

    Each clause runs the same plan -> execute -> measure loop as the
    executor stage (fresh ``resolve()`` per step: selection state lives
    in the history, not the object); the figure of merit is the ratio of
    the best fixed clause's steady-state makespan to auto's."""
    import numpy as np
    from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                            execute_plan, resolve)
    from repro.core.auto import DEFAULT_CANDIDATES
    from repro.core.engine import PlanEngine

    speeds = [1.0] * workers
    speeds[SLOW_WORKER] = SLOW_SPEED
    costs = np.ones(n_iter)
    loop = LoopSpec(0, n_iter, num_workers=workers, loop_id="auto_select")

    def drive(clause: str) -> dict:
        eng = PlanEngine()
        hist = LoopHistory()
        tel = LoopTelemetry(hist, loop_id=loop.loop_id, num_workers=workers)
        makespans, tags = [], []
        for _ in range(steps):
            sched = resolve(clause)
            plan = eng.plan(sched, loop, history=hist)
            res = execute_plan(plan, costs, speeds=speeds,
                               history=hist, telemetry=tel)
            makespans.append(round(res.makespan, 2))
            tags.append(getattr(sched, "history_tag", clause))
        return {"makespan": makespans, "selected": tags,
                "steady_makespan": round(
                    sum(makespans[-steady_k:]) / steady_k, 2)}

    fixed = {c: drive(c) for c in DEFAULT_CANDIDATES}
    auto = drive("auto")
    best_clause = min(fixed, key=lambda c: fixed[c]["steady_makespan"])
    best = fixed[best_clause]["steady_makespan"]
    ratio = round(best / max(auto["steady_makespan"], 1e-9), 3)
    return {
        "n_iter": n_iter,
        "workers": workers,
        "steps": steps,
        "slow_worker": SLOW_WORKER,
        "slow_speed": SLOW_SPEED,
        "fixed_steady": {c: fixed[c]["steady_makespan"] for c in fixed},
        "best_fixed": best_clause,
        "auto": auto,
        "auto_vs_best_fixed_ratio": ratio,
        "auto_ratio_gate": AUTO_RATIO_GATE,
    }


def serve_smoke(arch: str = "qwen2.5-3b", requests: int = 8,
                slots: int = 2, max_new: int = 4) -> dict:
    """Two real serve runs; the second plans from the first's telemetry."""
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.serve import Request, ServeLoop

    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)

    def make_requests():
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=int(rng.integers(4, 12))
                                            ).astype(np.int32),
                        max_new=max_new)
                for i in range(requests)]

    loop = ServeLoop(cfg, slots=slots, scheduler="awf")
    t0 = time.perf_counter()
    out1 = loop.run(make_requests())
    cold_s = time.perf_counter() - t0
    epoch1 = loop.measured_epoch()
    t0 = time.perf_counter()
    out2 = loop.run(make_requests())
    warm_s = time.perf_counter() - t0
    toks = sum(len(v) for v in out2.values())
    return {
        "arch": arch,
        "slots": slots,
        "requests": requests,
        "completed": [len(out1), len(out2)],
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 3),
        "tok_s": round(toks / warm_s, 2),
        "epochs": [epoch1, loop.measured_epoch()],
        "telemetry": loop.last_stats,
    }


def batched_speedup(arch: str = "qwen2.5-3b", requests: int = 16,
                    slots: int = 8, max_new: int = 32,
                    prompt_len: int = 8, max_len: int = 64) -> dict:
    """Warm tok/s of the batched decode engine vs the per-slot escape hatch.

    Both loops serve the same request set under the same ``dynamic``
    admission clause; the first run of each pays compilation and warms the
    caches, the second is timed.  Prompts share one FIXED length so prefill
    compiles once in the warm run — variable lengths would recompile
    prefill inside the timed run and drown the decode substrate under
    identical compile noise on both sides.  The decode-step count is
    identical (the engines are token-for-token equivalent —
    ``tests/test_serve.py``), so the ratio isolates the substrate: one
    jitted call per token for the whole team vs one call per active slot
    per token.
    """
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.serve import Request, ServeLoop

    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)

    def make_requests():
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=prompt_len
                                            ).astype(np.int32),
                        max_new=max_new)
                for i in range(requests)]

    def timed(batched: bool, repeats: int = 3) -> dict:
        loop = ServeLoop(cfg, slots=slots, max_len=max_len,
                         scheduler="dynamic", batched=batched)
        loop.run(make_requests())              # compile + warm
        best = None
        for _ in range(repeats):               # best-of-N: shed host noise
            t0 = time.perf_counter()
            out = loop.run(make_requests())
            wall = time.perf_counter() - t0
            if best is None or wall < best[1]:
                best = (out, wall)
        out, wall = best
        toks = sum(len(v) for v in out.values())
        return {"mode": loop.mode, "completed": len(out), "tokens": toks,
                "wall_s": round(wall, 3), "tok_s": round(toks / wall, 2)}

    per_slot = timed(batched=False)
    batched = timed(batched=True)
    speedup = round(batched["tok_s"] / per_slot["tok_s"], 3)
    return {
        "arch": arch,
        "slots": slots,
        "requests": requests,
        "max_new": max_new,
        "per_slot": per_slot,
        "batched": batched,
        "batched_vs_per_slot_speedup": speedup,
        "speedup_gate": SPEEDUP_GATE,
    }


def fused_speedup(arch: str = "qwen2.5-3b", requests: int = 16,
                  slots: int = 8, max_new: int = 32,
                  prompt_len: int = 8, max_len: int = 64,
                  decode_steps: int = FUSED_STEPS) -> dict:
    """Warm tok/s of the fused multi-token engine vs the stepwise one.

    Both loops are batched over the same stacked cache and serve the same
    request set; the only variable is the dispatch quantum — one jitted
    call per ``decode_steps`` tokens (an on-device ``lax.scan``) vs one
    per token.  Token outputs are identical (greedy decode is
    deterministic — ``tests/test_serve.py`` locks it), so the ratio
    isolates the Python->XLA round-trip amortization.  Fixed prompt
    length keeps prefill out of the timing (one bucket, one compile).
    """
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.serve import Request, ServeLoop

    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)

    def make_requests():
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=prompt_len
                                            ).astype(np.int32),
                        max_new=max_new)
                for i in range(requests)]

    def timed(steps: int, repeats: int = 3) -> dict:
        loop = ServeLoop(cfg, slots=slots, max_len=max_len,
                         scheduler="dynamic", decode_steps=steps)
        loop.run(make_requests())              # compile + warm
        best = None
        for _ in range(repeats):               # best-of-N: shed host noise
            t0 = time.perf_counter()
            out = loop.run(make_requests())
            wall = time.perf_counter() - t0
            if best is None or wall < best[1]:
                best = (out, wall, dict(loop.last_stats))
        out, wall, stats = best
        toks = sum(len(v) for v in out.values())
        return {"decode_steps": steps, "completed": len(out),
                "tokens": toks, "wall_s": round(wall, 3),
                "tok_s": round(toks / wall, 2),
                "decode_dispatches": stats["decode_dispatches"],
                "dispatches_per_token": stats["dispatches_per_token"]}

    stepwise = timed(1)
    fused = timed(decode_steps)
    speedup = round(fused["tok_s"] / stepwise["tok_s"], 3)
    return {
        "arch": arch,
        "slots": slots,
        "requests": requests,
        "max_new": max_new,
        "stepwise": stepwise,
        "fused": fused,
        "fused_speedup": speedup,
        "fused_gate": FUSED_GATE,
    }


def paged_concurrency(arch: str = "qwen2.5-3b",
                      requests: int = PAGED_REQUESTS) -> dict:
    """O(100)-way continuous batching through the paged-KV block pool.

    The *open* run sizes the pool above the trace's working set, so every
    request admits while blocks are free and occupancy climbs to the full
    trace — the concurrency a slot-count engine of the same memory could
    never reach.  The *pressured* run shrinks the pool far below the
    working set: decode growth must evict (LIFO) and readmit, and every
    request must STILL complete with its exact tokens (equivalence is
    locked in tests; the bench locks that the machinery engages under a
    realistic mixed trace).  tok/s and p99 admission latency come from
    the warm open run.
    """
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.serve import PagedServeLoop, Request
    from repro.serve_mem import make_mixed_trace

    cfg = get_smoke_config(arch)
    trace = make_mixed_trace(requests, vocab_size=cfg.vocab_size, seed=3)

    def mk(n=None):
        return [Request(rid=t.rid, prompt=t.prompt.copy(),
                        max_new=t.max_new) for t in trace[:n]]

    open_loop = PagedServeLoop(cfg, num_blocks=512, block_size=8,
                               max_context=64, concurrency=128,
                               scheduler="guided,2", decode_steps=4,
                               prefill_chunk=16)
    open_loop.run(mk())                        # compile + warm
    t0 = time.perf_counter()
    out = open_loop.run(mk())
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    s = dict(open_loop.last_stats)
    open_rec = {
        "completed": len(out), "tokens": toks, "wall_s": round(wall, 3),
        "tok_s": round(toks / wall, 2),
        "peak_concurrency": s["peak_concurrency"],
        "peak_blocks_used": s["peak_blocks_used"],
        "kv_util_mean": s["kv_util_mean"],
        "preemptions": s["preemptions"],
        "prefill_compiles": s["prefill_compiles"],
        "queue_p50_s": round(s["queue_p50_s"], 4),
        "queue_p99_s": round(s["queue_p99_s"], 4),
        "admission_p50_s": round(s["admission_p50_s"], 4),
        "admission_p99_s": round(s["admission_p99_s"], 4),
    }

    tight_loop = PagedServeLoop(cfg, num_blocks=12, block_size=8,
                                max_context=64, concurrency=16,
                                scheduler="guided,2", decode_steps=4,
                                prefill_chunk=16)
    t0 = time.perf_counter()
    out_t = tight_loop.run(mk(16))
    wall_t = time.perf_counter() - t0
    st = dict(tight_loop.last_stats)
    pressured_rec = {
        "requests": 16, "num_blocks": 12, "completed": len(out_t),
        "wall_s": round(wall_t, 3),
        "preemptions": st["preemptions"],
        "failed_allocs": st["failed_allocs"],
        "kv_util_mean": st["kv_util_mean"],
        "peak_blocks_used": st["peak_blocks_used"],
    }
    return {
        "arch": arch,
        "requests": requests,
        "num_blocks": 512,
        "block_size": 8,
        "open": open_rec,
        "pressured": pressured_rec,
        "concurrency_gate": PAGED_CONCURRENCY_GATE,
        "tok_s_gate": PAGED_TOKS_GATE,
        "admission_p99_gate_s": PAGED_ADM_P99_GATE,
    }


def collect(skip_serve: bool = False) -> dict:
    record: dict = {"bench": "serve_adapt",
                    "executor": executor_steady_state(),
                    "auto": auto_selection()}
    if not skip_serve:
        record["serve"] = serve_smoke()
        record["batched"] = batched_speedup()
        record["fused"] = fused_speedup()
        record["paged"] = paged_concurrency()
    ex = record["executor"]
    au = record["auto"]
    checks = {
        "epoch_advanced": ex["epoch_advances"] >= 1,
        "replanned_from_measurements": ex["cache_invalidations"] >= 1,
        "rebalanced_off_slow_worker": ex["rebalanced"],
        "makespan_improved": ex["makespan_improvement"] > 1.0,
        "auto_ratio_gate": au["auto_vs_best_fixed_ratio"] >= AUTO_RATIO_GATE,
    }
    if not skip_serve:
        sv = record["serve"]
        checks["serve_measured_epochs"] = sv["epochs"][-1] >= 2
        checks["serve_completed_all"] = (sv["completed"]
                                         == [sv["requests"]] * 2)
        bt = record["batched"]
        checks["batched_speedup_gate"] = (
            bt["batched_vs_per_slot_speedup"] >= SPEEDUP_GATE)
        checks["batched_completed_all"] = (
            bt["batched"]["completed"] == bt["requests"]
            and bt["per_slot"]["completed"] == bt["requests"])
        fu = record["fused"]
        checks["fused_speedup_gate"] = fu["fused_speedup"] >= FUSED_GATE
        checks["fused_completed_all"] = (
            fu["fused"]["completed"] == fu["requests"]
            and fu["stepwise"]["completed"] == fu["requests"])
        pg = record["paged"]
        checks["paged_completed_all"] = (
            pg["open"]["completed"] == pg["requests"]
            and pg["pressured"]["completed"] == pg["pressured"]["requests"])
        checks["paged_concurrency_gate"] = (
            pg["open"]["peak_concurrency"] >= PAGED_CONCURRENCY_GATE)
        checks["paged_tok_s_gate"] = pg["open"]["tok_s"] >= PAGED_TOKS_GATE
        checks["paged_admission_p99_gate"] = (
            pg["open"]["admission_p99_s"] <= PAGED_ADM_P99_GATE)
        checks["paged_preempted"] = pg["pressured"]["preemptions"] >= 1
    record["gate"] = {"checks": checks, "pass": all(checks.values())}
    return record


def rows(skip_serve: bool = True) -> list:
    """Harness contract: ``name,us_per_call,derived`` rows for run.py."""
    rec = collect(skip_serve=skip_serve)
    ex = rec["executor"]
    out = [("serve_adapt/executor", 0.0,
            f"epochs={ex['epoch_advances']};"
            f"share_slow={ex['slow_share'][0]}->{ex['slow_share'][-1]};"
            f"makespan_x={ex['makespan_improvement']}")]
    au = rec["auto"]
    out.append(("serve_adapt/auto", 0.0,
                f"ratio={au['auto_vs_best_fixed_ratio']};"
                f"best={au['best_fixed']};"
                f"selected={au['auto']['selected'][-1]}"))
    if "serve" in rec:
        sv = rec["serve"]
        out.append(("serve_adapt/serve", 0.0,
                    f"tok_s={sv['tok_s']};epochs={sv['epochs'][-1]}"))
    if "batched" in rec:
        bt = rec["batched"]
        out.append(("serve_adapt/batched", 0.0,
                    f"speedup={bt['batched_vs_per_slot_speedup']};"
                    f"batched_tok_s={bt['batched']['tok_s']};"
                    f"per_slot_tok_s={bt['per_slot']['tok_s']}"))
    if "fused" in rec:
        fu = rec["fused"]
        out.append(("serve_adapt/fused", 0.0,
                    f"speedup={fu['fused_speedup']};"
                    f"fused_tok_s={fu['fused']['tok_s']};"
                    f"stepwise_tok_s={fu['stepwise']['tok_s']};"
                    f"dispatches_per_token={fu['fused']['dispatches_per_token']}"))
    if "paged" in rec:
        pg = rec["paged"]
        out.append(("serve_adapt/paged", 0.0,
                    f"tok_s={pg['open']['tok_s']};"
                    f"peak_conc={pg['open']['peak_concurrency']};"
                    f"adm_p99_s={pg['open']['admission_p99_s']};"
                    f"preemptions={pg['pressured']['preemptions']}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the machine-readable record here "
                         "(CI: BENCH_serve.json)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 unless the adaptive loop demonstrably "
                         "replanned from measured data")
    ap.add_argument("--skip-serve", action="store_true",
                    help="executor stage only (no JAX model)")
    args = ap.parse_args(argv)

    record = collect(skip_serve=args.skip_serve)
    ex = record["executor"]
    print(f"executor: slow-worker share {ex['slow_share'][0]} -> "
          f"{ex['slow_share'][-1]} iters, makespan "
          f"{ex['makespan'][0]} -> {ex['makespan'][-1]} "
          f"({ex['makespan_improvement']}x), "
          f"{ex['epoch_advances']} epoch advances, "
          f"{ex['cache_invalidations']} cache invalidations")
    au = record["auto"]
    print(f"auto: steady {au['auto']['steady_makespan']} vs best fixed "
          f"'{au['best_fixed']}' {au['fixed_steady'][au['best_fixed']]} -> "
          f"ratio {au['auto_vs_best_fixed_ratio']} "
          f"(gate >= {AUTO_RATIO_GATE}), selected "
          f"{au['auto']['selected'][0]} -> {au['auto']['selected'][-1]}")
    if "serve" in record:
        sv = record["serve"]
        print(f"serve: {sv['tok_s']} tok/s warm, epochs {sv['epochs']}, "
              f"imbalance {sv['telemetry'].get('imbalance')}")
    if "batched" in record:
        bt = record["batched"]
        print(f"batched decode: {bt['batched']['tok_s']} tok/s vs "
              f"per-slot {bt['per_slot']['tok_s']} tok/s -> "
              f"{bt['batched_vs_per_slot_speedup']}x "
              f"(gate >= {SPEEDUP_GATE}x)")
    if "fused" in record:
        fu = record["fused"]
        print(f"fused decode x{FUSED_STEPS}: {fu['fused']['tok_s']} tok/s "
              f"({fu['fused']['dispatches_per_token']} dispatches/token) vs "
              f"stepwise {fu['stepwise']['tok_s']} tok/s -> "
              f"{fu['fused_speedup']}x (gate >= {FUSED_GATE}x)")
    if "paged" in record:
        pg = record["paged"]
        op, pr = pg["open"], pg["pressured"]
        print(f"paged: {pg['requests']} requests, {op['tok_s']} tok/s warm, "
              f"peak concurrency {op['peak_concurrency']} "
              f"(gate >= {PAGED_CONCURRENCY_GATE}), admission p99 "
              f"{op['admission_p99_s']}s (gate <= {PAGED_ADM_P99_GATE}s), "
              f"kv util {op['kv_util_mean']}; pressured pool "
              f"({pr['num_blocks']} blocks): {pr['preemptions']} preemptions, "
              f"{pr['failed_allocs']} failed allocs, "
              f"{pr['completed']}/{pr['requests']} completed")
    status = "PASS" if record["gate"]["pass"] else "FAIL"
    print(f"# gate: {record['gate']['checks']} -> {status}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "serve_adapt.json").write_text(json.dumps(record, indent=1))
    if args.json is not None:
        args.json.write_text(json.dumps(record, indent=1))
        print(f"# wrote {args.json}")
    return 0 if (record["gate"]["pass"] or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
