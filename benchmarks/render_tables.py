"""Render the EXPERIMENTS.md roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.render_tables [--dir DIR]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def render(d: Path) -> str:
    cells = {}
    for f in d.glob("*.json"):
        j = json.loads(f.read_text())
        cells[(j["arch"], j["shape"], j["mesh"])] = j
    archs = sorted({k[0] for k in cells})
    out = []
    out.append("### Single-pod (16x16 = 256 chips) baseline roofline table\n")
    out.append("| arch | shape | kind | compute_s | memory_s | collective_s "
               "| dominant | MODEL_FLOPS/HLO | fraction | fits HBM* |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPES:
            j = cells.get((a, s, "single"))
            if j is None:
                continue
            if j["status"] == "skip":
                out.append(f"| {a} | {s} | — | — | — | — | SKIP "
                           "(full attention @512k) | — | — | — |")
                continue
            m = j["memory"]
            out.append(
                f"| {a} | {s} | {j['kind']} | {j['compute_s']:.3f} | "
                f"{j['memory_s']:.3f} | {j['collective_s']:.3f} | "
                f"{j['dominant'].replace('_s','')} | "
                f"{j['useful_flops_ratio']:.2f} | "
                f"{j['roofline_fraction']:.4f} | "
                f"{'yes' if m['fits_hbm_tpu_adjusted'] else 'NO'} "
                f"({m['peak_bytes_tpu_adjusted']/1e9:.1f} GB) |")
    out.append("")
    out.append("### Multi-pod (2x16x16 = 512 chips) — compile proof + terms\n")
    out.append("| arch | shape | status | dominant | bound_s | fraction |")
    out.append("|---|---|---|---|---|---|")
    for a in archs:
        for s in SHAPES:
            j = cells.get((a, s, "multi"))
            if j is None:
                continue
            if j["status"] == "skip":
                out.append(f"| {a} | {s} | skip | — | — | — |")
            else:
                out.append(f"| {a} | {s} | ok | "
                           f"{j['dominant'].replace('_s','')} | "
                           f"{j['bound_s']:.3f} | "
                           f"{j['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun_final")
    args = ap.parse_args()
    print(render(Path(args.dir)))


if __name__ == "__main__":
    main()
