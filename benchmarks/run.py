"""Benchmark harness — one function per paper table/figure + framework
tables.  Prints ``name,us_per_call,derived`` CSV rows (harness contract)
and writes detailed tables under benchmarks/results/.

Paper artifacts reproduced:
  * chunk_tables        — chunk-size sequences per scheduler (the paper's
                          Fig. 1/§2 taxonomy made concrete)
  * interface_equiv     — Fig. 2: lambda-style == declare-style == builtin
  * makespan            — the qualitative claims of refs [8,15,26,31]:
                          scheduler × workload-distribution matrix
  * overhead            — per-dequeue scheduling overhead (the GSS/FSC
                          tradeoff axis)
Framework tables:
  * packing             — UDS document packing vs first-fit
  * moe_capacity        — WF2 capacity planning vs uniform (drop rates)
  * straggler           — AWF mitigation under a slow host
  * plan_engine         — vectorized-vs-generic planning speedup, plan
                          cache hit rate, and hier(...) composition
                          overhead (see plan_engine.py)
  * roofline            — per-cell dry-run terms (reads dryrun JSONs)
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"


def _timeit(fn, n=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------- tables
def chunk_tables() -> list:
    from repro.core import plan_schedule, resolve
    rows = []
    out = {}
    for name in ("static", "dynamic", "guided", "tss", "fac2", "wf2",
                 "awf_b", "af", "rand", "fsc"):
        sched = resolve(name)
        us = _timeit(lambda: plan_schedule(resolve(name), 1000, 8))
        plan = plan_schedule(sched, 1000, 8)
        sizes = [c.size for c in plan.chunks]
        out[name] = sizes[:12]
        rows.append((f"chunk_table/{name}", us,
                     f"n_chunks={len(sizes)};first={sizes[0]};last={sizes[-1]}"))
    (RESULTS / "chunk_tables.json").write_text(json.dumps(out, indent=1))
    return rows


def interface_equiv() -> list:
    """Paper Fig. 2: the same mystatic under both interface styles."""
    import sys
    sys.path.insert(0, str(Path(__file__).parent.parent / "tests"))
    import test_interfaces as TI  # reuse the exact Fig. 2 code
    from repro.core import LoopSpec, plan_waves
    from repro.core.schedulers import StaticChunk
    from repro.core import declare

    if "bench_mystatic" not in declare.registered_schedules():
        declare.declare_schedule(
            "bench_mystatic", arguments=1,
            init=declare.call(TI.my_init, declare.OMP_LB, declare.OMP_UB,
                              declare.OMP_INCR, declare.OMP_CHUNKSZ,
                              declare.OMP_NUM_WORKERS, declare.ARG(0)),
            next=declare.call(TI.my_next, declare.OMP_LB_CHUNK,
                              declare.OMP_UB_CHUNK, declare.OMP_CHUNK_INCR,
                              declare.ARG(0)),
            fini=declare.call(TI.my_fini, declare.ARG(0)))

    loop = LoopSpec(lb=0, ub=1003, num_workers=4, chunk=16)
    lr = TI.LoopRecord()
    dec = plan_waves(declare.use_schedule("bench_mystatic", lr), loop)
    builtin = plan_waves(StaticChunk(chunk=16), loop)
    match = dec.chunks == builtin.chunks
    us = _timeit(lambda: plan_waves(StaticChunk(chunk=16), loop))
    return [("interface_equiv/declare_vs_builtin", us, f"identical={match}")]


def makespan() -> list:
    """Scheduler × workload matrix (virtual-time makespans, P=8)."""
    from repro.core import LoopSpec, resolve, simulate_loop
    rng = np.random.default_rng(0)
    n, p = 2000, 8
    workloads = {
        "constant": np.ones(n),
        "uniform": rng.uniform(0.5, 1.5, n),
        "exponential": rng.exponential(1.0, n),
        "lognormal": rng.lognormal(0.0, 1.5, n),
        "bimodal": np.where(rng.random(n) < 0.1, 10.0, 1.0),
        "increasing": np.linspace(0.1, 2.0, n),
    }
    scheds = ("static", "dynamic", "guided", "tss", "tfss", "taper",
              "fac2", "awf_b", "af", "fsc", "static_steal")
    table = {}
    rows = []
    for wname, costs in workloads.items():
        table[wname] = {}
        for sname in scheds:
            res = simulate_loop(resolve(sname),
                                LoopSpec(0, n, num_workers=p,
                                         loop_id=f"{wname}-{sname}"),
                                costs, overhead=1e-4)
            table[wname][sname] = round(res.makespan, 4)
        best = min(table[wname], key=table[wname].get)
        rows.append((f"makespan/{wname}", 0.0,
                     f"best={best};static={table[wname]['static']};"
                     f"best_val={table[wname][best]}"))
    (RESULTS / "makespan.json").write_text(json.dumps(table, indent=1))
    return rows


def overhead() -> list:
    """Per-dequeue cost of each scheduler implementation (host-side),
    measured through the engine's ScheduleStream."""
    from repro.core import LoopSpec, SchedulerContext, get_engine, resolve
    rows = []
    for name in ("static", "dynamic", "guided", "fac2", "awf_c", "af"):
        loop = LoopSpec(lb=0, ub=10_000, num_workers=8, loop_id=name)

        def drain():
            stream = get_engine().open_stream(
                resolve(name), SchedulerContext(loop=loop))
            w = 0
            while stream.next(w % 8, 0.001) is not None:
                w += 1
            stream.close()
            return w

        n_deq = drain()
        us = _timeit(drain, n=3)
        rows.append((f"overhead/{name}", us / max(n_deq, 1),
                     f"dequeues={n_deq}"))
    return rows


def packing() -> list:
    from repro.data import pack_documents
    from repro.sched import pack_with_scheduler
    rng = np.random.default_rng(0)
    rows = []
    for sigma in (0.5, 1.0, 1.5):
        docs = [rng.integers(1, 100, size=int(l)).astype(np.int32)
                for l in np.clip(rng.lognormal(5.0, sigma, 128), 8, 2048)]
        ff = pack_documents(docs, 8, 2048).fill_fraction
        uds = pack_with_scheduler("static_steal,1",
                                  docs, 8, 2048).fill_fraction
        rows.append((f"packing/sigma={sigma}", 0.0,
                     f"first_fit={ff:.3f};uds={uds:.3f}"))
    return rows


def moe_capacity_bench() -> list:
    from repro.configs import get_config
    from repro.sched import CapacityPlanner
    cfg = get_config("qwen3-moe-235b-a22b")
    rows = []
    for skew in (1.0, 2.0, 8.0):
        pl = CapacityPlanner(cfg, 4096)
        E = cfg.num_experts
        load = np.ones(E)
        load[: E // 8] *= skew
        load /= load.sum()
        for _ in range(8):
            pl.observe(np.tile(load, (4, 1)))
        cap = pl.plan()
        uniform = np.full(E, pl.C, np.int32)
        d_uds = pl.drop_rate(np.tile(load, (4, 1)), cap)
        d_uni = pl.drop_rate(np.tile(load, (4, 1)), uniform)
        rows.append((f"moe_capacity/skew={skew}", 0.0,
                     f"drop_uniform={d_uni:.4f};drop_wf2={d_uds:.4f}"))
    return rows


def straggler() -> list:
    from repro.sched import StragglerMitigator
    m = StragglerMitigator(num_hosts=8)
    rng = np.random.default_rng(0)
    for _ in range(32):
        times = {h: 1.0 + 0.02 * rng.standard_normal() for h in range(8)}
        times[5] *= 1.4                        # host 5 is slow
        m.observe_step(times)
    w = m.weights()
    shares = m.token_shares(1_000_000)
    return [("straggler/awf", 0.0,
             f"flagged={m.stragglers()};w_slow={w[5]:.3f};"
             f"share_slow={shares[5]};share_fast={shares[0]}")]


def roofline() -> list:
    """Summarize dry-run JSONs (single-pod baseline table)."""
    rows = []
    d = RESULTS / "dryrun_final"
    if not d.exists():
        d = RESULTS / "dryrun"
    for f in sorted(d.glob("*_single.json")) if d.exists() else []:
        j = json.loads(f.read_text())
        if j.get("status") != "ok":
            continue
        rows.append((
            f"roofline/{j['arch']}/{j['shape']}", 0.0,
            f"dom={j['dominant']};bound_s={j['bound_s']:.3f};"
            f"frac={j['roofline_fraction']:.4f}"))
    return rows


def kernels() -> list:
    """Interpret-mode kernel timings (correctness-path cost, not TPU perf)."""
    import jax.numpy as jnp
    from repro.kernels.sched_matmul.ops import scheduled_matmul
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    us = _timeit(lambda: scheduled_matmul(a, b, block_k=128,
                                          interpret=True).block_until_ready(),
                 n=3)
    return [("kernels/sched_matmul_interpret", us, "shape=256x128x128")]


def plan_engine() -> list:
    import sys
    sys.path.insert(0, str(Path(__file__).parent))
    import plan_engine as pe
    return (pe.planning_speedup() + pe.cache_hit_rate()
            + pe.composed_overhead())


def serve_adapt() -> list:
    """Telemetry -> history -> replan loop (executor stage; the full serve
    stage runs via ``python benchmarks/serve_adapt.py``)."""
    import sys
    sys.path.insert(0, str(Path(__file__).parent))
    import serve_adapt as sa
    return sa.rows(skip_serve=True)


def train_straggler() -> list:
    """Multi-host AWF share convergence (pure-host stage; the real
    4-emulated-host train stage runs via
    ``python benchmarks/train_straggler.py``)."""
    import sys
    sys.path.insert(0, str(Path(__file__).parent))
    import train_straggler as ts
    return ts.rows(skip_train=True)


def main() -> None:
    RESULTS.mkdir(exist_ok=True)
    all_rows = []
    for fn in (chunk_tables, interface_equiv, makespan, overhead, packing,
               moe_capacity_bench, straggler, plan_engine, serve_adapt,
               train_straggler, kernels, roofline):
        try:
            all_rows.extend(fn())
        except Exception as e:  # pragma: no cover
            all_rows.append((f"{fn.__name__}/ERROR", 0.0, repr(e)[:80]))
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
