import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

# NOTE: the env var above MUST be set before any jax import (jax locks the
# device count on first init) — the same contract as launch/dryrun.py.

_DOC = """Multi-host straggler benchmark: AWF token shares rebalance the train loop.

The acceptance criterion of the multi-host TrainLoop as numbers: with 4
emulated hosts and one host slowed 2x, the plan -> execute -> measure ->
replan loop must demonstrably rebalance the uneven batch split.  Two
stages, serialized machine-readably (CI: ``--json BENCH_train.json``
uploaded as an artifact, ``--gate`` as the exit code):

1. **Share convergence** (pure host, no JAX): a ``StragglerMitigator`` fed
   synthetic per-host step times with one 2x-slow host.  Tracks the cold
   start (exact uniform shares before any measurement — the regression the
   cold-start fix locks), the slow host's share trajectory, and the
   converged fraction vs the ideal ``(1/2) / 3.5``.

2. **Train loop** (real model, 4 emulated CPU hosts): TWO ``TrainLoop``
   runs over the SAME seed/data — one adaptive (AWF shares drive
   ``split_batch_by_shares``), one pinned to static even shares
   (``min_host_share=1.0`` floors every host at the even share, making the
   splitter a no-op) — with ``host_skew`` injecting the 2x-slow host into
   the per-host time attribution.  Per step both loops report a VIRTUAL
   makespan ``max_h(tokens_h * skew_h)`` in token-cost units (masked
   tokens cost nothing on a real slow host's feed; wall time on the
   emulator cannot show this, exactly like ``serve_adapt``'s virtual
   executor stage).  The gate: steady-state recovery
   ``static_makespan / adaptive_makespan >= 1.3`` — the slow host sheds
   enough tokens that the modelled step time beats even splitting by 30%+.

3. **Auto selection** (pure host, no JAX): the same 2x-slow-host share
   loop driven with ``--straggler-scheduler``-style fixed clauses and with
   ``schedule(auto)``.  Reported: per-clause steady-state virtual step
   time, auto's per-step candidate tags, and
   ``auto_vs_best_fixed_ratio`` (best fixed steady / auto's), gated
   ``>= 0.9`` — auto must land within 10% of the best hand-picked clause.
"""
# ^ a named constant, not __doc__: the XLA env setup must be the module's
# first statements, and a docstring cannot follow them

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"

HOSTS = 4
SLOW_HOST = 3
SLOW_FACTOR = 2.0
RECOVERY_GATE = 1.3    # steady-state step-time recovery vs even shares
AUTO_RATIO_GATE = 0.9  # auto must reach >= 90% of the best fixed clause
AUTO_CLAUSES = ("wf2", "static", "fac2", "awf")


def shares_convergence(steps: int = 12, total: int = 4096) -> dict:
    """Pure-host stage: observe_step with a synthetic 2x-slow host."""
    from repro.sched import StragglerMitigator

    m = StragglerMitigator(num_hosts=HOSTS, min_share=0.1)
    shares = m.token_shares(total)
    cold = shares.tolist()
    traj = [round(float(shares[SLOW_HOST]) / total, 4)]
    rate = 1e-4                       # nominal seconds per token
    for _ in range(steps):
        times = {h: float(shares[h]) * rate
                 * (SLOW_FACTOR if h == SLOW_HOST else 1.0)
                 for h in range(HOSTS)}
        m.observe_step(times, host_tokens={h: max(int(shares[h]), 1)
                                           for h in range(HOSTS)})
        shares = m.token_shares(total)
        traj.append(round(float(shares[SLOW_HOST]) / total, 4))
    ideal = (1.0 / SLOW_FACTOR) / (HOSTS - 1 + 1.0 / SLOW_FACTOR)
    base, rem = divmod(total, HOSTS)
    uniform = [base + 1] * rem + [base] * (HOSTS - rem)
    return {
        "total_tokens": total,
        "cold_start_shares": cold,
        "cold_start_uniform": cold == uniform,
        "slow_frac": traj,                  # slow host's share per step
        "ideal_frac": round(ideal, 4),
        "converged": abs(traj[-1] - ideal) < 0.05,
        "epochs": m.epoch(),
    }


def auto_selection(steps: int = 16, total: int = 2048,
                   steady_k: int = 4) -> dict:
    """schedule(auto) as the straggler scheduler vs fixed clauses.

    Virtual scenario: per-host step time = share x skew, so the steady
    virtual makespan of each clause is exactly the step time its shares
    buy, and the ratio isolates the selection quality."""
    from repro.sched import StragglerMitigator

    def drive(clause: str) -> dict:
        m = StragglerMitigator(num_hosts=HOSTS, scheduler=clause,
                               min_share=0.1)
        makespans, tags = [], []
        for _ in range(steps):
            shares = m.token_shares(total)
            times = {h: float(shares[h])
                     * (SLOW_FACTOR if h == SLOW_HOST else 1.0)
                     for h in range(HOSTS)}
            m.observe_step(times, {h: max(int(shares[h]), 1)
                                   for h in range(HOSTS)})
            makespans.append(round(max(times.values()), 1))
            tags.append(m._share_tag)
        return {"makespan": makespans, "selected": tags,
                "steady_makespan": round(
                    sum(makespans[-steady_k:]) / steady_k, 1)}

    fixed = {c: drive(c) for c in AUTO_CLAUSES}
    auto = drive("auto")
    best_clause = min(fixed, key=lambda c: fixed[c]["steady_makespan"])
    best = fixed[best_clause]["steady_makespan"]
    ratio = round(best / max(auto["steady_makespan"], 1e-9), 3)
    return {
        "total_tokens": total,
        "steps": steps,
        "slow_host": SLOW_HOST,
        "slow_factor": SLOW_FACTOR,
        "fixed_steady": {c: fixed[c]["steady_makespan"] for c in fixed},
        "best_fixed": best_clause,
        "auto": auto,
        "auto_vs_best_fixed_ratio": ratio,
        "auto_ratio_gate": AUTO_RATIO_GATE,
    }


def train_straggler(arch: str = "qwen2.5-3b", steps: int = 12,
                    batch: int = 16, seq_len: int = 128,
                    data_sigma: float = 0.5, steady_k: int = 4) -> dict:
    """Real multi-host train loops: adaptive AWF shares vs static even."""
    import jax

    if jax.device_count() < HOSTS:
        raise SystemExit(f"needs {HOSTS} devices; run with XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={HOSTS}")
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainLoop

    cfg = get_smoke_config(arch)
    skew = np.ones(HOSTS)
    skew[SLOW_HOST] = SLOW_FACTOR

    def drive(min_host_share: float) -> dict:
        # 4 rows per host + a tight document-length spread keep the host
        # BLOCKS token-balanced, so the measured imbalance is the injected
        # host slowdown, not packing noise
        loop = TrainLoop(cfg, batch=batch, seq_len=seq_len, seed=0,
                         hosts=HOSTS, host_skew=skew,
                         data_sigma=data_sigma,
                         min_host_share=min_host_share)
        makespans, slow_frac, losses = [], [], []
        t0 = time.perf_counter()
        for _ in range(steps):
            losses += loop.run(1, log_every=10 ** 9)
            ht = loop._host_tokens.astype(float)
            makespans.append(float((ht * skew).max()))
            slow_frac.append(round(float(ht[SLOW_HOST])
                                   / max(float(ht.sum()), 1.0), 4))
        return {
            "min_host_share": min_host_share,
            "makespan_tokens": [round(m, 1) for m in makespans],
            "slow_frac": slow_frac,
            "steady_makespan": round(float(np.mean(makespans[-steady_k:])),
                                     1),
            "final_loss": round(losses[-1], 4),
            "losses_finite": bool(np.isfinite(losses).all()),
            "epochs": loop.mitigator.epoch(),
            "stragglers": loop.mitigator.stragglers(),
            "wall_s": round(time.perf_counter() - t0, 3),
        }

    adaptive = drive(min_host_share=0.1)
    static = drive(min_host_share=1.0)     # even-share floor: splitter no-op
    recovery = round(static["steady_makespan"]
                     / max(adaptive["steady_makespan"], 1e-9), 3)
    return {
        "arch": arch,
        "hosts": HOSTS,
        "slow_host": SLOW_HOST,
        "slow_factor": SLOW_FACTOR,
        "steps": steps,
        "batch": batch,
        "seq_len": seq_len,
        "data_sigma": data_sigma,
        "adaptive": adaptive,
        "static": static,
        "rebalance_ratio": round(static["slow_frac"][-1]
                                 / max(adaptive["slow_frac"][-1], 1e-9), 3),
        "recovered_step_time": recovery,
        "recovery_gate": RECOVERY_GATE,
    }


def collect(skip_train: bool = False) -> dict:
    record: dict = {"bench": "train_straggler",
                    "shares": shares_convergence(),
                    "auto": auto_selection()}
    sh = record["shares"]
    au = record["auto"]
    checks = {
        "cold_start_uniform": sh["cold_start_uniform"],
        "shares_converged": sh["converged"],
        "shares_epoch_advanced": sh["epochs"] >= 1,
        "auto_ratio_gate": au["auto_vs_best_fixed_ratio"] >= AUTO_RATIO_GATE,
    }
    if not skip_train:
        record["train"] = train_straggler()
        tr = record["train"]
        checks["train_losses_finite"] = (tr["adaptive"]["losses_finite"]
                                         and tr["static"]["losses_finite"])
        checks["train_epoch_per_step"] = (tr["adaptive"]["epochs"]
                                          == tr["steps"])
        checks["slow_host_flagged"] = SLOW_HOST in tr["adaptive"][
            "stragglers"]
        checks["slow_share_dropped"] = bool(
            tr["adaptive"]["slow_frac"][-1]
            < tr["static"]["slow_frac"][-1] - 0.03)
        checks["recovery_gate"] = bool(tr["recovered_step_time"]
                                       >= RECOVERY_GATE)
    record["gate"] = {"checks": checks, "pass": all(checks.values())}
    return record


def rows(skip_train: bool = True) -> list:
    """Harness contract: ``name,us_per_call,derived`` rows for run.py."""
    rec = collect(skip_train=skip_train)
    sh = rec["shares"]
    out = [("train_straggler/shares", 0.0,
            f"slow_frac={sh['slow_frac'][0]}->{sh['slow_frac'][-1]};"
            f"ideal={sh['ideal_frac']}")]
    au = rec["auto"]
    out.append(("train_straggler/auto", 0.0,
                f"ratio={au['auto_vs_best_fixed_ratio']};"
                f"best={au['best_fixed']};"
                f"selected={au['auto']['selected'][-1]}"))
    if "train" in rec:
        tr = rec["train"]
        out.append(("train_straggler/train", 0.0,
                    f"recovery={tr['recovered_step_time']};"
                    f"rebalance={tr['rebalance_ratio']}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the machine-readable record here "
                         "(CI: BENCH_train.json)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 unless the multi-host loop demonstrably "
                         "rebalanced off the injected slow host")
    ap.add_argument("--skip-train", action="store_true",
                    help="share-convergence stage only (no JAX model)")
    args = ap.parse_args(argv)

    record = collect(skip_train=args.skip_train)
    sh = record["shares"]
    print(f"shares: slow-host fraction {sh['slow_frac'][0]} -> "
          f"{sh['slow_frac'][-1]} (ideal {sh['ideal_frac']}), "
          f"cold start uniform: {sh['cold_start_uniform']}")
    au = record["auto"]
    print(f"auto: steady {au['auto']['steady_makespan']} vs best fixed "
          f"'{au['best_fixed']}' {au['fixed_steady'][au['best_fixed']]} -> "
          f"ratio {au['auto_vs_best_fixed_ratio']} "
          f"(gate >= {AUTO_RATIO_GATE}), selected "
          f"{au['auto']['selected'][0]} -> {au['auto']['selected'][-1]}")
    if "train" in record:
        tr = record["train"]
        print(f"train: slow-host share {tr['adaptive']['slow_frac'][0]} -> "
              f"{tr['adaptive']['slow_frac'][-1]}, virtual makespan "
              f"{tr['static']['steady_makespan']} (static even) -> "
              f"{tr['adaptive']['steady_makespan']} (AWF) = "
              f"{tr['recovered_step_time']}x recovery "
              f"(gate >= {RECOVERY_GATE}x)")
    status = "PASS" if record["gate"]["pass"] else "FAIL"
    print(f"# gate: {record['gate']['checks']} -> {status}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "train_straggler.json").write_text(
        json.dumps(record, indent=1))
    if args.json is not None:
        args.json.write_text(json.dumps(record, indent=1))
        print(f"# wrote {args.json}")
    return 0 if (record["gate"]["pass"] or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
