"""Learning-rate schedules: cosine, linear, and WSD (warmup-stable-decay,
the MiniCPM schedule — arXiv:2404.06395)."""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

__all__ = ["cosine_schedule", "wsd_schedule", "linear_schedule"]

Schedule = Callable


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return fn


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long constant plateau, short
    exponential-ish (here: linear-in-log) decay tail."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        decay_start = warmup_steps + stable_steps
        prog = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1),
                        0.0, 1.0)
        decay = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < decay_start, peak_lr, decay))
        return out
    return fn


def linear_schedule(peak_lr: float, warmup_steps: int, total_steps: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, peak_lr * (1 - prog))
    return fn
