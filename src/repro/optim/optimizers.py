"""Optimizers, built from scratch in JAX (no optax dependency).

* AdamW — for the ≤32B archs (f32 m/v, decoupled weight decay).
* Adafactor — for grok-1-314B / qwen3-moe-235B: factored second moment
  (row/col statistics for rank≥2 tensors), no first moment by default —
  the PaLM/T5 recipe that keeps optimizer state ~O(params/row) so a 314B
  model fits 16 GB/chip on a 256-chip pod.

Both return ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params, step)
    params = apply(params, updates)  # params + updates

Optimizer state mirrors the parameter tree, so parameter sharding specs
apply verbatim (ZeRO-1 comes free from the 2-D param sharding).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adafactor", "make_optimizer", "global_norm", "clip_by_global_norm"]

Tree = Any


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Tree, max_norm: float) -> Tuple[Tree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ------------------------------------------------------------------- AdamW
def adamw(lr_schedule: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0):
    def init_fn(params: Tree) -> Tree:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update_fn(grads: Tree, state: Tree, params: Tree, step: jax.Array
                  ) -> Tuple[Tree, Tree, Dict[str, jax.Array]]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        stepf = step.astype(jnp.float32) + 1.0
        lr = lr_schedule(step)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** stepf), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** stepf), v)
        updates = jax.tree.map(
            lambda p, mh, vh: (-lr * (mh / (jnp.sqrt(vh) + eps)
                                      + weight_decay * p.astype(jnp.float32))
                               ).astype(p.dtype),
            params, mh, vh)
        return updates, {"m": m, "v": v}, {"grad_norm": gnorm, "lr": lr}

    return init_fn, update_fn


# ---------------------------------------------------------------- Adafactor
def _factored_dims(shape) -> Optional[Tuple[int, int]]:
    """Factor the two largest of the trailing dims (T5 convention: the last
    two axes; leading axes like `layers`/`experts` are batched)."""
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor(lr_schedule: Callable, eps: float = 1e-30,
              decay: float = 0.8, clip_threshold: float = 1.0,
              weight_decay: float = 0.0):
    """Factored Adafactor (Shazeer & Stern 2018), relative-step off,
    momentum off — the memory-lean large-model configuration."""

    def init_fn(params: Tree) -> Tree:
        def make(p):
            if _factored_dims(p.shape) is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            vr = jnp.zeros(p.shape[:-1], jnp.float32)         # row stats
            vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"vr": vr, "vc": vc}
        return {"v": jax.tree.map(make, params)}

    def update_fn(grads: Tree, state: Tree, params: Tree, step: jax.Array
                  ) -> Tuple[Tree, Tree, Dict[str, jax.Array]]:
        stepf = step.astype(jnp.float32) + 1.0
        beta = 1.0 - stepf ** (-decay)                        # t^-0.8 schedule
        lr = lr_schedule(step)
        gnorm = global_norm(grads)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "v" in v:
                vnew = beta * v["v"] + (1 - beta) * g2
                precond = g * jax.lax.rsqrt(vnew)
                vout = {"v": vnew}
            else:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc)
                precond = g * rfac[..., None] * cfac[..., None, :]
                vout = {"vr": vr, "vc": vc}
            # update clipping (RMS(update) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            delta = -lr * precond
            if weight_decay:
                delta = delta - lr * weight_decay * p.astype(jnp.float32)
            return delta.astype(p.dtype), vout

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        updates = treedef.unflatten([u for u, _ in out])
        vnew = treedef.unflatten([v for _, v in out])
        return updates, {"v": vnew}, {"grad_norm": gnorm, "lr": lr}

    return init_fn, update_fn


def make_optimizer(name: str, lr_schedule: Callable, **kw):
    if name == "adamw":
        return adamw(lr_schedule, **kw)
    if name == "adafactor":
        return adafactor(lr_schedule, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
