"""Sharding specs for optimizer state (mirror of the parameter specs)."""

from __future__ import annotations

from typing import Any

import jax

from repro.optim.optimizers import _factored_dims

__all__ = ["opt_state_specs"]


def opt_state_specs(name: str, params: Any, specs: Any) -> Any:
    """Logical-axes trees for the optimizer state of ``params``.

    AdamW m/v inherit the parameter spec verbatim (ZeRO-1 via the 2-D param
    sharding).  Adafactor row/col stats drop the reduced axis.
    """
    if name == "adamw":
        return {"m": specs, "v": specs}
    if name == "adafactor":
        flat_p, treedef = jax.tree.flatten(params)
        flat_s = treedef.flatten_up_to(specs)

        def make(p, s):
            if _factored_dims(p.shape) is None:
                return {"v": s}
            return {"vr": tuple(s[:-1]), "vc": tuple(s[:-2]) + (s[-1],)}

        return {"v": treedef.unflatten(
            [make(p, s) for p, s in zip(flat_p, flat_s)])}
    raise KeyError(f"unknown optimizer {name!r}")
