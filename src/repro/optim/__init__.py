from repro.optim.optimizers import (
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from repro.optim.schedules import cosine_schedule, linear_schedule, wsd_schedule

__all__ = [
    "adamw", "adafactor", "make_optimizer", "global_norm",
    "clip_by_global_norm", "cosine_schedule", "wsd_schedule",
    "linear_schedule",
]
