"""WF2/AWF-planned MoE expert capacities.

Experts are units of processing with *measured* load (the fraction of
tokens routed to each, returned by moe_ffn — the end-loop-body measurement);
the capacity vector for the next step is planned by weighted factoring
through the PlanEngine: the slot budget (E · C iterations) is scheduled
over the experts (workers) with capability weights = normalized EWMA
loads, and each expert's capacity is its ``worker_iters`` share of the
plan.  Persistently-hot experts get more slots, cold experts fewer, under
a fixed total budget — reducing token dropping at equal memory.  Identical
load vectors across steps hit the engine's plan cache.

This is the paper's heterogeneous-workers story (WF2 "can employ workload
balancing information specified by the user") executing inside an MoE
layer.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import LoopSpec, get_engine
from repro.core.spec import SpecLike, resolve
from repro.models.config import ModelConfig
from repro.models.moe import moe_buffer_capacity, moe_capacity

__all__ = ["CapacityPlanner"]


class CapacityPlanner:
    """Plans per-expert capacities from an EWMA of measured loads.

    ``scheduler`` selects the weight-aware strategy that distributes the
    slot budget over experts (spec / clause string / instance); the
    default preserves the WF2 behavior.
    """

    def __init__(self, cfg: ModelConfig, seq_len: int,
                 ewma: float = 0.9, floor: float = 0.25,
                 scheduler: SpecLike = "wf2"):
        self.cfg = cfg
        self.C = moe_capacity(cfg, seq_len)              # uniform budget / expert
        self.C_buf = moe_buffer_capacity(cfg, seq_len)   # hard buffer bound
        self.ewma = ewma
        self.floor = floor
        self.scheduler = scheduler
        self.load: Optional[np.ndarray] = None           # (E,) EWMA of loads

    def observe(self, loads: np.ndarray) -> None:
        """loads: (L, E) per-layer routed fractions from the train step."""
        mean = np.asarray(loads).mean(axis=0)
        if self.load is None:
            self.load = mean
        else:
            self.load = self.ewma * self.load + (1 - self.ewma) * mean

    def plan(self) -> np.ndarray:
        """(E,) int32 capacities: capability weights = normalized expert
        loads; slot budget = E * C (same as uniform), hot experts may rise
        to the buffer bound C_buf = C * headroom."""
        E = self.cfg.num_experts
        if self.load is None:
            return np.full(E, self.C, np.int32)
        w = self.load / max(self.load.mean(), 1e-9)        # mean 1.0
        w = np.clip(w, self.floor, None)
        # weight-aware plan over the slot budget: experts are the
        # workers, slots the iterations; capacities = per-worker shares
        loop = LoopSpec(lb=0, ub=E * self.C, num_workers=E,
                        loop_id="moe_capacity")
        plan = get_engine().plan(
            resolve(self.scheduler), loop,
            weights=(w * E / w.sum()).tolist())       # normalized to sum E
        cap = plan.worker_iters()
        return np.clip(cap, 1, self.C_buf).astype(np.int32)

    def drop_rate(self, loads: np.ndarray, cap: np.ndarray) -> float:
        """Expected fraction of routed tokens dropped under ``cap`` given
        observed per-layer loads (diagnostic for benchmarks)."""
        E = self.cfg.num_experts
        # loads are fractions of all routed slots; scale to the slot budget
        tokens = np.asarray(loads) * E * self.C
        overflow = np.clip(tokens - cap[None, :], 0, None)
        return float(overflow.sum() / max(tokens.sum(), 1e-9))
