"""AWF straggler mitigation: adaptive re-weighting of per-host work.

The paper's adaptive weighted factoring, doing real systems work: hosts
(data-parallel workers) report step times; AWF weights derived from the
history re-balance the *document/token assignment* produced by the packing
scheduler, so a slow host (thermal throttling, a flaky NIC, a dying HBM
channel) receives proportionally less work instead of stalling the
all-reduce for everyone.

This is plan–execute–measure at the pod level: the UDS history object IS
the straggler detector.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                        MembershipEvent, get_engine)
from repro.core.engine import schedule_tag
from repro.core.history import awf_weights_from_rates
from repro.core.spec import resolve

__all__ = ["StragglerMitigator"]


@dataclasses.dataclass
class StragglerMitigator:
    """``scheduler`` selects the strategy that turns AWF weights into
    integer token shares — any weight-aware schedule clause (spec, clause
    string, or instance); the default preserves the WF2 behavior.

    ``min_share`` guarantees every host a floor of the even share
    (fraction in [0, 1]): a host must keep receiving SOME work or its
    rate is never measured again and it can never rehabilitate."""

    num_hosts: int
    loop_id: str = "train_step"
    threshold: float = 1.15      # flag hosts >15% slower than median
    window: int = 16
    scheduler: Any = "wf2"       # SpecLike; must honor ctx.weights
    min_share: float = 0.0       # per-host floor, as a fraction of total/P

    def __post_init__(self):
        self.history = LoopHistory()
        self.telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                       num_workers=self.num_hosts)
        self._step = 0
        # provenance of the shares the NEXT observe_step measures: which
        # schedule produced them (schedule(auto) scores candidates by it)
        self._share_tag: Optional[str] = None
        # invocation index of the last membership change: rate windows
        # never reach past it (old-team measurements carry dead ids and
        # pre-churn speed ratios)
        self._churn_floor = 0
        # the plan behind the last scheduler-produced shares (None on the
        # exact-uniform path) — its chunk→worker provenance is what a
        # membership-loss requeue recovers the dead hosts' tokens from
        self.last_plan = None
        self.membership_events: List[MembershipEvent] = []

    # --------------------------------------------------------- membership
    def resize(self, new_num_hosts: int, *, lost=(),
               step: Optional[int] = None) -> MembershipEvent:
        """Membership change: re-point every statistic at the new team.

        Records a :class:`MembershipEvent` sentinel through the telemetry
        (one measured-epoch bump, so cached adaptive share plans for this
        loop are invalidated and the next ``token_shares`` re-runs the
        scheduler's ``init`` over the new team size), floors the rate
        window at the churn (surviving hosts are renumbered densely, so
        pre-churn measurements attribute to the wrong ids), and drops the
        share provenance (the old plan's team no longer exists).
        """
        if new_num_hosts < 1:
            raise ValueError(f"new_num_hosts must be >= 1, "
                             f"got {new_num_hosts}")
        old = self.num_hosts
        kind = "loss" if new_num_hosts <= old else "join"
        lost = tuple(sorted(int(h) for h in lost))
        if kind == "loss" and not lost and new_num_hosts < old:
            # unspecified casualties: assume the highest ids left
            lost = tuple(range(new_num_hosts, old))
        joined = (tuple(range(old, new_num_hosts))
                  if kind == "join" else ())
        event = MembershipEvent(kind=kind, old_size=old,
                                new_size=new_num_hosts, lost=lost,
                                joined=joined, step=step)
        self.num_hosts = new_num_hosts
        self.telemetry.record_membership(event)   # epoch bump + team width
        self._churn_floor = self.history.num_invocations(self.loop_id)
        self._share_tag = None
        self.last_plan = None
        self.membership_events.append(event)
        return event

    # ------------------------------------------------------------ measure
    def observe_step(self, host_times: Dict[int, float],
                     host_tokens: Optional[Dict[int, int]] = None) -> None:
        """Record one training step's per-host wall times through the
        telemetry recorder: each step flushes as one measured invocation,
        advancing the history epoch that invalidates cached adaptive
        plans keyed on this mitigator's history."""
        bad = [h for h in host_times if not 0 <= int(h) < self.num_hosts]
        if bad:
            # a caller still sized for the dead fleet: refusing beats
            # silently attributing times to hosts that no longer exist
            raise ValueError(
                f"host ids {sorted(bad)} outside the current team "
                f"0..{self.num_hosts - 1} (resize() the mitigator after "
                f"a membership change)")
        self.history.open_invocation(self.loop_id, scheduler=self._share_tag)
        for h, t in host_times.items():
            n = (host_tokens or {}).get(h, 1)
            self.telemetry.record_chunk(h, 0, n, t, tokens=n)
        self.telemetry.flush()
        self._step += 1

    def epoch(self) -> int:
        """Measured epoch — how many flushed step observations the plan
        cache has seen for this loop."""
        return self.telemetry.epoch()

    # ------------------------------------------------------------- detect
    def stragglers(self) -> List[int]:
        """Hosts whose step-mean rate exceeds ``threshold`` x the median —
        the same windowed, equal-step aggregation the weights use, so
        detection and planning cannot disagree about who is slow."""
        rates = self._step_mean_rates()
        if len(rates) < 2:
            return []
        med = float(np.median(list(rates.values())))
        return [h for h, r in rates.items() if r > self.threshold * med]

    # --------------------------------------------------------------- plan
    def _step_mean_rates(self) -> Dict[int, float]:
        """Per-host mean seconds/iteration where every STEP contributes
        equally (unlike ``LoopHistory.worker_rates``, which token-weights
        across invocations).  Step costs are heteroscedastic — the compile
        step is ~100x a steady step — so token-weighting aliases per-step
        token-count variance into the rates: a host holding more tokens in
        an expensive step looks slower forever.  Equal-step means keep the
        rate RATIOS exactly the per-host slowdown ratios."""
        per: Dict[int, List[float]] = {}
        invs = self.history.invocations(self.loop_id)
        # the window never reaches past the last membership change: the
        # surviving team is renumbered densely, so pre-churn records
        # attribute to the wrong (possibly dead) host ids
        lo = max(len(invs) - self.window, self._churn_floor)
        for inv in invs[lo:]:
            for c in inv.chunks:
                if (c.elapsed is not None and c.size > 0
                        and 0 <= c.worker < self.num_hosts):
                    per.setdefault(c.worker, []).append(c.elapsed / c.size)
        return {h: sum(rs) / len(rs) for h, rs in per.items() if rs}

    def weights(self) -> np.ndarray:
        """AWF capability weights from the step-mean rates
        (``awf_weights_from_rates``) — feed these to a weight-aware
        packing schedule (e.g. "wf2") or the batch splitter.  Always
        finite: before any ``observe_step`` (or on a degenerate all-zero
        history) every host gets exactly 1.0; never-measured hosts get the
        mean speed."""
        return np.asarray(awf_weights_from_rates(self._step_mean_rates(),
                                                 self.num_hosts))

    def min_share_floor(self, total_tokens: int) -> int:
        """The effective integer per-host floor for ``total_tokens``:
        ``min_share`` of the even share, never above the even share itself
        — so ``num_hosts`` floors always fit inside the budget."""
        if total_tokens <= 0:
            return 0
        frac = float(np.clip(self.min_share, 0.0, 1.0))
        return min(int(frac * total_tokens / self.num_hosts),
                   total_tokens // self.num_hosts)

    def _uniform_shares(self, total_tokens: int) -> np.ndarray:
        """Exact uniform partition: base share everywhere, the remainder
        spread deterministically over the lowest host ids."""
        base, rem = divmod(total_tokens, self.num_hosts)
        shares = np.full(self.num_hosts, base, np.int64)
        shares[:rem] += 1
        return shares

    def token_shares(self, total_tokens: int) -> np.ndarray:
        """Integer per-host token budgets proportional to AWF weights,
        materialized as a plan of ``self.scheduler`` (default WF2) over
        the token budget (hosts are the workers) — the plan covers
        exactly, so shares always sum to ``total_tokens``, and identical
        weight vectors hit the engine's plan cache across steps.

        Cold start (no ``observe_step`` yet) and measured-uniform
        histories return the EXACT uniform partition rather than the
        scheduler's chunk-shaped approximation of it: uniform shares are
        the identity the multi-host equivalence guarantee rests on
        (``split_batch_by_shares`` must be a no-op), so float-rounding
        noise in the weights must not perturb them.  ``min_share`` is
        enforced afterwards by reclaiming tokens from the richest hosts
        (sum-preserving)."""
        if total_tokens <= 0:
            return np.zeros(self.num_hosts, np.int64)
        w = self.weights()
        if np.abs(w - 1.0).max() < 1e-9:
            # exact-uniform shares are produced by the identity split, not
            # by the scheduler — leave the step unattributed
            self._share_tag = None
            self.last_plan = None
            shares = self._uniform_shares(total_tokens)
        else:
            loop = LoopSpec(lb=0, ub=total_tokens,
                            num_workers=self.num_hosts,
                            loop_id=f"{self.loop_id}/token_shares")
            sched = resolve(self.scheduler)
            if hasattr(sched, "select"):
                # schedule(auto): run the selection round against THIS
                # mitigator's step history before the plan key is taken,
                # so the cache keys on the selected candidate
                sched.select(self.history, loop, weights=w.tolist())
            self._share_tag = schedule_tag(sched)
            plan = get_engine().plan(sched, loop, weights=w.tolist())
            self.last_plan = plan
            shares = plan.worker_iters().astype(np.int64)
        shares = self._enforce_min_share(shares, total_tokens)
        if shares.shape != (self.num_hosts,) or \
                int(shares.sum()) != total_tokens:
            raise AssertionError(
                f"token shares {shares.tolist()} do not cover "
                f"{total_tokens} tokens over {self.num_hosts} hosts — "
                f"mitigator/team size mismatch after a membership change?")
        return shares

    def _enforce_min_share(self, shares: np.ndarray,
                           total_tokens: int) -> np.ndarray:
        """Raise every host to the floor, reclaiming the added tokens
        from the hosts richest above it — sum-preserving by construction
        (the floor always fits: see ``min_share_floor``)."""
        floor = self.min_share_floor(total_tokens)
        if floor <= 0:
            return shares
        shares = shares.astype(np.int64).copy()
        need = np.maximum(floor - shares, 0)
        pool = int(need.sum())
        if pool == 0:
            return shares
        shares += need
        for i in np.argsort(-shares):
            if pool == 0:
                break
            take = min(int(shares[i]) - floor, pool)
            if take > 0:
                shares[i] -= take
                pool -= take
        return shares
