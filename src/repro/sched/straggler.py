"""AWF straggler mitigation: adaptive re-weighting of per-host work.

The paper's adaptive weighted factoring, doing real systems work: hosts
(data-parallel workers) report step times; AWF weights derived from the
history re-balance the *document/token assignment* produced by the packing
scheduler, so a slow host (thermal throttling, a flaky NIC, a dying HBM
channel) receives proportionally less work instead of stalling the
all-reduce for everyone.

This is plan–execute–measure at the pod level: the UDS history object IS
the straggler detector.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import LoopHistory, LoopSpec, LoopTelemetry, get_engine
from repro.core.spec import resolve

__all__ = ["StragglerMitigator"]


@dataclasses.dataclass
class StragglerMitigator:
    """``scheduler`` selects the strategy that turns AWF weights into
    integer token shares — any weight-aware schedule clause (spec, clause
    string, or instance); the default preserves the WF2 behavior."""

    num_hosts: int
    loop_id: str = "train_step"
    threshold: float = 1.15      # flag hosts >15% slower than median
    window: int = 16
    scheduler: Any = "wf2"       # SpecLike; must honor ctx.weights

    def __post_init__(self):
        self.history = LoopHistory()
        self.telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                       num_workers=self.num_hosts)
        self._step = 0

    # ------------------------------------------------------------ measure
    def observe_step(self, host_times: Dict[int, float],
                     host_tokens: Optional[Dict[int, int]] = None) -> None:
        """Record one training step's per-host wall times through the
        telemetry recorder: each step flushes as one measured invocation,
        advancing the history epoch that invalidates cached adaptive
        plans keyed on this mitigator's history."""
        self.history.open_invocation(self.loop_id)
        for h, t in host_times.items():
            n = (host_tokens or {}).get(h, 1)
            self.telemetry.record_chunk(h, 0, n, t, tokens=n)
        self.telemetry.flush()
        self._step += 1

    def epoch(self) -> int:
        """Measured epoch — how many flushed step observations the plan
        cache has seen for this loop."""
        return self.telemetry.epoch()

    # ------------------------------------------------------------- detect
    def stragglers(self) -> List[int]:
        rates = self.history.worker_rates(self.loop_id, last_k=self.window)
        if len(rates) < 2:
            return []
        med = float(np.median(list(rates.values())))
        return [h for h, r in rates.items() if r > self.threshold * med]

    # --------------------------------------------------------------- plan
    def weights(self) -> np.ndarray:
        """AWF capability weights, normalized to sum num_hosts — feed these
        to a weight-aware packing schedule (e.g. "wf2") or the batch
        splitter."""
        return np.asarray(
            self.history.awf_weights(self.loop_id, self.num_hosts))

    def token_shares(self, total_tokens: int) -> np.ndarray:
        """Integer per-host token budgets proportional to AWF weights,
        materialized as a plan of ``self.scheduler`` (default WF2) over
        the token budget (hosts are the workers) — the plan covers
        exactly, so shares always sum to ``total_tokens``, and identical
        weight vectors hit the engine's plan cache across steps."""
        w = self.weights()
        loop = LoopSpec(lb=0, ub=total_tokens, num_workers=self.num_hosts,
                        loop_id=f"{self.loop_id}/token_shares")
        plan = get_engine().plan(resolve(self.scheduler), loop,
                                 weights=w.tolist())
        return plan.worker_iters()
