"""UDS-planned gradient-accumulation microbatches.

Microbatches are the scheduling chunks of a training step: with variable-
cost rows (packed sequences of different fill), a decreasing-chunk schedule
(TSS/FAC2) front-loads the heavy microbatches so the pipeline drains evenly,
and AWF-weighted splits compensate persistent host speed differences.

The compiled step keeps *uniform* microbatch shapes (XLA is static); the
scheduler instead decides the ASSIGNMENT: which rows go into which
microbatch slot (a permutation), equalizing per-microbatch cost.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import LoopHistory, LoopSpec, SchedulerContext, get_engine
from repro.core.spec import SpecLike, resolve

__all__ = ["plan_hier_microbatch_permutation", "plan_microbatch_permutation"]


def plan_microbatch_permutation(sched: SpecLike,
                                row_costs: Sequence[float],
                                num_microbatches: int,
                                history: Optional[LoopHistory] = None
                                ) -> np.ndarray:
    """Permutation of batch rows such that consecutive equal-size slices
    (the compiled microbatches) have near-equal total cost.

    ``sched`` is a ScheduleSpec / clause string / scheduler instance.
    Rows are iterations; microbatches are workers; the UDS dequeues row
    chunks for the currently-lightest microbatch (longest-processing-time
    order) through an engine ``ScheduleStream`` — measured bucket costs feed
    back as the ``elapsed`` of the previous chunk.  Returns (B,) int32
    permutation.
    """
    sched = resolve(sched)
    B = len(row_costs)
    assert B % num_microbatches == 0
    per = B // num_microbatches
    order = np.argsort([-c for c in row_costs], kind="stable")
    loop = LoopSpec(lb=0, ub=B, num_workers=num_microbatches,
                    loop_id="microbatch")
    stream = get_engine().open_stream(
        sched, SchedulerContext(loop=loop, history=history))

    buckets: list[list[int]] = [[] for _ in range(num_microbatches)]
    load = np.zeros(num_microbatches)
    elapsed = {m: None for m in range(num_microbatches)}
    active = set(range(num_microbatches))
    while active:
        m = min(active, key=lambda i: (load[i], i))
        chunk = stream.next(m, elapsed[m])
        if chunk is None:
            active.discard(m)
            continue
        cost = 0.0
        for idx in range(chunk.start, chunk.stop):
            row = int(order[idx])
            # overflow spills to the lightest non-full bucket
            tgt = m if len(buckets[m]) < per else int(
                np.argmin([load[i] if len(buckets[i]) < per else np.inf
                           for i in range(num_microbatches)]))
            buckets[tgt].append(row)
            load[tgt] += row_costs[row]
            cost += row_costs[row]
        elapsed[m] = cost if cost else 1e-9
    stream.close()
    perm = [r for b in buckets for r in b]
    assert sorted(perm) == list(range(B))
    return np.asarray(perm, dtype=np.int32)


def plan_hier_microbatch_permutation(sched: SpecLike,
                                     row_costs: Sequence[float],
                                     num_microbatches: int,
                                     num_hosts: int,
                                     history: Optional[LoopHistory] = None
                                     ) -> np.ndarray:
    """Host-block-aligned microbatch permutation for hierarchical clauses.

    Multi-host training owns the (B, S) batch as ``num_hosts`` contiguous
    row blocks (the splitter masks per block), while the compiled
    microbatch reshape ``(B, S) -> (M, B/M, S)`` re-shards every
    microbatch's rows over the hosts again — within microbatch ``m`` host
    ``h`` physically runs rows ``m*B/M + [h*B/(M*H), (h+1)*B/(M*H))``.
    This planner keeps BOTH owners aligned: each host block is permuted
    *independently* (the hier device-level clause balances row cost
    across the M slots inside the block), and the per-host slot runs are
    interleaved so host ``h``'s rows land exactly in host ``h``'s shard
    of every microbatch.  Row ownership never crosses hosts, so token
    shares, straggler attribution, and membership requeue of a host's
    block all stay valid with microbatching on.

    Returns a (B,) int32 permutation with
    ``perm[m*B/M + h*rpm + j] = h*B/H + local_perm_h[m*rpm + j]``
    where ``rpm = B/(M*H)`` and ``local_perm_h`` is the flat planner's
    permutation of host ``h``'s block.
    """
    B = len(row_costs)
    if num_hosts <= 0 or B % num_hosts != 0:
        raise ValueError(
            f"batch rows ({B}) must divide evenly over hosts ({num_hosts})")
    rows_per_host = B // num_hosts
    if rows_per_host % num_microbatches != 0:
        raise ValueError(
            f"rows per host ({rows_per_host}) must divide evenly over "
            f"num_microbatches ({num_microbatches})")
    rpm = rows_per_host // num_microbatches  # rows per (microbatch, host)
    costs = np.asarray(row_costs, dtype=float)
    perm = np.empty(B, dtype=np.int32)
    for h in range(num_hosts):
        lo = h * rows_per_host
        local = plan_microbatch_permutation(
            sched, costs[lo:lo + rows_per_host], num_microbatches,
            history=history)
        # local[m*rpm:(m+1)*rpm] are host h's rows for microbatch m
        for m in range(num_microbatches):
            dst = m * (B // num_microbatches) + h * rpm
            perm[dst:dst + rpm] = lo + local[m * rpm:(m + 1) * rpm]
    assert sorted(perm.tolist()) == list(range(B))
    return perm
