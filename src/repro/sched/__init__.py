"""UDS applied to the distributed substrate: packing, MoE capacity,
microbatching, straggler mitigation."""

from repro.sched.packing import pack_with_scheduler, plan_packing
from repro.sched.moe_capacity import CapacityPlanner
from repro.sched.straggler import StragglerMitigator
from repro.sched.microbatch import plan_microbatch_permutation

__all__ = ["pack_with_scheduler", "plan_packing", "CapacityPlanner",
           "StragglerMitigator", "plan_microbatch_permutation"]
