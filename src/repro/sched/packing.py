"""UDS-scheduled document packing.

Mapping of the paper onto the data pipeline: documents are loop iterations
(cost = token count), sequence rows are workers, and the *scheduling
strategy* — which row dequeues the next document chunk — is an arbitrary
UDS.  Imbalanced packing = load imbalance: rows that fill early waste
padding (the idle-thread analogue).  WF2/FAC2 beat first-fit exactly the
way they beat static scheduling on CPU loops — the benchmark
``benchmarks/packing.py`` reproduces that qualitative claim.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core import LoopHistory, LoopSpec, SchedulerContext, get_engine
from repro.core.spec import SpecLike, resolve
from repro.data.pipeline import PackedBatch, pack_documents

__all__ = ["plan_packing", "pack_with_scheduler"]


def plan_packing(sched: SpecLike, doc_lens: Sequence[int],
                 batch: int, seq_len: int,
                 history: Optional[LoopHistory] = None) -> List[int]:
    """Assign each document to a batch row using a UDS.

    ``sched`` is any schedule selection the unified clause accepts — a
    ``ScheduleSpec``, a clause string (``"guided,4"``, ``"uds:myname"``),
    or a scheduler instance.  Documents are sorted by length
    (longest-first, the classic LPT trick), then dequeued: the scheduler
    decides how many documents (the chunk) the currently least-loaded row
    takes.  Returns per-document row ids, -1 for documents that did not
    fit.
    """
    sched = resolve(sched)
    order = np.argsort([-l for l in doc_lens], kind="stable")
    loop = LoopSpec(lb=0, ub=len(doc_lens), num_workers=batch,
                    loop_id="packing")
    stream = get_engine().open_stream(
        sched, SchedulerContext(loop=loop, history=history))

    fill = np.zeros(batch, np.int64)
    assign = [-1] * len(doc_lens)
    elapsed = {w: None for w in range(batch)}
    active = set(range(batch))
    while active:
        w = min(active, key=lambda r: fill[r])     # idle-most row dequeues
        chunk = stream.next(w, elapsed[w])
        if chunk is None:
            active.discard(w)
            continue
        cost = 0
        for idx in range(chunk.start, chunk.stop):
            doc = int(order[idx])
            n = doc_lens[doc]
            if fill[w] + n <= seq_len:
                assign[doc] = w
                fill[w] += n
                cost += n
        elapsed[w] = float(cost) if cost else 1e-9
    stream.close()
    return assign


def pack_with_scheduler(sched: SpecLike,
                        docs: Sequence[np.ndarray], batch: int, seq_len: int,
                        history: Optional[LoopHistory] = None) -> PackedBatch:
    assign = plan_packing(sched, [len(d) for d in docs], batch, seq_len,
                          history)
    keep = [i for i, a in enumerate(assign) if a >= 0]
    return pack_documents([docs[i] for i in keep], batch, seq_len,
                          assignment=[assign[i] for i in keep])
