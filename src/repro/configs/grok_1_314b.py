"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

Sharding note: 8 experts < 16-way model axis, but each expert's d_ff=32768 is
huge — so experts stay unsharded and every expert FFN is TP-sharded over
"model" (the per-arch override below).  Optimizer: Adafactor (314B params;
AdamW's 12 bytes/param does not fit 16 GB/chip on a 256-chip v5e pod).
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    moe_cap_headroom=1.2,    # §Perf: 1.6 costs 33% extra expert FLOPs
    rope_theta=1e4,
    optimizer="adafactor",
    sharding_overrides=(("experts", None), ("mlp", "model")),
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
    rope_theta=1e4,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "hf:xai-org/grok-1; unverified")
