"""Architecture & shape registry.

Every assigned architecture registers its exact published config here (one
file per arch, dims pinned from the assignment table) plus a *reduced* smoke
config of the same family for CPU tests.  Shapes are the assigned input
shapes; ``applicable`` encodes the assignment's skip rules (long_500k needs
sub-quadratic context).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "register", "get_config", "get_smoke_config",
           "list_archs", "cells", "ArchEntry"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    smoke: ModelConfig
    source: str          # provenance tag from the assignment table


_REGISTRY: Dict[str, ArchEntry] = {}


def register(config: ModelConfig, smoke: ModelConfig, source: str) -> None:
    _REGISTRY[config.name] = ArchEntry(config, smoke, source)


def _ensure_loaded() -> None:
    # import all arch modules exactly once (registration side effect)
    from repro.configs import (  # noqa: F401
        grok_1_314b, qwen3_moe_235b_a22b, rwkv6_3b, qwen2_5_3b, minicpm_2b,
        qwen3_32b, phi3_mini_3_8b, musicgen_large, zamba2_2_7b, qwen2_vl_7b,
    )


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _REGISTRY[name].config


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name].smoke


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic context archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("SKIP: pure full-attention arch — 512k-token context "
                       "requires sub-quadratic attention (assignment rule; "
                       "see DESIGN.md §Arch-applicability)")
    return True, ""


def cells() -> List[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape) cells with applicability flags."""
    _ensure_loaded()
    out = []
    for arch in list_archs():
        cfg = _REGISTRY[arch].config
        for sname, spec in SHAPES.items():
            ok, why = applicable(cfg, spec)
            out.append((arch, sname, ok, why))
    return out
