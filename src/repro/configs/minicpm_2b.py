"""minicpm-2b — 40L d_model=2304 36H (MHA: kv=36) d_ff=5760 vocab=122753;
llama-like arch trained with the WSD (warmup-stable-decay) LR schedule,
which repro/optim/schedules.py implements.  [arXiv:2404.06395; hf]

MiniCPM ties input/output embeddings.  36 heads do not divide the 16-way
model axis — GSPMD shards unevenly (padded); noted in EXPERIMENTS.md.
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    pad_vocab_multiple=256,   # -> 122880: shards 16-way (§Perf note; the
                              # unpadded table cannot shard and its CE
                              # all-reduces dominate prefill at 187 s)
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    num_layers=2,
    d_model=72,
    num_heads=6,
    num_kv_heads=6,
    d_ff=128,
    vocab_size=257,          # odd vocab on purpose (uneven-shard coverage)
    tie_embeddings=True,
    rope_theta=1e4,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "arXiv:2404.06395; hf")
