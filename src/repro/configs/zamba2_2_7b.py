"""zamba2-2.7b — 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

Hybrid: O(1) SSM state + periodic shared attention -> runs long_500k.
One shared attn+MLP block applied after every 6th Mamba2 layer (9
applications), on concat(hidden, embedding); per-application LoRA omitted
(DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    shared_attention_every=6,
    attention="hybrid",
    scan_chunk=32,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    shared_attention_every=2,
    attention="hybrid",
    scan_chunk=8,
    rope_theta=1e4,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "arXiv:2411.15242; hf")
