"""qwen2-vl-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064;
M-RoPE (3-D rotary: temporal/height/width), dynamic resolution.
[arXiv:2409.12191; hf]

Backbone only: the ViT frontend is a stub — ``input_specs()`` feeds merged
text+vision embeddings (B,S,D) plus the 3-D M-RoPE position ids (3,B,S).
M-RoPE sections (16,24,24) over half-dim 64 (head_dim 128).

UDS tie-in: dynamic-resolution images yield variable-length patch streams —
the classic irregular-iteration workload the packing scheduler balances.
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision",
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    mrope_sections=(2, 3, 3),
    rope_theta=1e6,
    frontend="vision",
    flash_threshold=64,
)

register(CONFIG, SMOKE, "arXiv:2409.12191; hf")
