"""phi3-mini-3.8b — 32L d_model=3072 32H (MHA: kv=32) d_ff=8192 vocab=32064;
RoPE + SwiGLU.  [arXiv:2404.14219; unverified]
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="phi3-mini-3.8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    rope_theta=1e4,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "arXiv:2404.14219; unverified")
