"""repro.configs — one pinned config per assigned architecture (+ reduced
smoke twins).  Use ``get_config("<arch>")`` / ``--arch <id>`` in launchers."""

from repro.configs.base import (
    SHAPES,
    ShapeSpec,
    applicable,
    cells,
    get_config,
    get_smoke_config,
    list_archs,
)

__all__ = ["SHAPES", "ShapeSpec", "applicable", "cells", "get_config",
           "get_smoke_config", "list_archs"]
