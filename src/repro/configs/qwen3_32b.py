"""qwen3-32b — 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936;
qk-norm, GQA, head_dim=128 (qwen3 family).  [hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    rope_theta=1e6,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "hf:Qwen/Qwen3-8B; hf")
