"""musicgen-large — 48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048;
decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only, per the assignment: the EnCodec/text-conditioning frontend is
a stub — ``input_specs()`` feeds precomputed frame embeddings (B,S,D).
MusicGen uses GELU MLP + sinusoidal positions (not RoPE/SwiGLU); the
4-codebook delay-pattern head is collapsed to a single vocab-2048 head
(documented in DESIGN.md).
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp="gelu",
    positional="sinusoidal",
    frontend="audio",
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    mlp="gelu",
    positional="sinusoidal",
    frontend="audio",
    flash_threshold=64,
)

register(CONFIG, SMOKE, "arXiv:2306.05284; hf")
