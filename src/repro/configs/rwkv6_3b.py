"""rwkv6-3b "Finch" — 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536; data-dependent decay.  [arXiv:2404.05892; hf]

Attention-free: O(1) state per token -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # wkv heads: d_model / wkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    wkv_head_dim=64,
    attention="none",
    positional="none",
    scan_chunk=32,
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    wkv_head_dim=16,
    attention="none",
    positional="none",
    scan_chunk=8,
)

register(CONFIG, SMOKE, "arXiv:2404.05892; hf")
