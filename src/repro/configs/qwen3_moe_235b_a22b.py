"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per
expert) vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]

Qwen3 family: head_dim=128, qk-norm, no qkv bias.  128 experts shard evenly
over the 16-way model axis (8 experts/chip) — the headline expert-parallel
case for the WF2 capacity scheduler.  Optimizer: Adafactor (235B params).
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_cap_headroom=1.2,    # §Perf: 1.6 costs 33% extra expert FLOPs
    qk_norm=True,
    rope_theta=1e6,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    qk_norm=True,
    rope_theta=1e6,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "hf:Qwen/Qwen3-30B-A3B; hf")
