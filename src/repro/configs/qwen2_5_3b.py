"""qwen2.5-3b — 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936;
GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""

from repro.models.config import ModelConfig
from repro.configs.base import register

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2.5-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    rope_theta=1e6,
    flash_threshold=64,
)

register(CONFIG, SMOKE, "hf:Qwen/Qwen2.5-0.5B; hf")
