"""UDS-scheduled tiled matmul — the paper's idea at Pallas kernel level.

An OpenMP loop scheduler decides which iterations a thread dequeues next; a
TPU kernel's analogue is *which tile the next grid step processes*.  Here the
UDS chunk table (a permutation of M-tiles, produced by ``SchedulePlan``) is
**scalar-prefetched** into the kernel, and every BlockSpec index_map reads it
— so STATIC/GSS/TSS/FAC2-shaped tile orders (e.g. locality-first vs
load-balance-first under a multi-kernel megacore split) are selected at run
time without recompiling.

TPU mapping:
  * grid = (m_tiles, n_tiles, k_tiles); K innermost so the f32 accumulator
    lives in VMEM scratch across the K loop;
  * MXU-aligned blocks (multiples of 128 in M/N, K);
  * VMEM working set = bm·bk + bk·bn + bm·bn (+ f32 acc) — block defaults
    (128, 128, 512) keep it ≈ 0.8 MB, far under the ~16 MB/core v5e VMEM,
    leaving room for double buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sched_matmul"]


def _kernel(order_ref, a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def sched_matmul(a: jax.Array, b: jax.Array,
                 tile_order: Optional[jax.Array] = None,
                 *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 512, interpret: bool = False) -> jax.Array:
    """C = A @ B with UDS-ordered M-tiles.

    a: (M, K); b: (K, N); tile_order: (M // block_m,) int32 permutation —
    the dequeue order of M-tiles (defaults to identity = static block
    schedule).  Shapes must tile exactly (production path pads first).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"shapes {(M, K, N)} must tile by {(block_m, block_n, block_k)}")
    m_tiles = M // block_m
    if tile_order is None:
        tile_order = jnp.arange(m_tiles, dtype=jnp.int32)

    grid = (m_tiles, N // block_n, K // block_k)
    kernel = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda i, j, k, order: (order[i], k)),
                pl.BlockSpec((block_k, block_n),
                             lambda i, j, k, order: (k, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda i, j, k, order: (order[i], j)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )
    return kernel(tile_order.astype(jnp.int32), a, b)
