"""Public op: UDS-scheduled matmul with padding + plan integration."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wave import SchedulePlan
from repro.kernels.sched_matmul.sched_matmul import sched_matmul
from repro.kernels.sched_matmul.ref import sched_matmul_ref

__all__ = ["scheduled_matmul", "tile_order_from_plan", "sched_matmul",
           "sched_matmul_ref"]


def tile_order_from_plan(plan: SchedulePlan, m_tiles: int) -> np.ndarray:
    """Flatten a UDS SchedulePlan over [0, m_tiles) into the kernel's
    tile-visit order (dequeue order, chunks expanded to their tiles)."""
    order = []
    for c in plan.chunks:
        order.extend(range(c.start, min(c.stop, m_tiles)))
    assert sorted(order) == list(range(m_tiles)), "plan must tile exactly"
    return np.asarray(order, dtype=np.int32)


def scheduled_matmul(a: jax.Array, b: jax.Array,
                     tile_order: Optional[jax.Array] = None,
                     *, block_m: int = 128, block_n: int = 128,
                     block_k: int = 512, use_kernel: bool = True,
                     interpret: bool = False) -> jax.Array:
    """C = A @ B; pads to tile multiples, runs the Pallas kernel."""
    if not use_kernel:
        return sched_matmul_ref(a, b)
    M, K = a.shape
    _, N = b.shape
    block_k = min(block_k, max(8, K))
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    if tile_order is not None and pm:
        extra = jnp.arange(M // block_m, (M + pm) // block_m, dtype=jnp.int32)
        tile_order = jnp.concatenate([tile_order.astype(jnp.int32), extra])
    out = sched_matmul(ap, bp, tile_order, block_m=block_m, block_n=block_n,
                       block_k=block_k, interpret=interpret)
    return out[:M, :N]
