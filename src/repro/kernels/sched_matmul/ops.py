"""Public op: UDS-scheduled matmul with padding + plan-engine integration."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import PlanEngine, plan_worker_order
from repro.core.plan import SchedulePlan
from repro.core.spec import SpecLike
from repro.kernels.sched_matmul.sched_matmul import sched_matmul
from repro.kernels.sched_matmul.ref import sched_matmul_ref

__all__ = ["scheduled_matmul", "tile_order_from_plan", "plan_tile_order",
           "sched_matmul", "sched_matmul_ref"]


def tile_order_from_plan(plan: SchedulePlan, m_tiles: int) -> np.ndarray:
    """Flatten a UDS SchedulePlan over [0, m_tiles) into the kernel's
    tile-visit order (dequeue order, chunks expanded to their tiles) —
    vectorized over the plan's flat arrays."""
    order = plan.tile_order(m_tiles)
    assert order.shape[0] == m_tiles and np.array_equal(
        np.sort(order), np.arange(m_tiles)), "plan must tile exactly"
    return order


def plan_tile_order(sched: SpecLike, m_tiles: int,
                    num_workers: int = 2, *,
                    engine: Optional[PlanEngine] = None,
                    device: bool = False,
                    **sched_params) -> np.ndarray:
    """Worker-major M-tile visit order for a schedule clause (a
    ScheduleSpec, a string like ``"guided,4"`` / ``"uds:name"``, or a
    scheduler instance), planned — and cached across kernel launches — by
    the engine: each of the ``num_workers`` kernel lanes (default 2 = TPU
    megacore) gets the contiguous tile run the UDS assigned to it.
    A hierarchical clause (``"hier(host=static, tile=guided,2)"``) yields
    a host-block-major leaf order: each outer block's tiles are visited
    in its own child plan's order (``ComposedPlan.tile_order``).
    ``device=True`` returns the plan's cached device array (one upload
    per plan, reused across launches)."""
    return plan_worker_order(sched, m_tiles, num_workers=num_workers,
                             loop_id=f"sched_matmul/{m_tiles}",
                             engine=engine, device=device, **sched_params)


def scheduled_matmul(a: jax.Array, b: jax.Array,
                     tile_order: Optional[jax.Array] = None,
                     *, block_m: int = 128, block_n: int = 128,
                     block_k: int = 512, use_kernel: bool = True,
                     interpret: bool = False) -> jax.Array:
    """C = A @ B; pads to tile multiples, runs the Pallas kernel."""
    if not use_kernel:
        return sched_matmul_ref(a, b)
    M, K = a.shape
    _, N = b.shape
    block_k = min(block_k, max(8, K))
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    if tile_order is not None and pm:
        extra = jnp.arange(M // block_m, (M + pm) // block_m, dtype=jnp.int32)
        tile_order = jnp.concatenate([tile_order.astype(jnp.int32), extra])
    out = sched_matmul(ap, bp, tile_order, block_m=block_m, block_n=block_n,
                       block_k=block_k, interpret=interpret)
    return out[:M, :N]
