"""Pure-jnp oracle for the schedule-driven tiled matmul.

The kernel computes C = A @ B where the M-tiles are *visited in the order a
UDS dequeued them* (the ``tile_order`` permutation).  Reordering tiles never
changes the result — the oracle is a plain matmul — but the schedule changes
locality/pipelining on TPU; tests assert exactness for every permutation.
"""

from __future__ import annotations

import jax.numpy as jnp


def sched_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                     tile_order=None, block_m: int = 128) -> jnp.ndarray:
    del tile_order, block_m  # order is perf-only; semantics are A @ B
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
