"""Blockwise causal flash attention — Pallas TPU kernel.

Online-softmax over KV blocks (Rabe-Staats/FlashAttention), mapped to TPU:

  * grid = (batch*heads, q_blocks, kv_blocks); KV innermost so the running
    (acc, m, l) statistics live in VMEM scratch across the KV loop;
  * blocks MXU-aligned (block_q × head_dim and block_kv × head_dim tiles);
  * causal skipping: KV blocks strictly above the diagonal are skipped via
    ``pl.when`` — ~2× work saving the pure-jnp path can't express;
  * VMEM footprint ≈ (block_q + block_kv)·d + block_q·block_kv + block_q·d
    f32 ≈ 1.3 MB at (512, 1024, 128) — double-bufferable in 16 MB/core.

The q/kv block sizes are the UDS "chunk" parameters of the KV loop (the
paper's grouping of iterations into scheduling items), and the optional
``q_block_order`` — a permutation produced from a ``SchedulePlan`` — is the
UDS dequeue order of Q blocks: under causal masking block i carries O(i)
work, so decreasing-cost orders (GSS/TSS-shaped) let a multi-kernel
megacore split load-balance without recompiling.  The order is
scalar-prefetched; every BlockSpec index_map reads it.

Oracle: ref.py (also the model's blockwise_attention path).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(*refs, scale: float, causal: bool, block_q: int, block_kv: int,
            kv_blocks: int, has_order: bool):
    if has_order:
        order_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        qi = order_ref[pl.program_id(1)]      # logical Q block (UDS order)
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks entirely above the diagonal
    run = (qi * block_q + block_q - 1) >= (ki * block_kv) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (block_q, d)
        k = k_ref[0].astype(jnp.float32)              # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            k_pos = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_kv",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_block_order=None,
                    *, causal: bool = True,
                    block_q: int = 512, block_kv: int = 1024,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: (B, H, S, d) (repeat GQA heads outside). Returns (B, H, S, d).

    ``q_block_order``: optional (S // block_q,) int32 permutation — the UDS
    dequeue order of Q blocks (defaults to identity = static block
    schedule).  S must tile by the block sizes (production path pads first).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_kv == 0, (sq, sk)
    scale = 1.0 / math.sqrt(d)
    bh = b * h
    qr = q.reshape(bh, sq, d)
    kr = k.reshape(bh, sk, d)
    vr = v.reshape(bh, sk, d)
    q_blocks = sq // block_q
    kv_blocks = sk // block_kv

    body = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_kv=block_kv,
                             kv_blocks=kv_blocks,
                             has_order=q_block_order is not None)
    scratch = [
        pltpu.VMEM((block_q, d), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
    ]
    out_shape = jax.ShapeDtypeStruct((bh, sq, d), q.dtype)
    if q_block_order is None:
        kernel = pl.pallas_call(
            body,
            grid=(bh, q_blocks, kv_blocks),
            in_specs=[
                pl.BlockSpec((1, block_q, d), lambda b_, i, j: (b_, i, 0)),
                pl.BlockSpec((1, block_kv, d), lambda b_, i, j: (b_, j, 0)),
                pl.BlockSpec((1, block_kv, d), lambda b_, i, j: (b_, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, d),
                                   lambda b_, i, j: (b_, i, 0)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )
        return kernel(qr, kr, vr).reshape(b, h, sq, d)

    kernel = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, q_blocks, kv_blocks),
            in_specs=[
                pl.BlockSpec((1, block_q, d),
                             lambda b_, i, j, order: (b_, order[i], 0)),
                pl.BlockSpec((1, block_kv, d),
                             lambda b_, i, j, order: (b_, j, 0)),
                pl.BlockSpec((1, block_kv, d),
                             lambda b_, i, j, order: (b_, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, d),
                                   lambda b_, i, j, order: (b_, order[i], 0)),
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )
    return kernel(jnp.asarray(q_block_order, jnp.int32),
                  qr, kr, vr).reshape(b, h, sq, d)
