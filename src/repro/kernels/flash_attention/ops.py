"""Public op: flash attention with GQA, padding, and platform dispatch.

On TPU the Pallas kernel runs natively; on CPU it runs in interpret mode
(tests) or falls back to the jnp oracle (large shapes).

The Q-block visit order is a UDS scheduling decision: under causal masking
Q block i attends to O(i) KV blocks, so a decreasing-cost schedule
(GSS/TSS) balances a multi-kernel megacore split.  ``mha(schedule=...)``
plans the order through the PlanEngine (cached across identically-shaped
calls) and scalar-prefetches it into the kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import plan_worker_order
from repro.core.spec import SpecLike
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha", "plan_q_block_order", "flash_attention", "attention_ref"]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def plan_q_block_order(sched: SpecLike,
                       q_blocks: int, num_workers: int = 2,
                       device: bool = False,
                       **sched_params):
    """Worker-major Q-block visit order for a schedule clause (spec,
    string like ``"tss"`` / ``"guided,4"``, or scheduler instance),
    planned (and cached) by the engine: each of the ``num_workers``
    kernel lanes (default 2 = megacore) gets its worker's contiguous
    block run, so the lanes inherit the schedule's load balance.  A
    hierarchical clause (``"hier(host=static, tile=tss)"``) yields a
    host-block-major leaf order — each outer block's Q-blocks visited in
    its own child plan's order (``ComposedPlan.tile_order``).
    ``device=True`` returns the plan's cached device array (one upload
    per plan, reused across launches)."""
    return plan_worker_order(sched, q_blocks, num_workers=num_workers,
                             loop_id=f"flash_attention/{q_blocks}",
                             device=device, **sched_params)


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        block_q: int = 512, block_kv: int = 1024,
        schedule: Optional[SpecLike] = None,
        use_kernel: bool = True, interpret: bool = False) -> jax.Array:
    """q: (B, S, H, d); k/v: (B, S, KV, d) (GQA repeated here).
    Returns (B, S, H, d).  ``schedule`` is the schedule clause that orders
    the kernel's Q-block visits — a ScheduleSpec, a clause string, or a
    scheduler instance (None = identity / static block order)."""
    b, s, hq, d = q.shape
    kv = k.shape[2]
    if hq != kv:
        reps = hq // kv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        out = attention_ref(qt, kt, vt, causal=causal)
        return out.transpose(0, 2, 1, 3)
    bq = min(block_q, max(8, s))
    bkv = min(block_kv, max(8, s))
    qp = _pad_to(qt, 2, bq)
    kp = _pad_to(kt, 2, bkv)
    vp = _pad_to(vt, 2, bkv)
    order = None
    if schedule is not None:
        # the plan's cached device table: a plan-cache hit reuses the
        # buffer uploaded for a previous identically-shaped launch
        order = plan_q_block_order(schedule, qp.shape[2] // bq, device=True)
    out = flash_attention(qp, kp, vp, order, causal=causal, block_q=bq,
                          block_kv=bkv, interpret=interpret)
    return out[:, :, :s].transpose(0, 2, 1, 3)
