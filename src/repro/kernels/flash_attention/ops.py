"""Public op: flash attention with GQA, padding, and platform dispatch.

On TPU the Pallas kernel runs natively; on CPU it runs in interpret mode
(tests) or falls back to the jnp oracle (large shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha", "flash_attention", "attention_ref"]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        block_q: int = 512, block_kv: int = 1024,
        use_kernel: bool = True, interpret: bool = False) -> jax.Array:
    """q: (B, S, H, d); k/v: (B, S, KV, d) (GQA repeated here).
    Returns (B, S, H, d)."""
    b, s, hq, d = q.shape
    kv = k.shape[2]
    if hq != kv:
        reps = hq // kv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        out = attention_ref(qt, kt, vt, causal=causal)
        return out.transpose(0, 2, 1, 3)
    bq = min(block_q, max(8, s))
    bkv = min(block_kv, max(8, s))
    qp = _pad_to(qt, 2, bq)
    kp = _pad_to(kt, 2, bkv)
    vp = _pad_to(vt, 2, bkv)
    out = flash_attention(qp, kp, vp, causal=causal, block_q=bq,
                          block_kv=bkv, interpret=interpret)
    return out[:, :, :s].transpose(0, 2, 1, 3)
