"""Oracle for the flash-attention kernel: plain softmax attention in jnp."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, d); k/v: (B, H, Sk, d) — same head counts (repeat GQA
    outside).  f32 softmax, output in q.dtype."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = (jnp.arange(sq)[:, None] + (sk - sq)) >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
