"""Chunked linear-attention scan — Pallas TPU kernel (RWKV6 wkv / Mamba2 SSD).

Recurrence: S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ;  y_t = q_t·S (see ref.py).

TPU mapping:
  * grid = (B·H, n_chunks); chunk index innermost, so the running state
    S (dk × dv, f32) persists in VMEM scratch across the chunk loop —
    HBM→VMEM traffic is one (C × d) tile set per chunk, state never
    leaves VMEM (the CUDA versions bounce state through shared memory
    per thread-block; on TPU it simply stays resident);
  * scalar-per-head decay (Mamba2): full MXU chunked form — intra-chunk
    (C×C) score matmul masked by the decay-gap matrix, inter-chunk one
    (C×dk)@(dk×dv) matmul;
  * vector decay (RWKV6): numerically-safe sequential inner loop over the
    chunk (VPU outer products) with chunked I/O.  The common factored
    q̃·k̃ form overflows for data-dependent per-channel decay
    (exp(−Σlog w) is unbounded); the paper-faithful safe form is kept —
    see models/linear_scan.py for the same choice in the jnp path.

The chunk size is the UDS-schedulable parameter (cfg.scan_chunk).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["linear_scan_scalar", "linear_scan_vector"]


# ----------------------------------------------------------- scalar decay
def _scalar_kernel(q_ref, k_ref, v_ref, lw_ref, y_ref, s_out_ref, s_ref,
                   *, chunk: int, n_chunks: int, inclusive: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    lw = lw_ref[0].astype(jnp.float32)        # (C,)

    ai = jnp.cumsum(lw)                       # inclusive log-decay
    q_dec = ai if inclusive else ai - lw
    # inter-chunk: (q ⊙ exp(dec)) @ S
    y = jax.lax.dot_general(q * jnp.exp(q_dec)[:, None], s_ref[...],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk
    gap = q_dec[:, None] - ai[None, :]        # (C, C), masked entries <= 0
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (col <= row) if inclusive else (col < row)
    m = jnp.where(mask, jnp.exp(jnp.where(mask, gap, 0.0)), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * m
    y = y + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    alast = ai[-1]
    kdec = k * jnp.exp(alast - ai)[:, None]
    s_ref[...] = s_ref[...] * jnp.exp(alast) + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _out():
        s_out_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("inclusive", "chunk", "interpret"))
def linear_scan_scalar(q: jax.Array, k: jax.Array, v: jax.Array,
                       log_w: jax.Array, *, inclusive: bool = True,
                       chunk: int = 32, interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """Mamba2/SSD form. q/k: (B,H,T,dk); v: (B,H,T,dv); log_w: (B,H,T).
    Returns (y (B,H,T,dv), final_state (B,H,dk,dv) f32).  T % chunk == 0."""
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    bh = B * H
    qr = q.reshape(bh, T, dk)
    kr = k.reshape(bh, T, dk)
    vr = v.reshape(bh, T, dv)
    lwr = log_w.reshape(bh, T)

    y, s = pl.pallas_call(
        functools.partial(_scalar_kernel, chunk=chunk, n_chunks=nc,
                          inclusive=inclusive),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, T, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lwr)
    return y.reshape(B, H, T, dv), s.reshape(B, H, dk, dv)


# ----------------------------------------------------------- vector decay
def _vector_kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref,
                   s_ref, y_acc_ref,
                   *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    w = jnp.exp(lw_ref[0].astype(jnp.float32))  # (C, dk)
    u = u_ref[0].astype(jnp.float32)          # (dk,)

    def step(t, _):
        qt = jax.lax.dynamic_slice_in_dim(q, t, 1, 0)      # (1, dk)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)      # (1, dv)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)      # (1, dk)
        # exclusive + bonus-u (RWKV6): y = q·S_prev + (q·(u⊙k)) v
        y_hist = jax.lax.dot_general(qt, s_ref[...],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        bonus = jnp.sum(qt * u[None, :] * kt, axis=-1, keepdims=True)
        yt = y_hist + bonus * vt                           # (1, dv)
        y_acc_ref[...] = jax.lax.dynamic_update_slice_in_dim(
            y_acc_ref[...], yt, t, 0)
        # S = diag(w)·S + kᵀ v
        s_ref[...] = s_ref[...] * wt.T + kt.T * vt         # (dk, dv)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())
    y_ref[0] = y_acc_ref[...].astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _out():
        s_out_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan_vector(q: jax.Array, k: jax.Array, v: jax.Array,
                       log_w: jax.Array, u: jax.Array, *,
                       chunk: int = 32, interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 wkv form (exclusive + bonus u).  q/k/v/log_w: (B,H,T,n);
    u: (H, n).  Returns (y (B,H,T,n), final_state (B,H,n,n) f32)."""
    B, H, T, n = q.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    bh = B * H
    qr = q.reshape(bh, T, n)
    kr = k.reshape(bh, T, n)
    vr = v.reshape(bh, T, n)
    lwr = log_w.reshape(bh, T, n)
    ur = jnp.broadcast_to(u[None], (B, H, n)).reshape(bh, n)

    y, s = pl.pallas_call(
        functools.partial(_vector_kernel, chunk=chunk, n_chunks=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, T, n), v.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, n), jnp.float32),
            pltpu.VMEM((chunk, n), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, lwr, ur)
    return y.reshape(B, H, T, n), s.reshape(B, H, n, n)
