"""Public op: chunked linear-attention scan (RWKV6 / Mamba2 forms)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.linear_scan import (
    linear_scan_scalar,
    linear_scan_vector,
)
from repro.kernels.linear_scan.ref import chunked_linear_attention

__all__ = ["wkv", "ssd", "linear_scan_scalar", "linear_scan_vector"]


def _pad_time(x: jax.Array, chunk: int) -> Tuple[jax.Array, int]:
    t = x.shape[2]
    pad = (-t) % chunk
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, pad)
        x = jnp.pad(x, widths)
    return x, t


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
        u: jax.Array, *, chunk: int = 32, use_kernel: bool = True,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 wkv. r/k/v/log_w: (B,H,T,n); u: (H,n)."""
    if not use_kernel:
        return chunked_linear_attention(r, k, v, log_w, u=u,
                                        inclusive=False, chunk=chunk)
    rp, t = _pad_time(r, chunk)
    kp, _ = _pad_time(k, chunk)
    vp, _ = _pad_time(v, chunk)
    lp, _ = _pad_time(log_w, chunk)
    y, s = linear_scan_vector(rp, kp, vp, lp, u, chunk=chunk,
                              interpret=interpret)
    return y[:, :, :t], s


def ssd(c: jax.Array, b: jax.Array, x: jax.Array, log_a: jax.Array,
        *, chunk: int = 32, use_kernel: bool = True,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD (inclusive, scalar-per-head decay).
    c/b: (B,H,T,N) (q/k roles); x: (B,H,T,hd) (values); log_a: (B,H,T)."""
    if not use_kernel:
        return chunked_linear_attention(c, b, x, log_a,
                                        inclusive=True, chunk=chunk)
    cp, t = _pad_time(c, chunk)
    bp, _ = _pad_time(b, chunk)
    xp, _ = _pad_time(x, chunk)
    la = log_a
    pad = (-la.shape[2]) % chunk
    if pad:
        la = jnp.pad(la, ((0, 0), (0, 0), (0, pad)))
    y, s = linear_scan_scalar(cp, bp, xp, la, inclusive=True, chunk=chunk,
                              interpret=interpret)
    return y[:, :, :t], s
