"""Oracle for the linear-scan kernel: the sequential recurrence, plus a
re-export of the model's chunked formulation (they must all agree)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.linear_scan import (  # noqa: F401  (re-export for tests)
    chunked_linear_attention,
    linear_attention_step,
)


def linear_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         log_w: jax.Array,
                         *, u: Optional[jax.Array] = None,
                         inclusive: bool = True,
                         initial_state: Optional[jax.Array] = None,
                         ) -> Tuple[jax.Array, jax.Array]:
    """Sequential per-timestep reference (the ground truth).

    q/k: (B,H,T,dk); v: (B,H,T,dv); log_w: (B,H,T,dk) or (B,H,T).
    """
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if log_w.ndim == 3:
        log_w = jnp.broadcast_to(log_w[..., None], (B, H, T, dk))
    S = (jnp.zeros((B, H, dk, dv), f32) if initial_state is None
         else initial_state.astype(f32))

    def step(S, t):
        qt = q[:, :, t].astype(f32)
        kt = k[:, :, t].astype(f32)
        vt = v[:, :, t].astype(f32)
        wt = jnp.exp(log_w[:, :, t].astype(f32))
        kv = jnp.einsum("bhn,bhv->bhnv", kt, vt)
        S_new = S * wt[..., None] + kv
        if u is not None:
            y = jnp.einsum("bhn,bhnv->bhv", qt, S + u[None, :, :, None] * kv)
        elif inclusive:
            y = jnp.einsum("bhn,bhnv->bhv", qt, S_new)
        else:
            y = jnp.einsum("bhn,bhnv->bhv", qt, S)
        return S_new, y

    S, ys = jax.lax.scan(step, S, jnp.arange(T))
    y = jnp.moveaxis(ys, 0, 2).astype(v.dtype)
    return y, S
