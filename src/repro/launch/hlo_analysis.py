"""Loop-aware analysis of post-optimization HLO text.

XLA's ``cost_analysis()`` counts each while-loop *body once* — under
scan-over-layers that undercounts FLOPs, bytes and collectives by ~L×.
This module parses the optimized HLO and multiplies every computation's
contribution by its loop trip count:

  * computations are parsed into (name -> instructions) with a per-
    computation symbol table (instruction name -> shape);
  * a call graph is built from while bodies/conditions, fusion calls,
    conditionals, and plain calls;
  * while trip counts are recovered from the loop condition's comparison
    constant (scan lowers to a counted loop);
  * FLOPs: 2·prod(result)·prod(contracting dims) per ``dot`` (einsums and
    matmuls; models here have no convolutions);
  * bytes: Σ (operands + result) per instruction at fusion granularity
    (fused computations contribute 0 — their internals stay in
    registers/VMEM), approximating HBM traffic;
  * collectives: result-shape bytes × ring-traffic factor (see
    roofline.py) × trip multiplier.

It also reports ``cpu_bf16_legalization_bytes``: f32 stacks written by
dynamic-update-slice that shadow a bf16 tensor of identical dims — an
artifact of XLA:CPU rewriting bf16 dots to f32 (TPU executes bf16 on the
MXU natively, so these buffers do not exist on the target hardware).
The dry-run's adjusted fit check subtracts them.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "normalize_cost_analysis", "HloStats"]


def normalize_cost_analysis(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Some versions (e.g. 0.4.3x) return a one-entry *list* of per-program
    dicts, others a plain dict, and it may be None for empty programs —
    always return a dict so callers can index ``["flops"]`` safely.
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\-.]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\-.]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\-.]+)")
_CALLED = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w\-.,% ]+)\}?")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# Fused-traffic model: XLA:TPU fuses elementwise chains into neighboring
# matmuls/reductions, so HBM traffic is dominated by these op classes.  The
# CPU-optimized HLO we analyze fuses far less — counting every elementwise
# op would overstate TPU traffic by ~10×.
_INCLUDE_BYTES_OPS = {"dot", "dot-general", "fusion", "dynamic-update-slice",
                      "dynamic-slice", "scatter", "gather", "sort",
                      "convolution", "reduce-window", "concatenate"}


def _shape_dims(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        d = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, d))
    return out


def _shape_bytes(text: str) -> int:
    return sum(math.prod(d) * _DTYPE_BYTES[dt] for dt, d in _shape_dims(text))


def _shape_bytes2(text: str, bf16_shapes) -> Tuple[int, int]:
    """(raw, tpu-corrected) bytes: f32 tensors whose dims also appear in
    bf16 anywhere in the module are counted at bf16 width — they are
    XLA:CPU's bf16->f32 op legalization, absent on TPU (native bf16)."""
    raw = corr = 0
    for dt, d in _shape_dims(text):
        b = math.prod(d) * _DTYPE_BYTES[dt]
        raw += b
        if dt in ("f32", "u32", "s32") and d in bf16_shapes:
            corr += math.prod(d) * 2
        else:
            corr += b
    return raw, corr


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str            # everything after the opening paren


@dataclasses.dataclass
class HloStats:
    flops: float
    bytes_accessed: float            # raw (CPU-HLO dtypes)
    bytes_accessed_tpu: float        # f32-with-bf16-twin counted at bf16 size
    collective_bytes: float          # traffic-model bytes (ring factors), raw
    collective_bytes_tpu: float
    collective_count: int
    collective_by_op: Dict[str, float]
    while_trip_counts: Dict[str, int]
    cpu_bf16_legalization_bytes: int


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3),
                                     m.group(4)))
    return comps


def _split_operands(rest: str) -> str:
    """Return the operand segment (up to the matching close paren)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _trip_count(cond_instrs: List[_Instr]) -> int:
    """Scan loops compare an s32 induction variable against the trip count;
    take the largest s32 constant in the condition computation."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and ins.shape.startswith("s32"):
            m = re.match(r"([0-9]+)\)?", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloStats:
    comps = _parse_computations(text)

    # symbol tables: per computation, instruction name -> result shape text
    symtab: Dict[str, Dict[str, str]] = {}
    for cname, instrs in comps.items():
        tab: Dict[str, str] = {}
        for ins in instrs:
            tab[ins.name] = ins.shape
        symtab[cname] = tab

    # call graph: computation -> multiplier
    mult: Dict[str, float] = {}
    entry = None
    for cname in comps:
        if cname.endswith("main") or entry is None:
            # the ENTRY computation is printed with "ENTRY %main ..."
            pass
    # find entry: computation not called by anyone
    called = set()
    calls: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    trip_counts: Dict[str, int] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                m = re.search(r"condition=%?([\w\-.]+)", ins.rest)
                c_cond = m.group(1) if m else None
                m = re.search(r"body=%?([\w\-.]+)", ins.rest)
                c_body = m.group(1) if m else None
                trips = _trip_count(comps.get(c_cond, [])) if c_cond else 1
                if c_body:
                    calls[cname].append((c_body, float(trips)))
                    called.add(c_body)
                    trip_counts[c_body] = trips
                if c_cond:
                    calls[cname].append((c_cond, float(trips + 1)))
                    called.add(c_cond)
            else:
                m = _CALLED.search(ins.rest)
                if m:
                    for sub in re.split(r"[,\s]+", m.group(1)):
                        sub = sub.strip().lstrip("%")
                        if sub in comps:
                            calls[cname].append((sub, 1.0))
                            called.add(sub)
    roots = [c for c in comps if c not in called]
    mult = {c: 0.0 for c in comps}
    stack = [(r, 1.0) for r in roots]
    seen_guard = 0
    while stack:
        cname, m = stack.pop()
        mult[cname] += m
        seen_guard += 1
        if seen_guard > 100000:
            break
        for sub, k in calls.get(cname, []):
            stack.append((sub, m * k))

    # fused computations contribute zero *bytes* (their internals are not
    # HBM traffic) but their dots still count flops.
    fused_called_by_fusion = set()
    fusion_target: Dict[Tuple[str, str], str] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w\-.]+)", ins.rest)
                if m:
                    fused_called_by_fusion.add(m.group(1))
                    fusion_target[(cname, ins.name)] = m.group(1)

    # A fusion's result/operands count as HBM traffic only if it contains a
    # structural op (matmul/reduce/scatter/...).  Pure elementwise/convert
    # fusions — ubiquitous in CPU HLO because of bf16->f32 dot legalization —
    # fuse into their neighbors on TPU and move no extra HBM bytes.
    _STRUCTURAL = {"dot", "reduce", "scatter", "dynamic-update-slice",
                   "gather", "sort", "convolution", "dynamic-slice"}
    structural_fusion = {
        c: any(i.op in _STRUCTURAL for i in instrs)
        for c, instrs in comps.items()}

    flops = 0.0
    bytes_acc = 0.0
    bytes_acc_tpu = 0.0
    coll_bytes = 0.0
    coll_bytes_tpu = 0.0
    coll_count = 0
    coll_by_op: Dict[str, float] = {}
    legal_shapes = set()
    # pre-pass: every bf16 shape in the module (for the dtype correction)
    bf16_shapes = set()
    for instrs in comps.values():
        for ins in instrs:
            for dt, d in _shape_dims(ins.shape):
                if dt == "bf16":
                    bf16_shapes.add(d)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        tab = symtab[cname]
        for ins in instrs:
            dims_all = _shape_dims(ins.shape)
            # ---- flops (dots)
            if ins.op in ("dot", "dot-general") or ins.op.startswith("dot"):
                cm = _CONTRACT.search(ins.rest)
                contracting = ([int(x) for x in cm.group(1).split(",") if x]
                               if cm else [])
                ops = _OPERAND.findall(_split_operands(ins.rest))
                lhs_shape = tab.get(ops[0], "") if ops else ""
                lhs_dims = _shape_dims(lhs_shape)
                k = 1
                if lhs_dims:
                    ld = lhs_dims[0][1]
                    for c in contracting:
                        if c < len(ld):
                            k *= ld[c]
                result_elems = sum(math.prod(d) for _, d in dims_all)
                flops += m * 2.0 * result_elems * k
            # ---- bytes (fused-traffic model, see _INCLUDE_BYTES_OPS)
            if (ins.op == "fusion"
                    and not structural_fusion.get(
                        fusion_target.get((cname, ins.name), ""), True)):
                pass                      # elementwise-only fusion: no bytes
            elif (ins.op in _INCLUDE_BYTES_OPS
                    and cname not in fused_called_by_fusion):
                pairs = [_shape_bytes2(tab.get(o, ""), bf16_shapes) for o in
                         _OPERAND.findall(_split_operands(ins.rest))]
                op_bytes = [pq[0] for pq in pairs]
                op_bytes_t = [pq[1] for pq in pairs]
                r_raw, r_tpu = _shape_bytes2(ins.shape, bf16_shapes)
                inplace = (ins.op in ("dynamic-update-slice", "scatter")
                           or ins.name.startswith("dynamic-update-slice")
                           or ins.name.startswith("scatter"))
                sliced = (ins.op in ("dynamic-slice", "gather")
                          or ins.name.startswith("dynamic-slice")
                          or ins.name.startswith("gather"))
                if inplace and op_bytes:
                    # aliased in-place update: traffic = 2 x slice, not the
                    # whole buffer (XLA aliases the dest)
                    b = 2 * (sum(op_bytes) - max(op_bytes))
                    bt = 2 * (sum(op_bytes_t) - max(op_bytes_t))
                elif sliced:
                    b, bt = 2 * r_raw, 2 * r_tpu
                elif ins.op == "fusion":
                    # a fusion wrapping a dynamic-slice reads a *slice* of
                    # its big operand (e.g. the per-layer read of a saved
                    # carry stack inside the bwd loop) — cap each operand's
                    # traffic at the fusion's result size
                    b = r_raw + sum(min(o, r_raw) for o in op_bytes)
                    bt = r_tpu + sum(min(o, r_tpu) for o in op_bytes_t)
                else:
                    b = r_raw + sum(op_bytes)
                    bt = r_tpu + sum(op_bytes_t)
                bytes_acc += m * b
                bytes_acc_tpu += m * bt
            # ---- collectives
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in _COLL_OPS and not ins.op.endswith("-done"):
                rb, rb_tpu = _shape_bytes2(ins.shape, bf16_shapes)
                gm = _GROUPS_IOTA.search(ins.rest)
                if gm:
                    n = int(gm.group(2))
                else:
                    gm = _GROUPS_LIST.search(ins.rest)
                    n = len(gm.group(1).split(",")) if gm else 2
                if n > 1:
                    if base_op == "all-gather":
                        f = (n - 1) / n
                    elif base_op == "reduce-scatter":
                        f = float(n - 1)
                    elif base_op == "all-reduce":
                        f = 2.0 * (n - 1) / n
                    elif base_op == "all-to-all":
                        f = (n - 1) / n
                    else:
                        f = 1.0
                else:
                    f = 0.0
                coll_bytes += m * rb * f
                coll_bytes_tpu += m * rb_tpu * f
                coll_count += int(m)
                coll_by_op[base_op] = coll_by_op.get(base_op, 0.0) + m * rb * f
            # ---- CPU bf16->f32 legalization artifact (saved f32 stacks)
            if (ins.op == "dynamic-update-slice" and ins.shape.startswith("f32")
                    and dims_all and len(dims_all[0][1]) >= 4):
                legal_shapes.add(dims_all[0][1])

    legal_bytes = sum(math.prod(d) * 4 for d in legal_shapes
                      if d in bf16_shapes)
    return HloStats(
        flops=flops,
        bytes_accessed=bytes_acc,
        bytes_accessed_tpu=bytes_acc_tpu,
        collective_bytes=coll_bytes,
        collective_bytes_tpu=coll_bytes_tpu,
        collective_count=coll_count,
        collective_by_op=coll_by_op,
        while_trip_counts=trip_counts,
        cpu_bf16_legalization_bytes=legal_bytes,
    )
