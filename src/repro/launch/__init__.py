"""Launch layer: meshes, sharding rules, compiled steps, dry-run, drivers."""

from repro.launch.mesh import (
    base_rules,
    batch_shardings,
    make_host_mesh,
    make_mesh,
    make_production_mesh,
    rules_for,
    shardings_for,
    spec_for,
)
from repro.launch.steps import (
    chunked_softmax_ce,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    split_batch_by_shares,
)

__all__ = [
    "make_production_mesh", "make_mesh", "make_host_mesh", "base_rules",
    "rules_for", "shardings_for", "spec_for", "batch_shardings",
    "chunked_softmax_ce", "input_specs", "make_train_step",
    "make_prefill_step", "make_serve_step", "split_batch_by_shares",
]
