"""Serving driver: continuous batching with a UDS request scheduler.

Requests (variable prompt lengths) arrive in a queue; the UDS decides which
requests form the next decode batch — receiver-initiated self-scheduling
where decode slots are workers and requests are iterations.  Slots that
finish (EOS / max tokens) immediately dequeue the next request chunk, i.e.
``schedule(dynamic, 1)``; guided/factoring variants admit several requests
per dequeue when the queue is deep.

Decode runs **batched and fused** by default: all slots share one stacked
``[slots, max_len]`` KV cache with per-slot lengths, and each dispatch is
ONE jitted call that runs ``decode_steps`` tokens for the whole team via an
on-device ``lax.scan`` (``make_fused_serve_step``) with per-slot stop/EOS/
length handling carried in the loop state — a slot that finishes its
request mid-dispatch freezes in place while the others keep decoding.  The
dispatch quantum ``decode_steps`` is a schedule parameter: T=1 reproduces
the stepwise engine token for token (greedy decode is deterministic, so
any T does — locked down in ``tests/test_serve.py``); larger T amortizes
the Python→XLA round-trip over T tokens at the cost of admission latency
(idle slots re-enter the team only at dispatch boundaries).

Admission prefills a request at batch=1 and scatters its cache into the
slot's row (``model.insert_prefill``), so in-flight slots are untouched.
Prompts are right-padded to power-of-two length *buckets* before the
jitted prefill — causal masking makes the padded prefix math identical, so
a long tail of distinct prompt lengths compiles one program per bucket
instead of one per length (~0.8s per avoided recompile on the smoke
config).  The per-slot escape hatch (``batched=False`` / ``--per-slot``:
one jit call per active slot per token over per-slot batch-1 caches)
remains token-for-token identical, and is the automatic fallback for
SSM/hybrid families.  UDS admission semantics are IDENTICAL in both modes:
the scheduler sees the same slots, the same dequeue order, and the same
chunk feedback protocol.

A request whose ``prompt + max_new`` exceeds the cache is admitted but
**truncated**: its generation budget is clamped to cache capacity and the
truncation is reported per request (``Request.truncated``,
``last_stats["truncated"]``) — never silently padded or dropped.  A prompt
that alone exceeds ``max_len`` is still refused loudly.

The loop is instrumented with :class:`~repro.core.telemetry.LoopTelemetry`:
every chunk's **full wall time** — the prefill of each of its requests plus
every decode dispatch of their generations — is attributed to the slot that
served it (one fused dispatch's wall time splits equally across the slots
it advanced, each credited its OWN produced-token count), fed back through
``stream.next`` (so within-invocation adaptive strategies like AWF-B
rebalance admission mid-run), and flushed into the loop's ``LoopHistory``
when the stream closes.  The flush bumps the history's measured epoch, so
a cached adaptive plan for this loop is invalidated and the *next*
``run()`` replans admission from the measured slot speeds (AWF timestep).
``ServeLoop.history`` persists across calls — pass one in to persist
across processes (it serializes with checkpoints).

**Paged mode** (``--paged-kv`` / :class:`PagedServeLoop`) replaces the
stacked per-slot cache with a shared block pool (``repro.serve_mem``):
cache MEMORY becomes the scheduled resource.  Requests are admitted when
blocks for their prompt are free (not when a slot opens), prompts prefill
in UDS-planned chunks that interleave with decode dispatches, sequences
grow block-by-block as they generate, and under memory pressure the most
recently admitted request is preempted — blocks freed, requeued at the
front, later re-prefilled with its generated prefix (greedy decode makes
the resumed request token-for-token identical to an uninterrupted run).
See docs/SCHEDULING.md, "Paged KV and continuous batching".

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 16 \
        --decode-steps 8
    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 32 \
        --paged-kv --num-blocks 48 --block-size 8 --max-concurrency 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                        MembershipEvent, SchedulerContext, ServeMeter,
                        get_engine)
from repro.core.spec import SpecLike, describe, resolve
from repro.launch.steps import (make_fused_serve_step, make_paged_prefill_step,
                                make_paged_serve_step, make_prefill_step,
                                make_serve_step)
from repro.models import get_model
from repro.serve_mem import BlockPool, BlockTables
from repro.serve_mem.blocks import blocks_for_tokens

__all__ = ["ServeLoop", "PagedServeLoop", "Request", "bucket_length",
           "plan_prefill_chunks", "main"]

# smallest prefill bucket: tiny prompts share one program instead of
# compiling at 1, 2, 3, ... tokens
MIN_PREFILL_BUCKET = 8


def bucket_length(n: int, max_len: int) -> int:
    """Prompt-length bucket: next power of two >= n (floored at
    ``MIN_PREFILL_BUCKET``), capped at ``max_len``.  One jitted prefill
    compilation per bucket serves every prompt length inside it."""
    b = MIN_PREFILL_BUCKET
    while b < n:
        b *= 2
    return min(b, max_len)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int = 16
    generated: Optional[List[int]] = None
    # generation budget = min(max_new, cache capacity), set at admission;
    # truncated=True when the cache clamped the request below max_new
    budget: int = 0
    truncated: bool = False
    # lifecycle stamps (perf_counter clock, set by the serve loops):
    # arrival -> admission is queue latency, admission -> first token is
    # admission latency, arrival -> finish is e2e.  Preemption does NOT
    # reset stamps — the wait is part of the request's latency.
    t_arrive: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    # paged engine bookkeeping: admission sequence (LIFO preemption
    # victim order) and how many times this request was evicted
    admit_seq: int = -1
    preemptions: int = 0


class ServeLoop:
    """Continuous batching over a fixed decode-slot count.

    ``decode_steps`` is the dispatch quantum: tokens generated per jitted
    call in batched mode (1 = the stepwise engine).  ``history`` carries
    measured per-slot chunk times across ``run()`` invocations — the
    serving steady state's feedback channel.  After each run,
    ``last_stats`` holds the telemetry summary (per-slot busy time,
    tokens, tok/s, decode dispatch counts, truncations, measured epoch).
    """

    def __init__(self, cfg, *, slots: int = 4, max_len: int = 256,
                 scheduler: SpecLike = "dynamic", seed: int = 0,
                 history: Optional[LoopHistory] = None,
                 batched: bool = True, decode_steps: int = 1,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got {decode_steps}")
        key = jax.random.PRNGKey(seed)
        self.params, _ = self.model.init(key, jnp.float32)
        # any schedule-clause form: spec, "guided,4", "uds:name", "runtime",
        # or a scheduler instance
        self.scheduler = scheduler
        self.sched_name = describe(scheduler)
        self.loop_id = "serve"
        self.history = history if history is not None else LoopHistory()
        self.last_stats: Dict[str, Any] = {}
        self.eos_id = eos_id
        # jitted prefill, compiled once per prompt-length BUCKET: prompts
        # are right-padded to power-of-two buckets and the real length is
        # passed as a traced scalar (causal masking makes the padded math
        # identical), so a long tail of distinct lengths stops triggering
        # ~0.8s recompiles mid-serve.  SSM/hybrid prefills absorb pad
        # tokens into their recurrent state, so only attention families
        # (those with a batched decode path) bucket.
        self._prefill = jax.jit(make_prefill_step(self.model,
                                                  max_len=max_len))
        self._bucketed = self.model.batched_decode is not None
        # SSM/hybrid families have no stacked-cache decode yet: fall back
        # to the per-slot path rather than refuse to serve
        self.batched = bool(batched and self.model.batched_decode is not None)
        self.decode_steps = decode_steps if self.batched else 1
        if self.batched:
            # one stacked [slots, max_len] cache, per-slot lengths; ONE
            # jitted dispatch per decode_steps tokens across all active
            # slots (an on-device scan with per-slot stop handling)
            self._decode_fused = jax.jit(
                make_fused_serve_step(self.model, self.decode_steps))
            self._insert = jax.jit(self.model.insert_prefill)
            self.cache = self.model.init_batched_decode(
                slots, max_len, dtype=jnp.float32)[0]
            self.caches = None
        else:
            # per-slot state: one cache per slot (batch=1), one jit call
            # per active slot per token — the escape hatch / SSM path
            self._decode = jax.jit(make_serve_step(self.model))
            self.caches = [self.model.init_decode(1, max_len,
                                                  dtype=jnp.float32)[0]
                           for _ in range(slots)]
        self.active: Dict[int, Request] = {}
        self._dispatches = 0
        self._decoded = 0

    @property
    def mode(self) -> str:
        return "batched" if self.batched else "per_slot"

    @property
    def prefill_compiles(self) -> int:
        """Distinct compiled prefill programs (the bucketing regression
        metric: mixed prompt lengths must not grow this per-length)."""
        return self._prefill._cache_size()

    def _prefill_into(self, slot: int, req: Request) -> int:
        P = int(req.prompt.size)
        # the cache holds the prompt plus one KV per decode step; capacity
        # is how many tokens can be generated before the fill hits max_len
        # (the first token comes from the prefill logits and appends
        # nothing).  A prompt that alone overflows the cache is refused
        # loudly; a generation that would overflow is admitted with its
        # budget clamped and the truncation REPORTED per request.
        capacity = self.max_len - P + 1
        if capacity < 1:
            raise ValueError(
                f"request {req.rid}: prompt ({P} tokens) exceeds the "
                f"cache (max_len={self.max_len}); raise ServeLoop max_len "
                f"or shorten the request")
        req.budget = min(req.max_new, capacity)
        req.truncated = req.budget < req.max_new
        tokens = req.prompt
        if self._bucketed:
            pb = bucket_length(P, self.max_len)
            if pb > P:
                tokens = np.concatenate(
                    [tokens, np.zeros(pb - P, tokens.dtype)])
            inputs = {"tokens": jnp.asarray(tokens[None, :])}
            logits, cache = self._prefill(self.params, inputs,
                                          jnp.asarray(P, jnp.int32))
        else:
            inputs = {"tokens": jnp.asarray(tokens[None, :])}
            logits, cache = self._prefill(self.params, inputs)
        if self.batched:
            # masked scatter into the slot's row of the stacked cache;
            # every other (possibly in-flight) slot is untouched
            self.cache = self._insert(self.cache, cache, slot)
        else:
            self.caches[slot] = cache
        tok = int(jnp.argmax(logits, -1)[0])
        req.generated = [tok]
        return tok

    def _finished_at_admission(self, req: Request, tok: int) -> bool:
        """Budget of 1 (or an immediate EOS) completes at prefill."""
        if len(req.generated) >= req.budget:
            return True
        return self.eos_id is not None and tok == self.eos_id

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Schedule + serve all requests to completion."""
        sched = resolve(self.scheduler)
        loop = LoopSpec(lb=0, ub=len(requests), num_workers=self.slots,
                        loop_id=self.loop_id)
        telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                  num_workers=self.slots)
        stream = get_engine().open_stream(
            sched, SchedulerContext(loop=loop, history=self.history),
            telemetry=telemetry)
        meter = ServeMeter()
        now = time.perf_counter()
        for req in requests:
            if req.t_arrive is None:
                req.t_arrive = now
            meter.arrive(req.rid, req.t_arrive)
        queue: Deque[Request] = deque(requests)
        pending: Dict[int, Deque[Request]] = {s: deque()
                                              for s in range(self.slots)}
        # per-chunk wall time of the slot's *previous* chunk (prefill +
        # all decode dispatches), consumed by the next dequeue and then
        # cleared — never a stale prefill-only value
        elapsed: Dict[int, Optional[float]] = {s: None
                                               for s in range(self.slots)}
        results: Dict[int, List[int]] = {}
        truncated: List[int] = []
        slots_open = set(range(self.slots))
        exhausted = set()
        self._dispatches = 0
        self._decoded = 0
        eos_arr = jnp.asarray(-1 if self.eos_id is None else self.eos_id,
                              jnp.int32)

        def finish(s: int, req: Request) -> None:
            results[req.rid] = req.generated
            req.t_finish = time.perf_counter()
            meter.finish(req.rid, req.t_finish)
            if req.truncated:
                truncated.append(req.rid)

        while len(results) < len(requests):
            # admission: idle slots dequeue request chunks via the UDS,
            # reporting the measured wall time of their previous chunk
            for s in list(slots_open):
                if s in self.active or pending[s]:
                    continue
                if s in exhausted:
                    continue
                chunk = stream.next(s, elapsed[s])
                elapsed[s] = None              # consumed by this dequeue
                if chunk is None:
                    exhausted.add(s)
                    continue
                telemetry.begin(s, chunk)
                for i in range(chunk.start, chunk.stop):
                    pending[s].append(requests[i])
            progressed = False
            for s in range(self.slots):
                if s not in self.active and pending[s]:
                    req = pending[s].popleft()
                    t0 = time.perf_counter()
                    if req.t_admit is None:
                        req.t_admit = t0
                    meter.admit(req.rid, t0)
                    tok = self._prefill_into(s, req)
                    t1 = time.perf_counter()
                    if req.t_first is None:
                        req.t_first = t1
                    meter.first_token(req.rid, t1)
                    telemetry.add_time(s, t1 - t0, tokens=1)
                    progressed = True
                    if self._finished_at_admission(req, tok):
                        finish(s, req)
                        if not pending[s]:
                            elapsed[s] = telemetry.end(s)
                    else:
                        self.active[s] = req
            # one decode dispatch across active slots
            done_slots = []
            if self.batched and self.active:
                act = sorted(self.active)
                last = np.zeros((self.slots, 1), np.int32)
                mask = np.zeros((self.slots,), bool)
                rem = np.zeros((self.slots,), np.int32)
                for s in act:
                    req = self.active[s]
                    last[s, 0] = req.generated[-1]
                    mask[s] = True
                    rem[s] = req.budget - len(req.generated)
                t0 = time.perf_counter()
                toks, self.cache, act_out, rem_out = self._decode_fused(
                    self.params, {"tokens": jnp.asarray(last)},
                    self.cache, jnp.asarray(mask), jnp.asarray(rem),
                    eos_arr)
                toks = np.asarray(toks)     # device sync: true wall time
                act_out = np.asarray(act_out)
                rem_out = np.asarray(rem_out)
                dt = time.perf_counter() - t0
                self._dispatches += 1
                # one call served every active slot in lockstep: equal
                # wall-time shares keep per-slot attribution (AWF still
                # replans per slot), each slot credited the tokens IT
                # produced before freezing
                produced = {s: int(rem[s] - rem_out[s]) for s in act}
                telemetry.add_time_split(act, dt, tokens=produced)
                self._decoded += sum(produced.values())
                progressed = True
                for s in act:
                    req = self.active[s]
                    req.generated.extend(
                        int(t) for t in toks[s, :produced[s]])
                    if not act_out[s]:      # quota / EOS / capacity freeze
                        finish(s, req)
                        done_slots.append(s)
            else:
                for s, req in list(self.active.items()):
                    last = req.generated[-1]
                    t0 = time.perf_counter()
                    tok, cache = self._decode(
                        self.params, {"tokens": jnp.asarray([[last]])},
                        self.caches[s])
                    self.caches[s] = cache
                    req.generated.append(int(tok[0]))
                    telemetry.add_time(s, time.perf_counter() - t0, tokens=1)
                    self._dispatches += 1
                    self._decoded += 1
                    progressed = True
                    done = len(req.generated) >= req.budget
                    if (self.eos_id is not None
                            and req.generated[-1] == self.eos_id):
                        done = True
                    if done:
                        finish(s, req)
                        done_slots.append(s)
            for s in done_slots:
                del self.active[s]
                if not pending[s]:
                    # the chunk is fully served: close its ledger and hand
                    # its wall time to the slot's next dequeue
                    elapsed[s] = telemetry.end(s)
            if not progressed:
                break
        stream.close()        # flushes telemetry -> history epoch bump
        self.last_stats = telemetry.summary()
        self.last_stats["mode"] = self.mode
        self.last_stats["decode_steps"] = self.decode_steps
        self.last_stats["decode_dispatches"] = self._dispatches
        self.last_stats["decoded_tokens"] = self._decoded
        self.last_stats["dispatches_per_token"] = (
            round(self._dispatches / self._decoded, 4) if self._decoded
            else None)
        self.last_stats["truncated"] = sorted(truncated)
        self.last_stats["prefill_compiles"] = self.prefill_compiles
        self.last_stats["serve_meter"] = meter.summary()
        return results

    def measured_epoch(self) -> int:
        """Measured-invocation count for the serve loop — the plan-cache
        epoch adaptive admission schedules key on."""
        return self.history.measured_invocations(self.loop_id)


def plan_prefill_chunks(scheduler: SpecLike, n_tokens: int, *,
                        max_chunk: int,
                        history: Optional[LoopHistory] = None) -> List[int]:
    """Split one prompt's prefill into chunk sizes via the UDS spine.

    The prompt's token range ``[0, n_tokens)`` is planned as a
    single-worker loop under the serve scheduler clause, so the SAME
    ``--scheduler`` string that the loop serves under also governs how
    coarsely prefill interleaves with decode: ``schedule(static)``
    prefills in bursts of ``max_chunk``, ``schedule(dynamic,1)`` yields
    minimal chunks (lowest head-of-line blocking for in-flight decodes,
    most dispatches), ``guided`` starts coarse and refines toward the
    prompt's tail, and ``auto`` picks online from ``serve_prefill``
    telemetry.  Planned sizes are capped at ``max_chunk``; the caller
    bucket-pads each chunk at dispatch (:func:`bucket_length`), so the
    compile count is bounded by the bucket count, never by chunk-size
    variety.
    """
    if n_tokens <= 0:
        return []
    if max_chunk < 1:
        raise ValueError(f"max_chunk must be >= 1, got {max_chunk}")
    sched = resolve(scheduler)
    loop = LoopSpec(lb=0, ub=n_tokens, num_workers=1,
                    loop_id="serve_prefill")
    plan = get_engine().plan(sched, loop, history=history)
    order = np.argsort(np.asarray(plan.starts, np.int64), kind="stable")
    sizes: List[int] = []
    for i in order:
        rem = int(plan.sizes[i])
        while rem > 0:
            c = min(rem, max_chunk)
            sizes.append(c)
            rem -= c
    if sum(sizes) != n_tokens:
        raise AssertionError(
            f"prefill plan does not tile [0, {n_tokens}): {sizes}")
    return sizes


@dataclasses.dataclass
class _Prefill:
    """One in-flight chunked prefill (batch=1) through the paged pool."""

    req: Request
    tokens: np.ndarray            # prompt (+ generated prefix on readmit)
    sizes: List[int]              # UDS-planned chunk sizes, in order
    idx: int = 0                  # next chunk
    start: int = 0                # tokens already cached


class PagedServeLoop:
    """Continuous batching over a paged KV block pool.

    Where :class:`ServeLoop` schedules a fixed set of ``slots`` (each
    owning a dense ``max_len`` cache row), this engine schedules cache
    MEMORY: every request draws fixed-size KV blocks from one shared
    :class:`~repro.serve_mem.BlockPool` as its sequence grows, so
    concurrency is bounded by total cache tokens, not by a slot count.
    The loop interleaves three kinds of work:

    * **admission** — the next queued request is admitted when blocks for
      its prompt are free; its prefill is split into UDS-planned chunks
      (:func:`plan_prefill_chunks`) so long prompts never block in-flight
      decodes for more than one chunk.
    * **decode** — ONE fused dispatch advances every active request
      ``decode_steps`` tokens (``make_paged_serve_step``).  Before each
      dispatch, rows grow their block tables to cover the dispatch's
      appends; a row that cannot grow triggers **preemption**: the most
      recently admitted victim's blocks are freed and it is requeued at
      the FRONT with its generated prefix.  Readmission prefills
      ``prompt + generated`` — greedy decode is deterministic, so the
      resumed request is token-for-token identical to an uninterrupted
      run (locked in ``tests/test_paged.py``).
    * **finish** — completed requests release every block immediately.

    ``max_context`` is the per-request ceiling (the dense engine's
    ``max_len``); budgets clamp/truncate against it exactly as in
    :class:`ServeLoop`.  ``concurrency`` is only the fused dispatch's
    batch width (compiled once) — memory admission happens first.
    """

    def __init__(self, cfg, *, num_blocks: int = 64, block_size: int = 8,
                 max_context: int = 256, concurrency: int = 8,
                 scheduler: SpecLike = "dynamic", seed: int = 0,
                 history: Optional[LoopHistory] = None,
                 decode_steps: int = 1, eos_id: Optional[int] = None,
                 prefill_chunk: int = 32,
                 kill_rows: int = 0,
                 kill_at_dispatch: Optional[int] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        if self.model.fused_paged_decode is None:
            raise ValueError(
                f"{cfg.name}: model family has no paged-KV path "
                f"(use ServeLoop's per-slot engine)")
        if max_context % block_size:
            raise ValueError(
                f"max_context ({max_context}) must be a multiple of "
                f"block_size ({block_size})")
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got {decode_steps}")
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        if kill_rows < 0 or kill_rows >= concurrency:
            raise ValueError(
                f"kill_rows must leave at least one live dispatch row "
                f"(got kill_rows={kill_rows}, concurrency={concurrency})")
        if (kill_rows > 0) != (kill_at_dispatch is not None):
            raise ValueError(
                "kill_rows and kill_at_dispatch must be given together")
        self.params, _ = self.model.init(jax.random.PRNGKey(seed),
                                         jnp.float32)
        self.scheduler = scheduler
        self.sched_name = describe(scheduler)
        self.loop_id = "serve_paged"
        self.history = history if history is not None else LoopHistory()
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_context = max_context
        self.max_blocks_per_seq = max_context // block_size
        self.concurrency = concurrency
        self.decode_steps = decode_steps
        self.prefill_chunk = prefill_chunk
        self.eos_id = eos_id
        self.pool = BlockPool(num_blocks, block_size)
        self.tables = BlockTables(self.pool,
                                  max_blocks=self.max_blocks_per_seq)
        self.cache = self.model.init_paged_decode(num_blocks, block_size,
                                                  dtype=jnp.float32)[0]
        # one compile per prefill BUCKET (chunks are bucket-padded) and
        # ONE decode program (fixed (concurrency, W) dispatch shape)
        self._prefill_step = jax.jit(make_paged_prefill_step(self.model))
        self._decode = jax.jit(make_paged_serve_step(self.model,
                                                     decode_steps))
        self.active: Dict[int, Request] = {}        # dispatch row -> req
        self.last_stats: Dict[str, Any] = {}
        self._dispatches = 0
        self._decoded = 0
        self._pf_dispatches = 0
        # elastic slot-set shrink: an injected worker kill marks the top
        # kill_rows dispatch rows dead at the kill_at_dispatch-th decode
        # dispatch — their in-flight requests drain through the normal
        # evict-requeue machinery and readmit on surviving rows
        self._kill_rows = kill_rows
        self._kill_at = kill_at_dispatch
        self._kill_fired = False
        self._dead_rows: set = set()
        self.membership_events: List[MembershipEvent] = []
        # per-dispatch measurement log (elastic_recovery bench splits it
        # at the kill dispatch): wall time, produced tokens, live rows
        self.dispatch_log: List[Dict[str, Any]] = []

    @property
    def mode(self) -> str:
        return "paged"

    @property
    def prefill_compiles(self) -> int:
        """Distinct compiled prefill-chunk programs (bounded by the
        bucket count — the chunked-prefill bucketing regression metric)."""
        return self._prefill_step._cache_size()

    def measured_epoch(self) -> int:
        """Measured-invocation count for the paged serve loop."""
        return self.history.measured_invocations(self.loop_id)

    # ----------------------------------------------------------- internals
    def _fill_of(self, req: Request) -> int:
        """Cached KV positions: the prompt plus one per generated token
        except the newest (its KV lands at the next dispatch)."""
        return int(req.prompt.size) + len(req.generated) - 1

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Admit, prefill, decode, preempt as needed — to completion."""
        meter = ServeMeter()
        telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                  num_workers=1)
        pf_tel = LoopTelemetry(self.history, loop_id="serve_prefill",
                               num_workers=1)
        now = time.perf_counter()
        for req in requests:
            if req.t_arrive is None:
                req.t_arrive = now
            meter.arrive(req.rid, req.t_arrive)
        meter.blocks(self.pool.used, self.pool.num_blocks, now)
        queue: Deque[Request] = deque(requests)
        requeue: Deque[Request] = deque()     # preempted; front of the line
        results: Dict[int, List[int]] = {}
        truncated: List[int] = []
        pf: Optional[_Prefill] = None
        admit_seq = 0
        peak_conc = 0
        self._dispatches = 0
        self._decoded = 0
        self._pf_dispatches = 0
        self.dispatch_log = []
        C, W = self.concurrency, self.max_blocks_per_seq
        eos_arr = jnp.asarray(-1 if self.eos_id is None else self.eos_id,
                              jnp.int32)

        def finish(req: Request) -> None:
            results[req.rid] = req.generated
            req.t_finish = time.perf_counter()
            meter.finish(req.rid, req.t_finish)
            if req.truncated:
                truncated.append(req.rid)
            self.tables.release(req.rid)
            meter.blocks(self.pool.used, self.pool.num_blocks, req.t_finish)

        def preempt_one(exclude_rid: int) -> bool:
            """Evict the most recently admitted active request (LIFO:
            the oldest request keeps its memory — FIFO completion order
            survives pressure) and requeue it at the front."""
            rows = [r for r, rq in self.active.items()
                    if rq.rid != exclude_rid]
            if not rows:
                return False
            victim = max(rows, key=lambda r: self.active[r].admit_seq)
            rq = self.active.pop(victim)
            self.tables.release(rq.rid)
            rq.preemptions += 1
            meter.preempt(rq.rid)
            meter.blocks(self.pool.used, self.pool.num_blocks,
                         time.perf_counter())
            requeue.appendleft(rq)
            return True

        while len(results) < len(requests):
            progressed = False
            ran_prefill = False

            # ---- injected worker kill: a slot-set shrink is a membership
            # event.  The doomed rows' in-flight requests drain through
            # the evict-requeue machinery (blocks freed, front of the
            # line) and readmit on surviving rows; greedy decode makes
            # every resumed request token-for-token identical to an
            # unkilled run.  The fused dispatch keeps its compiled
            # (C, W) shape — dead rows just stay mask-gated off.
            if (self._kill_at is not None and not self._kill_fired
                    and self._dispatches >= self._kill_at):
                self._kill_fired = True
                doomed = set(range(C - self._kill_rows, C))
                self._dead_rows |= doomed
                # evict newest-first so appendleft leaves the requeue in
                # admit order (oldest victim readmits first)
                for r in sorted((r for r in doomed if r in self.active),
                                key=lambda r: self.active[r].admit_seq,
                                reverse=True):
                    rq = self.active.pop(r)
                    self.tables.release(rq.rid)
                    rq.preemptions += 1
                    meter.preempt(rq.rid)
                    requeue.appendleft(rq)
                meter.blocks(self.pool.used, self.pool.num_blocks,
                             time.perf_counter())
                event = MembershipEvent(
                    kind="loss", old_size=C,
                    new_size=C - len(self._dead_rows),
                    lost=tuple(sorted(doomed)), step=self._dispatches)
                telemetry.record_membership(event)
                # the serve loop's telemetry worker is the fused
                # dispatcher, not a row — keep the summary single-worker
                telemetry.num_workers = 1
                self.membership_events.append(event)

            # ---- admission: memory first (blocks for the prompt), then a
            # dispatch row; preempted requests readmit ahead of the queue
            if (pf is None and (requeue or queue)
                    and len(self.active) < C - len(self._dead_rows)):
                src = requeue if requeue else queue
                req = src[0]
                if req.budget == 0:    # first admission: fix the budget
                    P = int(req.prompt.size)
                    capacity = self.max_context - P + 1
                    if capacity < 1:
                        raise ValueError(
                            f"request {req.rid}: prompt ({P} tokens) "
                            f"exceeds max_context={self.max_context}; "
                            f"raise PagedServeLoop max_context or shorten "
                            f"the request")
                    req.budget = min(req.max_new, capacity)
                    req.truncated = req.budget < req.max_new
                tokens = req.prompt
                if req.generated:      # readmission: replay the prefix
                    tokens = np.concatenate(
                        [tokens, np.asarray(req.generated, np.int32)])
                n_all = int(tokens.size)
                if self.tables.ensure(req.rid, n_all):
                    src.popleft()
                    req.admit_seq = admit_seq
                    admit_seq += 1
                    t = time.perf_counter()
                    if req.t_admit is None:
                        req.t_admit = t
                    meter.admit(req.rid, t)
                    meter.blocks(self.pool.used, self.pool.num_blocks, t)
                    pf = _Prefill(req=req, tokens=tokens,
                                  sizes=plan_prefill_chunks(
                                      self.scheduler, n_all,
                                      max_chunk=self.prefill_chunk,
                                      history=self.history))
                    progressed = True
                elif not self.active:
                    # every block is free and the prompt still doesn't
                    # fit: the pool itself is too small for this request
                    raise ValueError(
                        f"request {req.rid}: {n_all} tokens need "
                        f"{blocks_for_tokens(n_all, self.block_size)} "
                        f"blocks but the pool has {self.pool.num_blocks}; "
                        f"raise num_blocks")

            # ---- one prefill chunk per turn while admission can progress
            if pf is not None:
                ran_prefill = True
                n = pf.sizes[pf.idx]
                pb = bucket_length(n, self.prefill_chunk)
                buf = np.zeros((1, pb), np.int32)
                buf[0, :n] = pf.tokens[pf.start:pf.start + n]
                t0 = time.perf_counter()
                logits, self.cache = self._prefill_step(
                    self.params, {"tokens": jnp.asarray(buf)}, self.cache,
                    jnp.asarray(self.tables.row(pf.req.rid)),
                    jnp.asarray(pf.start, jnp.int32),
                    jnp.asarray(n, jnp.int32))
                logits = np.asarray(logits)     # sync: true chunk time
                dt = time.perf_counter() - t0
                pf_tel.record_chunk(0, pf.start, pf.start + n, dt, tokens=n)
                self._pf_dispatches += 1
                pf.start += n
                pf.idx += 1
                progressed = True
                if pf.idx == len(pf.sizes):     # prompt fully cached
                    req = pf.req
                    pf = None
                    tok = int(np.argmax(logits[0]))
                    if req.generated is None:
                        req.generated = []
                    req.generated.append(tok)
                    t1 = time.perf_counter()
                    if req.t_first is None:
                        req.t_first = t1
                    meter.first_token(req.rid, t1)
                    done = len(req.generated) >= req.budget
                    if self.eos_id is not None and tok == self.eos_id:
                        done = True
                    if done:
                        finish(req)
                    else:
                        row = min(r for r in range(C)
                                  if r not in self.active
                                  and r not in self._dead_rows)
                        self.active[row] = req
                        peak_conc = max(peak_conc, len(self.active))

            # ---- one fused decode dispatch across every active row.
            # Admission has priority: decode runs when prefill could NOT
            # progress this turn (queue empty, pool full, or concurrency
            # cap) — occupancy builds while blocks are free, and under
            # memory pressure the loop alternates admission attempts with
            # decode dispatches at chunk granularity, which is exactly the
            # prefill/decode interleave the scheduler clause governs.
            if self.active and not ran_prefill:
                # grow tables oldest-first so the head of the line wins
                # under pressure; LIFO victims free blocks as needed
                for r in sorted(self.active,
                                key=lambda r: self.active[r].admit_seq):
                    if r not in self.active:    # preempted this turn
                        continue
                    rq = self.active[r]
                    total_need = int(rq.prompt.size) + rq.budget - 1
                    need = min(self._fill_of(rq) + self.decode_steps,
                               total_need)
                    while not self.tables.ensure(rq.rid, need):
                        if not preempt_one(exclude_rid=rq.rid):
                            raise ValueError(
                                f"request {rq.rid}: cannot grow to {need} "
                                f"tokens with every other request evicted "
                                f"— the pool ({self.num_blocks} blocks) "
                                f"is smaller than one request's context; "
                                f"raise num_blocks")
                meter.blocks(self.pool.used, self.pool.num_blocks,
                             time.perf_counter())
                rows = sorted(self.active)
                last = np.zeros((C, 1), np.int32)
                mask = np.zeros((C,), bool)
                rem = np.zeros((C,), np.int32)
                lens = np.zeros((C,), np.int32)
                lims = np.zeros((C,), np.int32)
                tab = np.full((C, W), -1, np.int32)
                for r in rows:
                    rq = self.active[r]
                    last[r, 0] = rq.generated[-1]
                    mask[r] = True
                    rem[r] = rq.budget - len(rq.generated)
                    lens[r] = self._fill_of(rq)
                    lims[r] = self.tables.capacity(rq.rid)
                    tab[r] = self.tables.row(rq.rid)
                t0 = time.perf_counter()
                toks, self.cache, _, act_out, rem_out = self._decode(
                    self.params, {"tokens": jnp.asarray(last)}, self.cache,
                    jnp.asarray(tab), jnp.asarray(lens), jnp.asarray(lims),
                    jnp.asarray(mask), jnp.asarray(rem), eos_arr)
                toks = np.asarray(toks)         # sync: true dispatch time
                rem_out = np.asarray(rem_out)
                dt = time.perf_counter() - t0
                produced_total = int(rem[mask].sum() - rem_out[mask].sum())
                telemetry.record_chunk(0, self._dispatches,
                                       self._dispatches + 1, dt,
                                       tokens=produced_total)
                self.dispatch_log.append(
                    {"dispatch": self._dispatches, "dt_s": dt,
                     "tokens": produced_total, "rows": len(rows),
                     "live_rows": C - len(self._dead_rows)})
                self._dispatches += 1
                progressed = True
                for r in rows:
                    rq = self.active[r]
                    produced = int(rem[r] - rem_out[r])
                    rq.generated.extend(int(t) for t in toks[r, :produced])
                    self._decoded += produced
                    done = len(rq.generated) >= rq.budget
                    if (self.eos_id is not None
                            and rq.generated[-1] == self.eos_id):
                        done = True
                    if done:
                        del self.active[r]
                        finish(rq)
                    # a capacity-frozen row just stays active: the next
                    # turn's growth phase gets it more blocks (or preempts
                    # someone to)

            if not progressed:
                break
        telemetry.flush()
        pf_tel.flush()
        self.last_stats = telemetry.summary()
        self.last_stats.update(meter.summary())
        self.last_stats["mode"] = self.mode
        self.last_stats["decode_steps"] = self.decode_steps
        self.last_stats["decode_dispatches"] = self._dispatches
        self.last_stats["decoded_tokens"] = self._decoded
        self.last_stats["prefill_dispatches"] = self._pf_dispatches
        self.last_stats["prefill_compiles"] = self.prefill_compiles
        self.last_stats["truncated"] = sorted(truncated)
        self.last_stats["peak_concurrency"] = peak_conc
        self.last_stats["num_blocks"] = self.num_blocks
        self.last_stats["block_size"] = self.block_size
        self.last_stats["peak_blocks_used"] = self.pool.peak_used
        self.last_stats["failed_allocs"] = self.pool.failed_allocs
        self.last_stats["dead_rows"] = sorted(self._dead_rows)
        self.last_stats["live_rows"] = C - len(self._dead_rows)
        self.last_stats["membership_events"] = [
            {"kind": e.kind, "old_size": e.old_size, "new_size": e.new_size,
             "lost": list(e.lost), "at_dispatch": e.step}
            for e in self.membership_events]
        return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--scheduler", default="dynamic",
                    help='schedule clause: "dynamic", "guided,4", '
                         '"uds:name(args)", "runtime" (late-bound from '
                         '$REPRO_SCHEDULE), or "auto" (selected online '
                         "from serve telemetry; see docs/SCHEDULING.md)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="tokens per fused decode dispatch (batched mode): "
                         "1 = the stepwise engine; 8 amortizes the "
                         "Python->XLA round-trip over 8 tokens")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (per-slot on-device stop in fused "
                         "mode); default: generate to the token budget")
    ap.add_argument("--batched", dest="batched", action="store_true",
                    default=True,
                    help="one jitted dispatch per decode-steps tokens "
                         "across all active slots over a stacked KV cache "
                         "(default)")
    ap.add_argument("--per-slot", dest="batched", action="store_false",
                    help="escape hatch: one decode call per active slot "
                         "per token over per-slot batch-1 caches")
    ap.add_argument("--paged-kv", action="store_true",
                    help="serve through the paged-KV block pool "
                         "(continuous batching: admission by free blocks, "
                         "chunked prefill, preemption under pressure)")
    ap.add_argument("--num-blocks", type=int, default=64,
                    help="paged mode: KV blocks in the shared pool")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged mode: token positions per KV block")
    ap.add_argument("--max-context", type=int, default=64,
                    help="paged mode: per-request context ceiling "
                         "(prompt + generated); must be a multiple of "
                         "--block-size")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="paged mode: max tokens per prefill chunk (the "
                         "UDS plans the chunking under --scheduler)")
    ap.add_argument("--max-concurrency", type=int, default=8,
                    help="paged mode: fused dispatch batch width (compiled "
                         "once); memory admission happens first")
    ap.add_argument("--kill-rows", type=int, default=0,
                    help="paged mode: injected worker kill — mark this "
                         "many dispatch rows dead mid-run (drain-and-"
                         "readmit; requires --kill-at-dispatch)")
    ap.add_argument("--kill-at-dispatch", type=int, default=None,
                    help="paged mode: decode dispatch count at which the "
                         "injected kill fires")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 24)
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    if args.paged_kv:
        loop = PagedServeLoop(cfg, num_blocks=args.num_blocks,
                              block_size=args.block_size,
                              max_context=args.max_context,
                              concurrency=args.max_concurrency,
                              scheduler=args.scheduler,
                              decode_steps=args.decode_steps,
                              eos_id=args.eos_id,
                              prefill_chunk=args.prefill_chunk,
                              kill_rows=args.kill_rows,
                              kill_at_dispatch=args.kill_at_dispatch)
    else:
        loop = ServeLoop(cfg, slots=args.slots, scheduler=args.scheduler,
                         batched=args.batched,
                         decode_steps=args.decode_steps,
                         eos_id=args.eos_id)
    t0 = time.perf_counter()
    out = loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    if args.paged_kv:
        s = loop.last_stats
        print(f"served {len(out)} requests, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s, paged decode x{loop.decode_steps}) "
              f"under schedule({loop.sched_name}); "
              f"peak concurrency {s.get('peak_concurrency')}, "
              f"{s.get('peak_blocks_used')}/{loop.num_blocks} blocks peak "
              f"(mean util {s.get('kv_util_mean')}), "
              f"{s.get('preemptions')} preemptions, "
              f"{s.get('prefill_compiles')} prefill compiles, "
              f"measured epoch {loop.measured_epoch()}")
        for ev in loop.membership_events:
            print(f"membership: {ev.kind} at dispatch {ev.step} — "
                  f"{ev.old_size} -> {ev.new_size} rows "
                  f"(lost {list(ev.lost)}); in-flight requests drained "
                  f"and readmitted on the survivors")
    else:
        print(f"served {len(out)} requests, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s, {loop.mode} decode x{loop.decode_steps}) "
              f"under schedule({loop.sched_name}); "
              f"{loop.last_stats.get('decode_dispatches')} decode dispatches "
              f"({loop.last_stats.get('dispatches_per_token')} per token), "
              f"measured epoch {loop.measured_epoch()}, "
              f"imbalance {loop.last_stats.get('imbalance')}")


if __name__ == "__main__":
    main()
