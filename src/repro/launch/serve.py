"""Serving driver: continuous batching with a UDS request scheduler.

Requests (variable prompt lengths) arrive in a queue; the UDS decides which
requests form the next decode batch — receiver-initiated self-scheduling
where decode slots are workers and requests are iterations.  Slots that
finish (EOS / max tokens) immediately dequeue the next request chunk, i.e.
``schedule(dynamic, 1)``; guided/factoring variants admit several requests
per dequeue when the queue is deep.

Decode runs **batched** by default: all slots share one stacked
``[slots, max_len]`` KV cache with per-slot lengths, and each generated
token is ONE jitted decode call across the whole team with an active-slot
mask (``make_batched_serve_step``).  Admission prefills a request at
batch=1 and scatters its cache into the slot's row
(``model.insert_prefill``), so in-flight slots are untouched.  The batched
path is token-for-token identical to the per-slot escape hatch
(``batched=False`` / ``--per-slot``: one jit call per active slot per
token over per-slot batch-1 caches) — the equivalence is locked down in
``tests/test_serve.py``.  UDS admission semantics are IDENTICAL in both
modes: the scheduler sees the same slots, the same dequeue order, and the
same chunk feedback protocol.

The loop is instrumented with :class:`~repro.core.telemetry.LoopTelemetry`:
every chunk's **full wall time** — the prefill of each of its requests plus
every decode step of their generations — is attributed to the slot that
served it, fed back through ``stream.next`` (so within-invocation adaptive
strategies like AWF-B rebalance admission mid-run), and flushed into the
loop's ``LoopHistory`` when the stream closes.  The flush bumps the
history's measured epoch, so a cached adaptive plan for this loop is
invalidated and the *next* ``run()`` replans admission from the measured
slot speeds (AWF timestep).  ``ServeLoop.history`` persists across calls —
pass one in to persist across processes (it serializes with checkpoints).

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                        SchedulerContext, get_engine)
from repro.core.spec import SpecLike, describe, resolve
from repro.launch.steps import (make_batched_serve_step, make_prefill_step,
                                make_serve_step)
from repro.models import get_model

__all__ = ["ServeLoop", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int = 16
    generated: Optional[List[int]] = None


class ServeLoop:
    """Continuous batching over a fixed decode-slot count.

    ``history`` carries measured per-slot chunk times across ``run()``
    invocations — the serving steady state's feedback channel.  After each
    run, ``last_stats`` holds the telemetry summary (per-slot busy time,
    tokens, tok/s, measured epoch).
    """

    def __init__(self, cfg, *, slots: int = 4, max_len: int = 256,
                 scheduler: SpecLike = "dynamic", seed: int = 0,
                 history: Optional[LoopHistory] = None,
                 batched: bool = True):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        key = jax.random.PRNGKey(seed)
        self.params, _ = self.model.init(key, jnp.float32)
        # any schedule-clause form: spec, "guided,4", "uds:name", "runtime",
        # or a scheduler instance
        self.scheduler = scheduler
        self.sched_name = describe(scheduler)
        self.loop_id = "serve"
        self.history = history if history is not None else LoopHistory()
        self.last_stats: Dict[str, Any] = {}
        # jitted prefill: compiled once per distinct prompt length (an
        # eager lax.scan re-traces AND re-compiles on every admission —
        # measured ~0.8s per prefill on the smoke config, dwarfing decode)
        self._prefill = jax.jit(make_prefill_step(self.model,
                                                  max_len=max_len))
        # SSM/hybrid families have no stacked-cache decode yet: fall back
        # to the per-slot path rather than refuse to serve
        self.batched = bool(batched and self.model.batched_decode is not None)
        if self.batched:
            # one stacked [slots, max_len] cache, per-slot lengths; ONE
            # jitted decode call per token across all active slots
            self._decode_batched = jax.jit(make_batched_serve_step(self.model))
            self._insert = jax.jit(self.model.insert_prefill)
            self.cache = self.model.init_batched_decode(
                slots, max_len, dtype=jnp.float32)[0]
            self.caches = None
        else:
            # per-slot state: one cache per slot (batch=1), one jit call
            # per active slot per token — the escape hatch / SSM path
            self._decode = jax.jit(make_serve_step(self.model))
            self.caches = [self.model.init_decode(1, max_len,
                                                  dtype=jnp.float32)[0]
                           for _ in range(slots)]
        self.active: Dict[int, Request] = {}

    @property
    def mode(self) -> str:
        return "batched" if self.batched else "per_slot"

    def _prefill_into(self, slot: int, req: Request) -> int:
        # the cache holds the prompt plus one KV per decode step; past
        # max_len the two decode paths would each clamp/drop DIFFERENTLY
        # (silently wrong tokens) — refuse loudly instead
        need = int(req.prompt.size) + req.max_new - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({req.prompt.size} tokens) + "
                f"max_new ({req.max_new}) needs a cache of {need} "
                f"positions > max_len={self.max_len}; raise ServeLoop "
                f"max_len or shorten the request")
        inputs = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache = self._prefill(self.params, inputs)
        if self.batched:
            # masked scatter into the slot's row of the stacked cache;
            # every other (possibly in-flight) slot is untouched
            self.cache = self._insert(self.cache, cache, slot)
        else:
            self.caches[slot] = cache
        tok = int(jnp.argmax(logits, -1)[0])
        req.generated = [tok]
        return tok

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Schedule + serve all requests to completion."""
        sched = resolve(self.scheduler)
        loop = LoopSpec(lb=0, ub=len(requests), num_workers=self.slots,
                        loop_id=self.loop_id)
        telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                  num_workers=self.slots)
        stream = get_engine().open_stream(
            sched, SchedulerContext(loop=loop, history=self.history),
            telemetry=telemetry)
        queue: Deque[Request] = deque(requests)
        pending: Dict[int, Deque[Request]] = {s: deque()
                                              for s in range(self.slots)}
        # per-chunk wall time of the slot's *previous* chunk (prefill +
        # all decode steps), consumed by the next dequeue and then cleared
        # — never a stale prefill-only value
        elapsed: Dict[int, Optional[float]] = {s: None
                                               for s in range(self.slots)}
        results: Dict[int, List[int]] = {}
        slots_open = set(range(self.slots))
        exhausted = set()

        while len(results) < len(requests):
            # admission: idle slots dequeue request chunks via the UDS,
            # reporting the measured wall time of their previous chunk
            for s in list(slots_open):
                if s in self.active or pending[s]:
                    continue
                if s in exhausted:
                    continue
                chunk = stream.next(s, elapsed[s])
                elapsed[s] = None              # consumed by this dequeue
                if chunk is None:
                    exhausted.add(s)
                    continue
                telemetry.begin(s, chunk)
                for i in range(chunk.start, chunk.stop):
                    pending[s].append(requests[i])
            progressed = False
            for s in range(self.slots):
                if s not in self.active and pending[s]:
                    req = pending[s].popleft()
                    t0 = time.perf_counter()
                    self._prefill_into(s, req)
                    telemetry.add_time(s, time.perf_counter() - t0, tokens=1)
                    self.active[s] = req
                    progressed = True
            # one decode step across active slots
            done_slots = []
            if self.batched and self.active:
                act = sorted(self.active)
                last = np.zeros((self.slots, 1), np.int32)
                mask = np.zeros((self.slots,), bool)
                for s in act:
                    last[s, 0] = self.active[s].generated[-1]
                    mask[s] = True
                t0 = time.perf_counter()
                tok, self.cache = self._decode_batched(
                    self.params, {"tokens": jnp.asarray(last)},
                    self.cache, jnp.asarray(mask))
                tok = np.asarray(tok)       # device sync: true wall time
                # one call served every active slot: equal wall-time shares
                # keep per-slot attribution (AWF still replans per slot)
                telemetry.add_time_split(act, time.perf_counter() - t0,
                                         tokens=1)
                progressed = True
                for s in act:
                    req = self.active[s]
                    req.generated.append(int(tok[s]))
                    if len(req.generated) >= req.max_new:
                        results[req.rid] = req.generated
                        done_slots.append(s)
            else:
                for s, req in list(self.active.items()):
                    last = req.generated[-1]
                    t0 = time.perf_counter()
                    tok, cache = self._decode(
                        self.params, {"tokens": jnp.asarray([[last]])},
                        self.caches[s])
                    self.caches[s] = cache
                    req.generated.append(int(tok[0]))
                    telemetry.add_time(s, time.perf_counter() - t0, tokens=1)
                    progressed = True
                    if len(req.generated) >= req.max_new:
                        results[req.rid] = req.generated
                        done_slots.append(s)
            for s in done_slots:
                del self.active[s]
                if not pending[s]:
                    # the chunk is fully served: close its ledger and hand
                    # its wall time to the slot's next dequeue
                    elapsed[s] = telemetry.end(s)
            if not progressed:
                break
        stream.close()        # flushes telemetry -> history epoch bump
        self.last_stats = telemetry.summary()
        self.last_stats["mode"] = self.mode
        return results

    def measured_epoch(self) -> int:
        """Measured-invocation count for the serve loop — the plan-cache
        epoch adaptive admission schedules key on."""
        return self.history.measured_invocations(self.loop_id)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--scheduler", default="dynamic",
                    help='schedule clause: "dynamic", "guided,4", '
                         '"uds:name(args)", or "runtime" '
                         "(late-bound from $REPRO_SCHEDULE)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batched", dest="batched", action="store_true",
                    default=True,
                    help="one jitted decode call per token across all "
                         "active slots over a stacked KV cache (default)")
    ap.add_argument("--per-slot", dest="batched", action="store_false",
                    help="escape hatch: one decode call per active slot "
                         "per token over per-slot batch-1 caches")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 24)
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    loop = ServeLoop(cfg, slots=args.slots, scheduler=args.scheduler,
                     batched=args.batched)
    t0 = time.perf_counter()
    out = loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {loop.mode} decode) "
          f"under schedule({loop.sched_name}); "
          f"measured epoch {loop.measured_epoch()}, "
          f"imbalance {loop.last_stats.get('imbalance')}")


if __name__ == "__main__":
    main()
