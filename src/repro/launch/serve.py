"""Serving driver: continuous batching with a UDS request scheduler.

Requests (variable prompt lengths) arrive in a queue; the UDS decides which
requests form the next decode batch — receiver-initiated self-scheduling
where decode slots are workers and requests are iterations.  Slots that
finish (EOS / max tokens) immediately dequeue the next request chunk, i.e.
``schedule(dynamic, 1)``; guided/factoring variants admit several requests
per dequeue when the queue is deep.

Decode runs **batched and fused** by default: all slots share one stacked
``[slots, max_len]`` KV cache with per-slot lengths, and each dispatch is
ONE jitted call that runs ``decode_steps`` tokens for the whole team via an
on-device ``lax.scan`` (``make_fused_serve_step``) with per-slot stop/EOS/
length handling carried in the loop state — a slot that finishes its
request mid-dispatch freezes in place while the others keep decoding.  The
dispatch quantum ``decode_steps`` is a schedule parameter: T=1 reproduces
the stepwise engine token for token (greedy decode is deterministic, so
any T does — locked down in ``tests/test_serve.py``); larger T amortizes
the Python→XLA round-trip over T tokens at the cost of admission latency
(idle slots re-enter the team only at dispatch boundaries).

Admission prefills a request at batch=1 and scatters its cache into the
slot's row (``model.insert_prefill``), so in-flight slots are untouched.
Prompts are right-padded to power-of-two length *buckets* before the
jitted prefill — causal masking makes the padded prefix math identical, so
a long tail of distinct prompt lengths compiles one program per bucket
instead of one per length (~0.8s per avoided recompile on the smoke
config).  The per-slot escape hatch (``batched=False`` / ``--per-slot``:
one jit call per active slot per token over per-slot batch-1 caches)
remains token-for-token identical, and is the automatic fallback for
SSM/hybrid families.  UDS admission semantics are IDENTICAL in both modes:
the scheduler sees the same slots, the same dequeue order, and the same
chunk feedback protocol.

A request whose ``prompt + max_new`` exceeds the cache is admitted but
**truncated**: its generation budget is clamped to cache capacity and the
truncation is reported per request (``Request.truncated``,
``last_stats["truncated"]``) — never silently padded or dropped.  A prompt
that alone exceeds ``max_len`` is still refused loudly.

The loop is instrumented with :class:`~repro.core.telemetry.LoopTelemetry`:
every chunk's **full wall time** — the prefill of each of its requests plus
every decode dispatch of their generations — is attributed to the slot that
served it (one fused dispatch's wall time splits equally across the slots
it advanced, each credited its OWN produced-token count), fed back through
``stream.next`` (so within-invocation adaptive strategies like AWF-B
rebalance admission mid-run), and flushed into the loop's ``LoopHistory``
when the stream closes.  The flush bumps the history's measured epoch, so
a cached adaptive plan for this loop is invalidated and the *next*
``run()`` replans admission from the measured slot speeds (AWF timestep).
``ServeLoop.history`` persists across calls — pass one in to persist
across processes (it serializes with checkpoints).

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 16 \
        --decode-steps 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (LoopHistory, LoopSpec, LoopTelemetry,
                        SchedulerContext, get_engine)
from repro.core.spec import SpecLike, describe, resolve
from repro.launch.steps import (make_fused_serve_step, make_prefill_step,
                                make_serve_step)
from repro.models import get_model

__all__ = ["ServeLoop", "Request", "bucket_length", "main"]

# smallest prefill bucket: tiny prompts share one program instead of
# compiling at 1, 2, 3, ... tokens
MIN_PREFILL_BUCKET = 8


def bucket_length(n: int, max_len: int) -> int:
    """Prompt-length bucket: next power of two >= n (floored at
    ``MIN_PREFILL_BUCKET``), capped at ``max_len``.  One jitted prefill
    compilation per bucket serves every prompt length inside it."""
    b = MIN_PREFILL_BUCKET
    while b < n:
        b *= 2
    return min(b, max_len)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int = 16
    generated: Optional[List[int]] = None
    # generation budget = min(max_new, cache capacity), set at admission;
    # truncated=True when the cache clamped the request below max_new
    budget: int = 0
    truncated: bool = False


class ServeLoop:
    """Continuous batching over a fixed decode-slot count.

    ``decode_steps`` is the dispatch quantum: tokens generated per jitted
    call in batched mode (1 = the stepwise engine).  ``history`` carries
    measured per-slot chunk times across ``run()`` invocations — the
    serving steady state's feedback channel.  After each run,
    ``last_stats`` holds the telemetry summary (per-slot busy time,
    tokens, tok/s, decode dispatch counts, truncations, measured epoch).
    """

    def __init__(self, cfg, *, slots: int = 4, max_len: int = 256,
                 scheduler: SpecLike = "dynamic", seed: int = 0,
                 history: Optional[LoopHistory] = None,
                 batched: bool = True, decode_steps: int = 1,
                 eos_id: Optional[int] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got {decode_steps}")
        key = jax.random.PRNGKey(seed)
        self.params, _ = self.model.init(key, jnp.float32)
        # any schedule-clause form: spec, "guided,4", "uds:name", "runtime",
        # or a scheduler instance
        self.scheduler = scheduler
        self.sched_name = describe(scheduler)
        self.loop_id = "serve"
        self.history = history if history is not None else LoopHistory()
        self.last_stats: Dict[str, Any] = {}
        self.eos_id = eos_id
        # jitted prefill, compiled once per prompt-length BUCKET: prompts
        # are right-padded to power-of-two buckets and the real length is
        # passed as a traced scalar (causal masking makes the padded math
        # identical), so a long tail of distinct lengths stops triggering
        # ~0.8s recompiles mid-serve.  SSM/hybrid prefills absorb pad
        # tokens into their recurrent state, so only attention families
        # (those with a batched decode path) bucket.
        self._prefill = jax.jit(make_prefill_step(self.model,
                                                  max_len=max_len))
        self._bucketed = self.model.batched_decode is not None
        # SSM/hybrid families have no stacked-cache decode yet: fall back
        # to the per-slot path rather than refuse to serve
        self.batched = bool(batched and self.model.batched_decode is not None)
        self.decode_steps = decode_steps if self.batched else 1
        if self.batched:
            # one stacked [slots, max_len] cache, per-slot lengths; ONE
            # jitted dispatch per decode_steps tokens across all active
            # slots (an on-device scan with per-slot stop handling)
            self._decode_fused = jax.jit(
                make_fused_serve_step(self.model, self.decode_steps))
            self._insert = jax.jit(self.model.insert_prefill)
            self.cache = self.model.init_batched_decode(
                slots, max_len, dtype=jnp.float32)[0]
            self.caches = None
        else:
            # per-slot state: one cache per slot (batch=1), one jit call
            # per active slot per token — the escape hatch / SSM path
            self._decode = jax.jit(make_serve_step(self.model))
            self.caches = [self.model.init_decode(1, max_len,
                                                  dtype=jnp.float32)[0]
                           for _ in range(slots)]
        self.active: Dict[int, Request] = {}
        self._dispatches = 0
        self._decoded = 0

    @property
    def mode(self) -> str:
        return "batched" if self.batched else "per_slot"

    @property
    def prefill_compiles(self) -> int:
        """Distinct compiled prefill programs (the bucketing regression
        metric: mixed prompt lengths must not grow this per-length)."""
        return self._prefill._cache_size()

    def _prefill_into(self, slot: int, req: Request) -> int:
        P = int(req.prompt.size)
        # the cache holds the prompt plus one KV per decode step; capacity
        # is how many tokens can be generated before the fill hits max_len
        # (the first token comes from the prefill logits and appends
        # nothing).  A prompt that alone overflows the cache is refused
        # loudly; a generation that would overflow is admitted with its
        # budget clamped and the truncation REPORTED per request.
        capacity = self.max_len - P + 1
        if capacity < 1:
            raise ValueError(
                f"request {req.rid}: prompt ({P} tokens) exceeds the "
                f"cache (max_len={self.max_len}); raise ServeLoop max_len "
                f"or shorten the request")
        req.budget = min(req.max_new, capacity)
        req.truncated = req.budget < req.max_new
        tokens = req.prompt
        if self._bucketed:
            pb = bucket_length(P, self.max_len)
            if pb > P:
                tokens = np.concatenate(
                    [tokens, np.zeros(pb - P, tokens.dtype)])
            inputs = {"tokens": jnp.asarray(tokens[None, :])}
            logits, cache = self._prefill(self.params, inputs,
                                          jnp.asarray(P, jnp.int32))
        else:
            inputs = {"tokens": jnp.asarray(tokens[None, :])}
            logits, cache = self._prefill(self.params, inputs)
        if self.batched:
            # masked scatter into the slot's row of the stacked cache;
            # every other (possibly in-flight) slot is untouched
            self.cache = self._insert(self.cache, cache, slot)
        else:
            self.caches[slot] = cache
        tok = int(jnp.argmax(logits, -1)[0])
        req.generated = [tok]
        return tok

    def _finished_at_admission(self, req: Request, tok: int) -> bool:
        """Budget of 1 (or an immediate EOS) completes at prefill."""
        if len(req.generated) >= req.budget:
            return True
        return self.eos_id is not None and tok == self.eos_id

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Schedule + serve all requests to completion."""
        sched = resolve(self.scheduler)
        loop = LoopSpec(lb=0, ub=len(requests), num_workers=self.slots,
                        loop_id=self.loop_id)
        telemetry = LoopTelemetry(self.history, loop_id=self.loop_id,
                                  num_workers=self.slots)
        stream = get_engine().open_stream(
            sched, SchedulerContext(loop=loop, history=self.history),
            telemetry=telemetry)
        queue: Deque[Request] = deque(requests)
        pending: Dict[int, Deque[Request]] = {s: deque()
                                              for s in range(self.slots)}
        # per-chunk wall time of the slot's *previous* chunk (prefill +
        # all decode dispatches), consumed by the next dequeue and then
        # cleared — never a stale prefill-only value
        elapsed: Dict[int, Optional[float]] = {s: None
                                               for s in range(self.slots)}
        results: Dict[int, List[int]] = {}
        truncated: List[int] = []
        slots_open = set(range(self.slots))
        exhausted = set()
        self._dispatches = 0
        self._decoded = 0
        eos_arr = jnp.asarray(-1 if self.eos_id is None else self.eos_id,
                              jnp.int32)

        def finish(s: int, req: Request) -> None:
            results[req.rid] = req.generated
            if req.truncated:
                truncated.append(req.rid)

        while len(results) < len(requests):
            # admission: idle slots dequeue request chunks via the UDS,
            # reporting the measured wall time of their previous chunk
            for s in list(slots_open):
                if s in self.active or pending[s]:
                    continue
                if s in exhausted:
                    continue
                chunk = stream.next(s, elapsed[s])
                elapsed[s] = None              # consumed by this dequeue
                if chunk is None:
                    exhausted.add(s)
                    continue
                telemetry.begin(s, chunk)
                for i in range(chunk.start, chunk.stop):
                    pending[s].append(requests[i])
            progressed = False
            for s in range(self.slots):
                if s not in self.active and pending[s]:
                    req = pending[s].popleft()
                    t0 = time.perf_counter()
                    tok = self._prefill_into(s, req)
                    telemetry.add_time(s, time.perf_counter() - t0, tokens=1)
                    progressed = True
                    if self._finished_at_admission(req, tok):
                        finish(s, req)
                        if not pending[s]:
                            elapsed[s] = telemetry.end(s)
                    else:
                        self.active[s] = req
            # one decode dispatch across active slots
            done_slots = []
            if self.batched and self.active:
                act = sorted(self.active)
                last = np.zeros((self.slots, 1), np.int32)
                mask = np.zeros((self.slots,), bool)
                rem = np.zeros((self.slots,), np.int32)
                for s in act:
                    req = self.active[s]
                    last[s, 0] = req.generated[-1]
                    mask[s] = True
                    rem[s] = req.budget - len(req.generated)
                t0 = time.perf_counter()
                toks, self.cache, act_out, rem_out = self._decode_fused(
                    self.params, {"tokens": jnp.asarray(last)},
                    self.cache, jnp.asarray(mask), jnp.asarray(rem),
                    eos_arr)
                toks = np.asarray(toks)     # device sync: true wall time
                act_out = np.asarray(act_out)
                rem_out = np.asarray(rem_out)
                dt = time.perf_counter() - t0
                self._dispatches += 1
                # one call served every active slot in lockstep: equal
                # wall-time shares keep per-slot attribution (AWF still
                # replans per slot), each slot credited the tokens IT
                # produced before freezing
                produced = {s: int(rem[s] - rem_out[s]) for s in act}
                telemetry.add_time_split(act, dt, tokens=produced)
                self._decoded += sum(produced.values())
                progressed = True
                for s in act:
                    req = self.active[s]
                    req.generated.extend(
                        int(t) for t in toks[s, :produced[s]])
                    if not act_out[s]:      # quota / EOS / capacity freeze
                        finish(s, req)
                        done_slots.append(s)
            else:
                for s, req in list(self.active.items()):
                    last = req.generated[-1]
                    t0 = time.perf_counter()
                    tok, cache = self._decode(
                        self.params, {"tokens": jnp.asarray([[last]])},
                        self.caches[s])
                    self.caches[s] = cache
                    req.generated.append(int(tok[0]))
                    telemetry.add_time(s, time.perf_counter() - t0, tokens=1)
                    self._dispatches += 1
                    self._decoded += 1
                    progressed = True
                    done = len(req.generated) >= req.budget
                    if (self.eos_id is not None
                            and req.generated[-1] == self.eos_id):
                        done = True
                    if done:
                        finish(s, req)
                        done_slots.append(s)
            for s in done_slots:
                del self.active[s]
                if not pending[s]:
                    # the chunk is fully served: close its ledger and hand
                    # its wall time to the slot's next dequeue
                    elapsed[s] = telemetry.end(s)
            if not progressed:
                break
        stream.close()        # flushes telemetry -> history epoch bump
        self.last_stats = telemetry.summary()
        self.last_stats["mode"] = self.mode
        self.last_stats["decode_steps"] = self.decode_steps
        self.last_stats["decode_dispatches"] = self._dispatches
        self.last_stats["decoded_tokens"] = self._decoded
        self.last_stats["dispatches_per_token"] = (
            round(self._dispatches / self._decoded, 4) if self._decoded
            else None)
        self.last_stats["truncated"] = sorted(truncated)
        self.last_stats["prefill_compiles"] = self.prefill_compiles
        return results

    def measured_epoch(self) -> int:
        """Measured-invocation count for the serve loop — the plan-cache
        epoch adaptive admission schedules key on."""
        return self.history.measured_invocations(self.loop_id)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--scheduler", default="dynamic",
                    help='schedule clause: "dynamic", "guided,4", '
                         '"uds:name(args)", "runtime" (late-bound from '
                         '$REPRO_SCHEDULE), or "auto" (selected online '
                         "from serve telemetry; see docs/SCHEDULING.md)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="tokens per fused decode dispatch (batched mode): "
                         "1 = the stepwise engine; 8 amortizes the "
                         "Python->XLA round-trip over 8 tokens")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (per-slot on-device stop in fused "
                         "mode); default: generate to the token budget")
    ap.add_argument("--batched", dest="batched", action="store_true",
                    default=True,
                    help="one jitted dispatch per decode-steps tokens "
                         "across all active slots over a stacked KV cache "
                         "(default)")
    ap.add_argument("--per-slot", dest="batched", action="store_false",
                    help="escape hatch: one decode call per active slot "
                         "per token over per-slot batch-1 caches")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 24)
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    loop = ServeLoop(cfg, slots=args.slots, scheduler=args.scheduler,
                     batched=args.batched, decode_steps=args.decode_steps,
                     eos_id=args.eos_id)
    t0 = time.perf_counter()
    out = loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {loop.mode} decode x{loop.decode_steps}) "
          f"under schedule({loop.sched_name}); "
          f"{loop.last_stats.get('decode_dispatches')} decode dispatches "
          f"({loop.last_stats.get('dispatches_per_token')} per token), "
          f"measured epoch {loop.measured_epoch()}, "
          f"imbalance {loop.last_stats.get('imbalance')}")


if __name__ == "__main__":
    main()
