"""Meshes and logical-axis sharding rules.

``make_production_mesh`` builds the target v5e meshes:
  * single-pod: (16, 16)      axes ("data", "model")   — 256 chips
  * multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Parameters/activations carry *logical* axis names (see models/common.py
ParamBuilder); ``Rules`` maps logical -> mesh axes.  Changing the rule table
(not the model code) is how the §Perf hillclimb re-shards — exactly the
decoupling the paper demands between a scheduling *strategy* and the code
that uses it.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.config import ModelConfig

__all__ = ["make_production_mesh", "make_mesh", "make_host_mesh", "Rules",
           "base_rules", "rules_for", "spec_for", "shardings_for",
           "input_sharding", "batch_shardings"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(num_hosts: int, model_par: int = 1) -> Mesh:
    """Data-parallel mesh whose leading axis is a HOST: ``("host", "model")``
    of shape ``(num_hosts, model_par)``.

    The "host" axis is the straggler-mitigation unit — per-host step times
    feed :class:`~repro.sched.straggler.StragglerMitigator`, whose AWF token
    shares drive the uneven batch split.  On a real pod each "host" entry is
    one process's device block; on CPU, N hosts are emulated with

        XLA_FLAGS=--xla_force_host_platform_device_count=N

    exported before the first jax import (jax locks the device count on
    first init — the same contract as launch/dryrun.py).
    """
    if num_hosts < 1:
        raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
    return make_mesh((num_hosts, model_par), ("host", "model"))


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Elastic-scaling entry point: any (data, model[, pod]) factorization of
    the currently-healthy device count (see runtime/elastic.py).  Uses the
    first prod(shape) devices so a 256-chip pod mesh builds on the 512-device
    dry-run host (and on degraded device sets after failures)."""
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"mesh {tuple(shape)} needs {n} devices, "
                         f"only {len(devs)} available")
    try:                       # jax >= 0.5: explicit-sharding axis types
        from jax.sharding import AxisType
    except ImportError:        # jax 0.4.x: meshes are implicitly Auto
        AxisType = None
    if AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(shape),
                             devices=devs[:n])
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devs[:n])


# A rule maps a logical axis name to a mesh axis (or tuple of axes, or None).
from repro.sharding import Rules, shardings_for, spec_for, _sizes


def base_rules(mesh: Mesh) -> Rules:
    """Baseline rule table (the §Perf starting point).

    2-D weight sharding: feature-ish axes over "model" (TP), the embed axis
    over "data" (FSDP/ZeRO) — optimizer state inherits, so a 314B-param
    model's state spreads over all 256 chips.
    """
    has_pod = "pod" in mesh.axis_names
    if "host" in mesh.axis_names:      # make_host_mesh: hosts ARE the DP axis
        batch_axes = ("host",)
        fsdp_axis = "host"
    else:
        batch_axes = ("pod", "data") if has_pod else ("data",)
        fsdp_axis = "data"
    return {
        "batch": batch_axes,
        "seq": None,             # sequence (activations) — context parallel off
        "seq_cache": None,       # KV-cache length axis
        "vocab": "model",
        "embed": fsdp_axis,      # FSDP axis on weights
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "experts": "model",
        "layers": None,          # scan axis — never sharded
        # activation axes (with_sharding_constraint inside scanned bodies —
        # without these GSPMD replicates batch inside the layer loop)
        "act_embed": None,       # residual feature dim stays unsharded
        "act_heads": "model",
        "act_kv": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
    }


def rules_for(cfg: ModelConfig, mesh: Mesh, shape_kind: str,
              global_batch: int = 0,
              overrides: Optional[Rules] = None) -> Rules:
    """Baseline rules + per-arch overrides + shape-driven adjustments."""
    rules = base_rules(mesh)
    for k, v in cfg.sharding_overrides:
        v = tuple(v) if isinstance(v, list) else v
        rules[k] = v
        if f"act_{k}" in rules:     # weight override implies activation twin
            rules[f"act_{k}"] = v
    # long-context decode with batch=1: batch is unshardable -> shard the
    # cache/sequence axis over the data (and pod) axes instead.
    if shape_kind == "decode" and global_batch == 1:
        rules["batch"] = None
        rules["seq_cache"] = (("pod", "data") if "pod" in mesh.axis_names
                              else ("data",))
    if overrides:
        rules.update(overrides)
    return rules


def input_sharding(mesh: Mesh, rules: Rules, *axes: Optional[str],
                   shape: Optional[Tuple[int, ...]] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(tuple(axes), rules, shape=shape,
                                        axis_sizes=_sizes(mesh)))


def batch_shardings(mesh: Mesh, rules: Rules, batch) -> dict:
    """Per-host input placement for a LIVE batch dict: each key's batch
    axis shards over whatever the rule table maps "batch" onto ("host" on
    a host mesh), everything else replicates.  Keys outside
    ``sharding.BATCH_AXES`` (per-expert vectors etc.) replicate whole.
    ``jax.device_put(batch, batch_shardings(...))`` is how the train loop
    commits each host's row block to that host's devices before the
    jitted step."""
    from repro.sharding import BATCH_AXES
    return {k: input_sharding(mesh, rules,
                              *BATCH_AXES.get(k, (None,) * v.ndim),
                              shape=v.shape)
            for k, v in batch.items()}
