import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count on first init) — see the multi-pod dry-run contract.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without TPU hardware:
  * the sharding config is coherent (GSPMD partitions every op),
  * the program fits HBM (memory_analysis),
  * and extracts the roofline terms (cost_analysis + HLO collective parse).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
  python -m repro.launch.dryrun --list

Perf knobs (the §Perf hillclimb levers):
  --remat none|full     --ce-chunk N     --rule logical=mesh_axis (repeat)
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path


def _parse_rules(pairs):
    out = {}
    for p in pairs or []:
        k, _, v = p.partition("=")
        if v in ("none", "None", ""):
            out[k] = None
        elif "," in v:
            out[k] = tuple(v.split(","))
        else:
            out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             remat: str = "full", ce_chunk: int = 512,
             rule_overrides=None, save_hlo: str = "",
             flash_threshold=None, scan_chunk=None,
             microbatches: int = 1) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import SHAPES, applicable, get_config
    from repro.models import get_model
    from repro.launch.mesh import (make_production_mesh, rules_for,
                                   shardings_for, input_sharding)
    from repro.launch.steps import (input_specs, input_shardings,
                                    make_prefill_step, make_serve_step,
                                    make_train_step, opt_state_specs)
    from repro.launch.roofline import roofline_terms
    from repro.optim import make_optimizer, cosine_schedule
    from repro.sharding import axis_rules

    import dataclasses as _dc
    cfg = get_config(arch)
    if flash_threshold is not None:
        cfg = _dc.replace(cfg, flash_threshold=flash_threshold)
    if scan_chunk is not None:
        cfg = _dc.replace(cfg, scan_chunk=scan_chunk)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "kind": shape.kind, "remat": remat, "ce_chunk": ce_chunk,
            "microbatches": microbatches,
            "flash_threshold": cfg.flash_threshold,
            "scan_chunk": cfg.scan_chunk,
            "rule_overrides": {k: v for k, v in (rule_overrides or {}).items()}}
    if not ok:
        return {**meta, "status": "skip", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = rules_for(cfg, mesh, shape.kind, shape.global_batch,
                      overrides=rule_overrides)
    model = get_model(cfg)
    params_abs, specs = model.init(jax.random.PRNGKey(0), jnp.bfloat16,
                                   abstract=True)
    pshard = shardings_for(specs, rules, mesh, tree=params_abs)
    ispecs = input_specs(cfg, shape)
    ishard = input_shardings(cfg, shape, rules, mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    tokens_global = shape.global_batch * (shape.seq_len
                                          if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens_global
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * tokens_global
    else:
        model_flops = 2.0 * n_active * shape.global_batch

    with mesh, axis_rules(mesh, rules):
        if shape.kind == "train":
            opt_init, opt_update = make_optimizer(
                cfg.optimizer, cosine_schedule(3e-4, 100, 10_000))
            opt_abs = jax.eval_shape(opt_init, params_abs)
            ospecs = opt_state_specs(cfg.optimizer, params_abs, specs)
            oshard = shardings_for(ospecs, rules, mesh, tree=opt_abs)
            step_fn = make_train_step(model, opt_update, remat=remat,
                                      ce_chunk=ce_chunk,
                                      num_microbatches=microbatches)
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, repl, ishard),
                out_shardings=(pshard, oshard, repl),
                donate_argnums=(0, 1))
            lowered = jitted.lower(
                params_abs, opt_abs,
                jax.ShapeDtypeStruct((), jnp.int32), ispecs)
        elif shape.kind == "prefill":
            cache_abs, cache_specs = model.init_decode(
                shape.global_batch, shape.seq_len, abstract=True)
            cshard = shardings_for(cache_specs, rules, mesh, tree=cache_abs)
            step_fn = make_prefill_step(model, max_len=shape.seq_len)
            logits_shard = input_sharding(
                mesh, rules, "batch", "vocab",
                shape=(shape.global_batch, cfg.vocab_size))
            jitted = jax.jit(step_fn, in_shardings=(pshard, ishard),
                             out_shardings=((logits_shard, cshard)))
            lowered = jitted.lower(params_abs, ispecs)
        else:  # decode
            cache_abs, cache_specs = model.init_decode(
                shape.global_batch, shape.seq_len, abstract=True)
            cshard = shardings_for(cache_specs, rules, mesh, tree=cache_abs)
            step_fn = make_serve_step(model)
            tok_shard = input_sharding(mesh, rules, "batch",
                                       shape=(shape.global_batch,))
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, ishard, cshard),
                             out_shardings=(tok_shard, cshard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, ispecs, cache_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.hlo_analysis import normalize_cost_analysis
    hlo_text = compiled.as_text()
    print(compiled.memory_analysis())       # proves it fits (dry-run contract)
    print({k: v for k, v in normalize_cost_analysis(
               compiled.cost_analysis()).items()
           if k in ("flops", "bytes accessed")})
    terms = roofline_terms(compiled, n_chips=n_chips,
                           model_flops_global=model_flops,
                           hlo_text=hlo_text)
    if save_hlo:
        Path(save_hlo).write_text(hlo_text)
    return {**meta, "status": "ok", "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "param_count": cfg.param_count(),
            "active_param_count": n_active,
            "tokens_global": tokens_global,
            **terms}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch, shape) cell")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun",
                    help="output directory for per-cell JSON")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--rule", action="append",
                    help="sharding rule override logical=mesh (repeatable)")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--flash-threshold", type=int, default=None)
    ap.add_argument("--scan-chunk", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    if args.list:
        for a in list_archs():
            print(a)
        return

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all/--list")
        cells = [(args.arch, args.shape)]

    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    overrides = _parse_rules(args.rule)

    failures = 0
    for arch, shape in cells:
        for m in meshes:
            tag = f"_{args.tag}" if args.tag else ""
            fname = outdir / f"{arch}_{shape}_{m}{tag}.json"
            try:
                res = run_cell(arch, shape, multi_pod=(m == "multi"),
                               remat=args.remat, ce_chunk=args.ce_chunk,
                               rule_overrides=overrides,
                               save_hlo=args.save_hlo,
                               flash_threshold=args.flash_threshold,
                               scan_chunk=args.scan_chunk,
                               microbatches=args.microbatches)
            except Exception as e:
                failures += 1
                res = {"arch": arch, "shape": shape, "mesh": m,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
            fname.write_text(json.dumps(res, indent=1, default=str))
            stat = res["status"]
            extra = ""
            if stat == "ok":
                extra = (f" dom={res['dominant']} bound={res['bound_s']:.4f}s"
                         f" frac={res['roofline_fraction']:.3f}"
                         f" compile={res['compile_s']:.0f}s")
            print(f"[dryrun] {arch} × {shape} × {m}: {stat}{extra}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
