"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip — the assignment's constants):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s/link

Terms per (arch × shape × mesh), all PER CHIP / in seconds:
    compute_s    = HLO_FLOPs / 197e12            (cost_analysis, per-device)
    memory_s     = HLO_bytes / 819e9             (cost_analysis "bytes accessed")
    collective_s = Σ collective_traffic / 50e9   (parsed from optimized HLO)

Collective traffic model (ring algorithms, result-shape bytes R, group n):
    all-gather          (n-1)/n · R        (R = gathered result, per chip)
    reduce-scatter      (n-1)   · R        (full input = n·R moves (n-1)/n·n·R)
    all-reduce          2(n-1)/n · R       (RS + AG)
    all-to-all          (n-1)/n · R
    collective-permute  1 · R

The post-SPMD module is the per-device program, so instruction shapes are
already per-chip.  cost_analysis does NOT include collective bytes — hence
the HLO text parse (assignment spec).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "parse_collectives", "roofline_terms", "CollectiveStats"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9            # v5e: 16 GB HBM

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2                              # conservative default


def _traffic_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-to-all":
        return (n - 1) / n
    if op == "collective-permute":
        return 1.0
    return 1.0


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float                     # traffic-model bytes per chip
    result_bytes: int                      # raw summed result-shape bytes
    count: int
    by_op: Dict[str, float]
    by_op_count: Dict[str, int]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    total = 0.0
    raw = 0
    count = 0
    by_op: Dict[str, float] = {}
    by_cnt: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("shape"))
        n = _group_size(line)
        traffic = rb * _traffic_factor(op, n)
        total += traffic
        raw += rb
        count += 1
        by_op[op] = by_op.get(op, 0.0) + traffic
        by_cnt[op] = by_cnt.get(op, 0) + 1
    return CollectiveStats(total_bytes=total, result_bytes=raw, count=count,
                           by_op=by_op, by_op_count=by_cnt)


def roofline_terms(compiled, *, n_chips: int, model_flops_global: float,
                   hlo_text: Optional[str] = None) -> Dict:
    """All three roofline terms + bookkeeping, from a compiled executable.

    Primary numbers come from the loop-aware HLO analysis (hlo_analysis.py):
    XLA's cost_analysis counts while-loop bodies once, which undercounts
    scanned-layer programs by ~num_layers×.  The raw cost_analysis values
    are retained as ``xla_*`` reference fields.
    """
    from repro.launch.hlo_analysis import analyze_hlo, normalize_cost_analysis

    ca = normalize_cost_analysis(compiled.cost_analysis())
    xla_flops = float(ca.get("flops", 0.0))              # per chip, loop=1
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    st = analyze_hlo(text)

    flops = st.flops                                     # per chip, loop-aware
    # primary terms use the dtype-corrected byte counts (f32 tensors that
    # shadow bf16 shapes are XLA:CPU bf16-op legalization, absent on TPU);
    # raw counts are kept as *_raw reference fields
    bytes_acc = st.bytes_accessed_tpu
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = st.collective_bytes_tpu / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
            # CPU-host artifact: XLA:CPU legalizes bf16 dots to f32 and saves
            # f32 residual stacks that don't exist on TPU (native bf16 MXU).
            adj = peak - st.cpu_bf16_legalization_bytes
            mem = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": peak,
                "cpu_bf16_legalization_bytes":
                    int(st.cpu_bf16_legalization_bytes),
                "peak_bytes_tpu_adjusted": adj,
                "fits_hbm": bool(peak < HBM_PER_CHIP),
                "fits_hbm_tpu_adjusted": bool(adj < HBM_PER_CHIP),
            }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    model_flops_chip = model_flops_global / n_chips
    # roofline fraction: useful model FLOPs per chip over the time the
    # dominant term implies (what MFU would be if the bottleneck is the
    # only cost — the dry-run analogue of measured MFU)
    roofline_fraction = (model_flops_chip / PEAK_FLOPS) / max(bound_s, 1e-30)

    return {
        **terms,
        "dominant": dominant,
        "bound_s": bound_s,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "hlo_bytes_raw": st.bytes_accessed,
        "xla_flops_loop_once": xla_flops,
        "xla_bytes_loop_once": xla_bytes,
        "collective_bytes_per_chip": st.collective_bytes_tpu,
        "collective_bytes_raw": st.collective_bytes,
        "collective_count": st.collective_count,
        "collective_by_op": st.collective_by_op,
        "while_trip_counts": st.while_trip_counts,
        "model_flops_global": model_flops_global,
        "model_flops_per_chip": model_flops_chip,
        "useful_flops_ratio": (model_flops_chip / flops) if flops else 0.0,
        "roofline_fraction": roofline_fraction,
        "memory": mem,
        "n_chips": n_chips,
    }
