"""Compiled step functions: train_step / prefill_step / serve_step.

These are THE artifacts the multi-pod dry-run lowers and compiles, and what
``train.py`` / ``serve.py`` drive for real.  All architecture dispatch goes
through the model facade; all sharding through the logical-rule tables.

Memory-critical design choices (each is a §Perf lever):
  * chunked softmax cross-entropy — the (B,S,V) logits tensor is never
    materialized; the LM head matmul runs inside a sequence-chunk scan
    (e.g. grok-1 train_4k: 318 GB of logits+grad avoided globally);
  * scan-over-layers + jax.checkpoint (remat policy configurable);
  * donated params/optimizer/cache buffers (in-place update at XLA level).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, ModelConfig
from repro.optim.specs import opt_state_specs  # noqa: F401  (re-export)
from repro.configs.base import ShapeSpec
from repro.core.spec import SpecLike
from repro.sharding import constrain

__all__ = ["chunked_softmax_ce", "make_train_step", "make_fused_train_step",
           "make_prefill_step", "make_serve_step", "make_batched_serve_step",
           "make_fused_serve_step", "apply_microbatch_plan",
           "plan_microbatches", "split_batch_by_shares", "input_specs",
           "head_weights"]

Tree = Any


# batch-dict keys with a leading batch dim (mirrors input_specs); keys not
# listed here (e.g. the per-expert cap_e vector) pass through unpermuted
_BATCH_MAJOR_KEYS = frozenset({"tokens", "embeds", "labels", "segment_ids"})


def apply_microbatch_plan(batch: Dict[str, jax.Array], perm,
                          extra_batch_keys: Sequence[str] = ()
                          ) -> Dict[str, jax.Array]:
    """Apply a UDS microbatch permutation (``sched.microbatch``, planned
    through the engine) to a host-side batch: rows are reordered so the
    compiled step's *static* equal split sees cost-balanced microbatches.
    Permutes by explicit key (``_BATCH_MAJOR_KEYS`` + ``extra_batch_keys``;
    ``positions_3d`` is (3, B, S) and permuted on its second axis) — never
    by shape inference, so same-length non-batch vectors can't be
    scrambled."""
    perm = jnp.asarray(perm)
    keys = _BATCH_MAJOR_KEYS | set(extra_batch_keys)
    out: Dict[str, jax.Array] = {}
    for k, v in batch.items():
        if k == "positions_3d":
            out[k] = v[:, perm]
        elif k in keys:
            out[k] = v[perm]
        else:
            out[k] = v
    return out


def plan_microbatches(batch: Dict[str, jax.Array], costs, num_microbatches: int,
                      scheduler: SpecLike = "dynamic,1",
                      history=None,
                      extra_batch_keys: Sequence[str] = ()
                      ) -> Dict[str, jax.Array]:
    """Plan and apply the UDS microbatch assignment in one step.

    ``scheduler`` is a schedule clause (spec / string / instance) resolved
    through the unified registry; the permutation it plans over
    ``costs`` (per-row work estimates) is applied so the compiled step's
    *static* equal split sees cost-balanced microbatches.
    """
    from repro.sched.microbatch import plan_microbatch_permutation
    perm = plan_microbatch_permutation(scheduler, costs, num_microbatches,
                                       history=history)
    return apply_microbatch_plan(batch, perm,
                                 extra_batch_keys=extra_batch_keys)


def split_batch_by_shares(batch: Dict[str, jax.Array], shares,
                          num_hosts: int,
                          labels_np: Optional[np.ndarray] = None):
    """Apply AWF token shares as an UNEVEN data-parallel batch split.

    The jitted train step needs ONE static shape, so the split is
    pad/mask-based: the global ``(B, S)`` batch is viewed as ``num_hosts``
    contiguous row blocks (the "host"-axis sharding layout), and host ``h``
    keeps only the first ``shares[h]`` token positions of its block
    (row-major), the rest becoming padding (tokens 0, labels -100,
    segment_ids 0, embeds zeroed) — exactly how a real uneven input
    pipeline underfills a slow host's feed while the compiled step keeps
    one shape.  Shares above a host's physical capacity
    (``B/num_hosts * S``) are clamped: a fast host can keep everything it
    was packed but cannot absorb another host's rows.

    Uniform shares at (or above) capacity are an exact no-op — the batch
    is returned UNTOUCHED (same arrays), the identity the multi-host
    loss-equivalence guarantee rests on.

    Returns ``(batch, host_tokens)`` where ``host_tokens[h]`` counts the
    real (label-carrying) tokens host ``h`` still owns — the per-host
    work estimate the straggler telemetry attributes step time by.
    Pass the packer's host-resident labels as ``labels_np`` to count them
    with zero device traffic; without it ``batch["labels"]`` is copied to
    host once (a device sync on a committed array).
    """
    labels = batch["labels"]
    B, S = labels.shape
    if B % num_hosts != 0:
        raise ValueError(f"global batch {B} not divisible by "
                         f"{num_hosts} hosts")
    shares = np.asarray(shares, np.int64)
    if shares.shape != (num_hosts,):
        raise ValueError(f"expected {num_hosts} shares, got shape "
                         f"{shares.shape}")
    rows_per_host = B // num_hosts
    cap = rows_per_host * S
    budget = np.clip(shares, 0, cap)
    # real-token counting works on a host-side labels array + the numpy
    # keep mask — never on the masked device output
    if labels_np is None:
        labels_np = np.asarray(labels)
    elif labels_np.shape != (B, S):
        raise ValueError(f"labels_np shape {labels_np.shape} != {(B, S)}")
    real = labels_np >= 0

    if bool((budget >= cap).all()):          # uniform/full shares: no-op
        return batch, real.reshape(num_hosts, -1).sum(axis=1,
                                                      dtype=np.int64)
    # token position within its host's block, row-major: row b col s ->
    # (b % rows_per_host) * S + s; kept iff below the host's budget
    pos = np.arange(B * S, dtype=np.int64).reshape(B, S) % cap
    keep_np = pos < budget[np.arange(B) // rows_per_host, None]
    host_tokens = (real & keep_np).reshape(num_hosts, -1).sum(
        axis=1, dtype=np.int64)
    keep = jnp.asarray(keep_np)
    out: Dict[str, jax.Array] = {}
    for k, v in batch.items():
        if k == "tokens":
            out[k] = jnp.where(keep, v, 0)
        elif k == "labels":
            out[k] = jnp.where(keep, v, -100)
        elif k == "segment_ids":
            out[k] = jnp.where(keep, v, 0)
        elif k == "embeds":
            out[k] = jnp.where(keep[..., None], v, 0)
        else:                                # positions_3d, cap_e, ...
            out[k] = v
    return out, host_tokens


def head_weights(params: Tree, cfg: ModelConfig) -> jax.Array:
    return (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])


def chunked_softmax_ce(x: jax.Array, head: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None,
                       chunk: int = 512,
                       valid_vocab: Optional[int] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over a huge vocab without materializing full logits.

    x: (B, S, D) final hidden; head: (D, V); labels: (B, S) int32.
    ``valid_vocab``: mask head columns >= this out of the logsumexp
    (padded-vocab support).  Returns (sum_loss, sum_count).
    """
    B, S, D = x.shape
    V = head.shape[-1]
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    xc = x.reshape(B, nc, chunk, D).swapaxes(0, 1)          # (nc,B,c,D)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint   # recompute chunk logits in bwd: never store (B,c,V) f32
    def step(carry, inp):
        loss_sum, cnt = carry
        xb, lb, mb = inp
        logits = jnp.einsum("bcd,dv->bcv", xb, head,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "batch", None, "act_vocab")
        if valid_vocab is not None and valid_vocab < V:
            pad_mask = jnp.arange(V) < valid_vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)             # (B,c)
        onehot = jax.nn.one_hot(lb, V, dtype=logits.dtype)
        ll = jnp.einsum("bcv,bcv->bc", logits, onehot)
        loss_sum = loss_sum + jnp.sum((lse - ll) * mb)
        cnt = cnt + jnp.sum(mb)
        return (loss_sum, cnt), None

    (loss_sum, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return loss_sum, cnt


def _make_microbatch_grads(model: Model, *, remat: str, ce_chunk: int,
                           aux_loss_weight: float,
                           num_microbatches: int) -> Callable:
    """The shared loss/grad core of the train-step factories: full-batch
    gradients, or a ``lax.scan`` gradient accumulation over
    ``num_microbatches`` equal splits (one compiled shape).

    The accumulation is GROUPING-INVARIANT: every microbatch's objective
    is its CE *sum* normalized by the GLOBAL real-token count (computed
    before the scan) plus its 1/M share of the aux loss, so summing the
    per-microbatch gradients reproduces the full-batch gradient exactly
    (in exact arithmetic) no matter which rows land in which microbatch.
    This is the invariant that lets a hierarchical host-block-aligned
    microbatch grouping match the flat single-host grouping's loss
    trajectory — and makes ``num_microbatches`` itself loss-neutral."""
    cfg = model.cfg

    def _losses(params, batch):
        inputs = {k: v for k, v in batch.items()
                  if k in ("tokens", "embeds", "positions_3d", "segment_ids")}
        hidden, loads = model.forward(params, inputs, remat=remat,
                                      return_hidden=True,
                                      cap_e=batch.get("cap_e"))
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        loss_sum, cnt = chunked_softmax_ce(
            hidden, head_weights(params, cfg), jnp.maximum(labels, 0),
            mask, chunk=ce_chunk,
            valid_vocab=(cfg.vocab_size
                         if cfg.padded_vocab != cfg.vocab_size else None))
        aux = jnp.zeros((), jnp.float32)
        if cfg.is_moe:
            # switch-style balance loss from measured hard loads
            # (aux = E * sum_e f_e^2; f = p approximation documented)
            f = loads.mean(axis=0)
            aux = cfg.num_experts * jnp.sum(f * f)
        return loss_sum, cnt, aux

    def loss_fn(params, batch):
        loss_sum, cnt, aux = _losses(params, batch)
        ce = loss_sum / jnp.maximum(cnt, 1.0)
        return ce + aux_loss_weight * aux, (ce, aux, cnt)

    def mb_loss_fn(params, batch, denom):
        # one microbatch's share of the GLOBAL objective
        loss_sum, cnt, aux = _losses(params, batch)
        obj = loss_sum / denom + aux_loss_weight * aux / num_microbatches
        return obj, (loss_sum, aux, cnt)

    def microbatch_grads(params, batch):
        if num_microbatches == 1:
            grads, (ce, aux, cnt) = jax.grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, ce, aux, cnt
        # static equal split (UDS plans sizes host-side by permuting work
        # into the microbatches; compiled shapes stay uniform)
        def split(v):
            if v.ndim >= 2 and v.shape[0] % num_microbatches == 0:
                return v.reshape(num_microbatches,
                                 v.shape[0] // num_microbatches, *v.shape[1:])
            return jnp.broadcast_to(v, (num_microbatches,) + v.shape)
        mb = {k: (split(v) if k != "positions_3d" else
                  v.reshape(3, num_microbatches, -1, v.shape[-1])
                  .swapaxes(0, 1))
              for k, v in batch.items()}
        denom = jnp.maximum(
            (batch["labels"] >= 0).sum().astype(jnp.float32), 1.0)

        def one(carry, mbi):
            g_acc, ls_acc, aux_acc, cnt_acc = carry
            grads, (ls, aux, cnt) = jax.grad(
                mb_loss_fn, has_aux=True)(params, mbi, denom)
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            return (g_acc, ls_acc + ls, aux_acc + aux, cnt_acc + cnt), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, ls, aux, cnt), _ = jax.lax.scan(
            one, (zeros, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), mb)
        # reported loss = global token mean (what the full batch reports)
        return g, ls / denom, aux / num_microbatches, cnt

    return microbatch_grads


def _apply_update(opt_update: Callable, params, opt_state, step,
                  grads, ce, aux, cnt):
    updates, opt_state, om = opt_update(grads, opt_state, params, step)
    params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                      ).astype(p.dtype), params, updates)
    # "tokens": labelled (non-masked) tokens this step — the measure
    # stage's tok/s numerator, threaded out for the telemetry loop
    metrics = {"loss": ce, "aux_loss": aux, "step": step + 1,
               "tokens": cnt, **om}
    return params, opt_state, metrics


def make_train_step(model: Model, opt_update: Callable,
                    *, remat: str = "full", ce_chunk: int = 512,
                    aux_loss_weight: float = 0.01,
                    num_microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics).

    ``batch``: tokens/embeds, labels, optional segment_ids / positions_3d /
    cap_e (engine-planned expert capacities).  ``num_microbatches`` > 1 runs
    UDS-sized gradient accumulation: ``sched/microbatch.py`` plans the row
    permutation host-side and ``apply_microbatch_plan`` applies it; the
    equal split here keeps the compiled shape static.
    """
    microbatch_grads = _make_microbatch_grads(
        model, remat=remat, ce_chunk=ce_chunk,
        aux_loss_weight=aux_loss_weight,
        num_microbatches=num_microbatches)

    def train_step(params, opt_state, step, batch):
        grads, ce, aux, cnt = microbatch_grads(params, batch)
        return _apply_update(opt_update, params, opt_state, step,
                             grads, ce, aux, cnt)

    return train_step


def make_fused_train_step(model: Model, opt_update: Callable,
                          *, remat: str = "full", ce_chunk: int = 512,
                          aux_loss_weight: float = 0.01,
                          num_microbatches: int = 1,
                          extra_batch_keys: Sequence[str] = ()) -> Callable:
    """Returns train_step(params, opt_state, step, batch, perm) ->
    (params, opt_state, metrics): the FUSED K-microbatch dispatch.

    One jitted call per optimizer step does everything the per-microbatch
    path spread over host round-trips: the UDS microbatch assignment
    (``perm``, the plan's chunk table as a device int32 array — the
    schedule still decides which rows land in which microbatch) is applied
    ON DEVICE, then the ``lax.scan`` gradient accumulation runs all
    ``num_microbatches`` microbatches, then the optimizer update — no
    host-side eager permutation dispatches between them.  Numerically
    identical to ``make_train_step`` fed a host-permuted batch (the
    permutation is the same gather, just lowered into the program).
    """
    microbatch_grads = _make_microbatch_grads(
        model, remat=remat, ce_chunk=ce_chunk,
        aux_loss_weight=aux_loss_weight,
        num_microbatches=num_microbatches)
    keys = tuple(extra_batch_keys)

    def train_step(params, opt_state, step, batch, perm):
        batch = apply_microbatch_plan(batch, perm, extra_batch_keys=keys)
        grads, ce, aux, cnt = microbatch_grads(params, batch)
        return _apply_update(opt_update, params, opt_state, step,
                             grads, ce, aux, cnt)

    return train_step


def make_prefill_step(model: Model, *, max_len: Optional[int] = None
                      ) -> Callable:
    """``length`` (optional traced scalar) marks the real prompt length
    inside a right-padded token buffer — the bucketed-prefill form that
    compiles once per length BUCKET instead of once per distinct prompt
    length (attention families only; SSM prefills absorb pad tokens into
    their state and must keep exact lengths)."""
    def prefill_step(params, batch, length=None):
        if length is None:
            return model.prefill(params, batch, max_len)
        return model.prefill(params, batch, max_len, length=length)
    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode step: greedy token + updated cache/state."""
    def serve_step(params, batch, cache):
        logits, cache = model.decode(params, batch, cache,
                                     cap_e=batch.get("cap_e"))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache
    return serve_step


def make_batched_serve_step(model: Model) -> Callable:
    """One decode step across ALL serving slots of a stacked cache: greedy
    token per slot + updated cache.  ``active (slots,) bool`` masks the
    cache/length update for idle slots; their token output is meaningless
    and discarded by the caller.  One jitted call per generated token for
    the whole team — the batched ``ServeLoop`` hot path."""
    if model.batched_decode is None:
        raise ValueError(
            f"{model.name}: model family has no batched decode path "
            f"(use the per-slot serve step)")

    def serve_step(params, batch, cache, active):
        logits, cache = model.batched_decode(params, batch, cache,
                                             active=active,
                                             cap_e=batch.get("cap_e"))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache
    return serve_step


def make_fused_serve_step(model: Model, num_steps: int) -> Callable:
    """``num_steps`` greedy decode tokens per dispatch across ALL slots of
    a stacked cache — ONE jitted call runs a ``lax.scan`` of
    ``num_steps`` batched decode steps with per-slot stop/EOS/length
    handling on device (``transformer.fused_decode_steps``).  The batched
    ``ServeLoop`` hot path: the Python→XLA round-trip is paid once per
    ``num_steps`` tokens instead of once per token.  ``num_steps=1`` is
    exactly the stepwise batched engine.

    Returns serve_step(params, batch, cache, active, remaining, eos_id)
    -> (tokens (slots, num_steps), cache, active, remaining)."""
    if model.fused_decode is None:
        raise ValueError(
            f"{model.name}: model family has no batched decode path "
            f"(use the per-slot serve step)")
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")

    def serve_step(params, batch, cache, active, remaining, eos_id):
        return model.fused_decode(params, batch, cache,
                                  num_steps=num_steps, active=active,
                                  remaining=remaining, eos_id=eos_id,
                                  cap_e=batch.get("cap_e"))
    return serve_step


def make_paged_prefill_step(model: Model) -> Callable:
    """One CHUNK of one prompt through the paged-KV pool — the
    continuous-batching prefill unit.  ``batch["tokens"]`` is a
    bucket-padded ``(1, Cb)`` chunk; ``start``/``length`` are traced
    scalars, so each padded width ``Cb`` compiles exactly once (the paged
    analogue of the dense engine's one-compile-per-bucket prefill).

    Returns prefill_chunk(params, batch, cache, tables, start, length)
    -> (logits (1, V) at the chunk's last real token, cache)."""
    if model.paged_prefill_chunk is None:
        raise ValueError(
            f"{model.name}: model family has no paged-KV path "
            f"(use the dense serve engines)")

    def prefill_chunk(params, batch, cache, tables, start, length):
        return model.paged_prefill_chunk(params, batch, cache,
                                         tables=tables, start=start,
                                         length=length,
                                         cap_e=batch.get("cap_e"))
    return prefill_chunk


def make_paged_serve_step(model: Model, num_steps: int) -> Callable:
    """``num_steps`` greedy tokens per dispatch across every row of a
    paged-KV block pool — the paged twin of :func:`make_fused_serve_step`.
    ``tables (B, W)`` / ``lengths (B,)`` come from the host-side block
    manager; ``limits (B,)`` is per-row allocated capacity in tokens, so a
    row that outgrows its blocks freezes mid-dispatch instead of writing
    into memory it does not own (the serve loop's preemption signal).

    Returns serve_step(params, batch, cache, tables, lengths, limits,
    active, remaining, eos_id) -> (tokens (B, num_steps), cache, lengths,
    active, remaining)."""
    if model.fused_paged_decode is None:
        raise ValueError(
            f"{model.name}: model family has no paged-KV path "
            f"(use the dense serve engines)")
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")

    def serve_step(params, batch, cache, tables, lengths, limits,
                   active, remaining, eos_id):
        return model.fused_paged_decode(params, batch, cache,
                                        num_steps=num_steps, tables=tables,
                                        lengths=lengths, limits=limits,
                                        active=active, remaining=remaining,
                                        eos_id=eos_id,
                                        cap_e=batch.get("cap_e"))
    return serve_step


# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                dtype: jnp.dtype = jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation (dry-run contract)."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    if cfg.frontend != "none":
        out["embeds"] = sds((B, S, cfg.d_model), dtype)
    else:
        out["tokens"] = sds((B, S), jnp.int32)
    if cfg.mrope_sections is not None:
        out["positions_3d"] = sds((3, B, S), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds((B, S), jnp.int32)
    if cfg.is_moe:
        out["cap_e"] = sds((cfg.num_experts,), jnp.int32)
    return out


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, rules, mesh):
    """NamedShardings matching input_specs (divisibility-checked)."""
    from repro.launch.mesh import input_sharding
    from repro.sharding import BATCH_AXES
    specs = input_specs(cfg, shape)
    return {k: input_sharding(mesh, rules, *BATCH_AXES[k], shape=v.shape)
            for k, v in specs.items()}
