"""Training driver: end-to-end loop with UDS scheduling, checkpoints,
straggler mitigation.

CPU-runnable (smoke configs / reduced settings); the same driver targets
TPU pods by picking a production mesh and full config:

    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 50
    python -m repro.launch.train --arch qwen3-moe-235b-a22b --smoke \
        --steps 30 --scheduler awf --microbatches 2
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core import LoopHistory, LoopTelemetry
from repro.core.spec import SpecLike, resolve
from repro.data import SyntheticCorpus
from repro.launch.mesh import make_mesh, rules_for, shardings_for
from repro.launch.steps import (make_train_step, opt_state_specs,
                                plan_microbatches)
from repro.models import get_model
from repro.optim import cosine_schedule, make_optimizer, wsd_schedule
from repro.sched import (CapacityPlanner, StragglerMitigator,
                         pack_with_scheduler)
from repro.sharding import axis_rules
from repro.checkpoint import AsyncCheckpointer

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Composable training loop; examples and tests drive this class."""

    def __init__(self, cfg, *, batch: int, seq_len: int,
                 mesh_shape=None, scheduler: SpecLike = "fac2",
                 microbatch_scheduler: SpecLike = "dynamic,1",
                 num_microbatches: int = 1, lr: float = 3e-4,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 data_sigma: float = 1.0):
        self.cfg = cfg
        self.batch, self.seq_len = batch, seq_len
        self.model = get_model(cfg)
        self.history = LoopHistory()
        # the measure stage: per-step wall time + token counts flushed into
        # the history under "train_step" — each flush bumps the measured
        # epoch, so adaptive schedules planning against this history replan
        # from real step times (and the packing history's own records feed
        # the AWF document packer)
        self.telemetry = LoopTelemetry(self.history, loop_id="train_step",
                                       num_workers=1)
        # ``scheduler`` / ``microbatch_scheduler`` accept any schedule
        # clause form: a spec, "guided,4", "uds:name(args)", "runtime"
        # (late-bound from $REPRO_SCHEDULE), or a scheduler instance
        self.pack_sched = resolve(scheduler)
        self.microbatch_sched = microbatch_scheduler
        self.num_microbatches = num_microbatches
        self.capacity = (CapacityPlanner(cfg, seq_len) if cfg.is_moe else None)

        devs = len(jax.devices())
        if mesh_shape is None:
            model_par = 1
            while model_par * 2 <= devs and model_par < 4:
                model_par *= 2
            mesh_shape = (max(devs // model_par, 1), model_par)
        self.mesh = make_mesh(mesh_shape, ("data", "model"))
        self.rules = rules_for(cfg, self.mesh, "train", batch)

        if cfg.name.startswith("minicpm"):
            sched_fn = wsd_schedule(lr, 20, 10_000, 1_000)   # the WSD paper
        else:
            sched_fn = cosine_schedule(lr, 20, 10_000)
        opt_init, opt_update = make_optimizer(cfg.optimizer, sched_fn)

        key = jax.random.PRNGKey(seed)
        with self.mesh, axis_rules(self.mesh, self.rules):
            params, specs = self.model.init(key, jnp.bfloat16)
            pshard = shardings_for(specs, self.rules, self.mesh, tree=params)
            params = jax.device_put(params, pshard)
            opt_state = opt_init(params)
            oshard = shardings_for(
                opt_state_specs(cfg.optimizer, params, specs),
                self.rules, self.mesh, tree=opt_state)
            opt_state = jax.device_put(opt_state, oshard)
        self.params, self.opt_state = params, opt_state
        self.pshard, self.oshard = pshard, oshard
        self.specs = specs

        step_fn = make_train_step(self.model, opt_update,
                                  num_microbatches=num_microbatches)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step = 0
        self.corpus = SyntheticCorpus(cfg.vocab_size, mean_len=seq_len / 4,
                                      sigma=data_sigma, seed=seed)
        self._doc_iter = self.corpus.documents()
        self.mitigator = StragglerMitigator(num_hosts=1)
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_dir = ckpt_dir

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, jax.Array]:
        docs = [next(self._doc_iter) for _ in range(self.batch * 3)]
        packed = pack_with_scheduler(self.pack_sched, docs, self.batch,
                                     self.seq_len, history=self.history)
        batch = {"tokens": jnp.asarray(packed.tokens),
                 "labels": jnp.asarray(packed.labels),
                 "segment_ids": jnp.asarray(packed.segment_ids)}
        if self.num_microbatches > 1:
            costs = (packed.segment_ids > 0).sum(axis=1).astype(float)
            batch = plan_microbatches(batch, costs, self.num_microbatches,
                                      scheduler=self.microbatch_sched)
        if self.capacity is not None:
            batch["cap_e"] = jnp.asarray(self.capacity.plan())
        if self.cfg.frontend != "none":
            # stub frontend: embed tokens host-side stand-in
            emb = jax.random.normal(
                jax.random.PRNGKey(self.step),
                (self.batch, self.seq_len, self.cfg.d_model), jnp.bfloat16)
            batch["embeds"] = emb
        if self.cfg.mrope_sections is not None:
            pos = jnp.tile(jnp.arange(self.seq_len, dtype=jnp.int32)[None],
                           (self.batch, 1))
            batch["positions_3d"] = jnp.stack([pos, pos, pos])
        return batch

    def run(self, steps: int, log_every: int = 10) -> list:
        losses = []
        with self.mesh, axis_rules(self.mesh, self.rules):
            for _ in range(steps):
                batch = self.next_batch()
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state,
                    jnp.asarray(self.step, jnp.int32), batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                tokens = int(metrics.get("tokens", self.batch * self.seq_len))
                # measure: one record per step (host 0, size = tokens),
                # flushed immediately so each step is one measured epoch
                self.telemetry.record_chunk(0, 0, max(tokens, 1), dt,
                                            tokens=tokens)
                self.telemetry.flush()
                self.mitigator.observe_step({0: dt},
                                            host_tokens={0: max(tokens, 1)})
                losses.append(loss)
                self.step += 1
                if self.ckpt and self.step % 10 == 0:
                    self.ckpt.save(self.step, {"params": self.params,
                                               "opt": self.opt_state})
                if self.step % log_every == 0:
                    print(f"step {self.step:5d} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms, {tokens/max(dt,1e-9):.0f} "
                          f"tok/s)", flush=True)
        if self.ckpt:
            self.ckpt.wait()
        return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--scheduler", default="fac2",
                    help='schedule clause: "fac2", "guided,4", '
                         '"uds:name(args)", or "runtime" '
                         "(late-bound from $REPRO_SCHEDULE)")
    ap.add_argument("--microbatch-scheduler", default="dynamic,1",
                    help="schedule clause for the microbatch assignment")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoop(cfg, batch=args.batch, seq_len=args.seq_len,
                     scheduler=args.scheduler,
                     microbatch_scheduler=args.microbatch_scheduler,
                     num_microbatches=args.microbatches, lr=args.lr,
                     ckpt_dir=args.ckpt_dir)
    losses = loop.run(args.steps)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
