"""Training driver: end-to-end loop with UDS scheduling, checkpoints,
straggler mitigation.

CPU-runnable (smoke configs / reduced settings); the same driver targets
TPU pods by picking a production mesh and full config:

    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 50
    python -m repro.launch.train --arch qwen3-moe-235b-a22b --smoke \
        --steps 30 --scheduler awf --microbatches 2

Multi-host (``hosts > 1``): the loop runs on a ``("host", "model")`` mesh
(emulate N hosts on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
jax import), records PER-HOST step wall times into the telemetry ledger,
feeds them through ``StragglerMitigator.observe_step`` every step, and on
each measured-epoch bump re-splits the global batch UNEVENLY across hosts
from the mitigator's AWF ``token_shares`` (``split_batch_by_shares`` —
masked, shape-static).  A slow host (``host_skew`` injects one in
emulation; real pods report real clocks) sees its token share shrink
within a few steps:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.train --arch qwen2.5-3b --smoke --hosts 4 \
        --straggler-scheduler "wf2"
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import Chunk, LoopHistory, LoopTelemetry
from repro.core.spec import SpecLike, resolve
from repro.data import SyntheticCorpus
from repro.launch.mesh import (batch_shardings, make_host_mesh, make_mesh,
                               rules_for, shardings_for)
from repro.launch.steps import (make_fused_train_step, make_train_step,
                                opt_state_specs, plan_microbatches,
                                split_batch_by_shares)
from repro.models import get_model
from repro.optim import cosine_schedule, make_optimizer, wsd_schedule
from repro.sched import (CapacityPlanner, StragglerMitigator,
                         pack_with_scheduler)
from repro.sharding import axis_rules
from repro.checkpoint import AsyncCheckpointer

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Composable training loop; examples and tests drive this class."""

    def __init__(self, cfg, *, batch: int, seq_len: int,
                 mesh_shape=None, scheduler: SpecLike = "fac2",
                 microbatch_scheduler: SpecLike = "dynamic,1",
                 num_microbatches: int = 1,
                 fused_microbatches: bool = False, lr: float = 3e-4,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 data_sigma: float = 1.0, hosts: int = 1,
                 straggler_scheduler: SpecLike = "wf2",
                 min_host_share: float = 0.1,
                 host_skew: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.batch, self.seq_len = batch, seq_len
        self.model = get_model(cfg)
        self.history = LoopHistory()
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        if batch % hosts != 0:
            raise ValueError(f"global batch {batch} not divisible by "
                             f"{hosts} hosts")
        if hosts > 1 and num_microbatches > 1:
            # the splitter's host model is "host h owns contiguous row
            # block h" of the (B, S) input; the microbatch reshape
            # (B,S) -> (M, B/M, S) inside jit lets GSPMD re-shard each
            # microbatch over the hosts, so physical row ownership is no
            # longer that block and shares/attribution would land on the
            # wrong hosts.  Refuse rather than silently mis-attribute
            # (microbatch-aware host row mapping is a ROADMAP item).
            raise ValueError("hosts > 1 does not compose with "
                             "num_microbatches > 1 yet")
        self.hosts = hosts
        # per-host slowdown multipliers — the EMULATION's measurement model
        # (one process cannot clock N emulated hosts separately): host h's
        # share of each step's wall time is token_count[h] * host_skew[h].
        # Real multi-host deployments pass genuine per-host clocks to
        # ``mitigator.observe_step`` instead and leave this at ones.
        skew = np.ones(hosts) if host_skew is None else np.asarray(
            host_skew, float)
        if skew.shape != (hosts,) or not (skew > 0).all():
            raise ValueError(f"host_skew needs {hosts} positive entries")
        self.host_skew = skew
        # the measure stage: per-step wall time + token counts flushed into
        # the history under "train_step" — each flush bumps the measured
        # epoch, so adaptive schedules planning against this history replan
        # from real step times (and the packing history's own records feed
        # the AWF document packer).  Multi-host: one ledger per host, the
        # step's wall time split by ``add_time_weighted`` attribution.
        self.telemetry = LoopTelemetry(self.history, loop_id="train_step",
                                       num_workers=hosts)
        # ``scheduler`` / ``microbatch_scheduler`` accept any schedule
        # clause form: a spec, "guided,4", "uds:name(args)", "runtime"
        # (late-bound from $REPRO_SCHEDULE), or a scheduler instance
        self.pack_sched = resolve(scheduler)
        self.microbatch_sched = microbatch_scheduler
        self.num_microbatches = num_microbatches
        # fused: apply the UDS microbatch permutation ON DEVICE inside the
        # jitted step (one dispatch per optimizer step) instead of as a
        # host-side eager gather before it — numerically identical
        # (same permutation, lowered into the program).  A no-op request
        # at num_microbatches == 1 is simply ignored.
        self.fused_microbatches = bool(fused_microbatches
                                       and num_microbatches > 1)
        self.capacity = (CapacityPlanner(cfg, seq_len) if cfg.is_moe else None)

        devs = len(jax.devices())
        if hosts > 1:
            if devs < hosts:
                raise ValueError(
                    f"hosts={hosts} needs {hosts} devices, only {devs} "
                    f"available — emulate them with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={hosts} "
                    f"(before the first jax import)")
            if mesh_shape is not None:
                if mesh_shape[0] != hosts:
                    raise ValueError(f"mesh_shape {tuple(mesh_shape)} "
                                     f"disagrees with hosts={hosts}")
                model_par = mesh_shape[1]
            else:
                model_par = 1
                per_host = devs // hosts
                while model_par * 2 <= per_host and model_par < 4:
                    model_par *= 2
            self.mesh = make_host_mesh(hosts, model_par)
        else:
            if mesh_shape is None:
                model_par = 1
                while model_par * 2 <= devs and model_par < 4:
                    model_par *= 2
                mesh_shape = (max(devs // model_par, 1), model_par)
            self.mesh = make_mesh(mesh_shape, ("data", "model"))
        self.rules = rules_for(cfg, self.mesh, "train", batch)

        if cfg.name.startswith("minicpm"):
            sched_fn = wsd_schedule(lr, 20, 10_000, 1_000)   # the WSD paper
        else:
            sched_fn = cosine_schedule(lr, 20, 10_000)
        opt_init, opt_update = make_optimizer(cfg.optimizer, sched_fn)

        key = jax.random.PRNGKey(seed)
        with self.mesh, axis_rules(self.mesh, self.rules):
            params, specs = self.model.init(key, jnp.bfloat16)
            pshard = shardings_for(specs, self.rules, self.mesh, tree=params)
            params = jax.device_put(params, pshard)
            opt_state = opt_init(params)
            oshard = shardings_for(
                opt_state_specs(cfg.optimizer, params, specs),
                self.rules, self.mesh, tree=opt_state)
            opt_state = jax.device_put(opt_state, oshard)
        self.params, self.opt_state = params, opt_state
        self.pshard, self.oshard = pshard, oshard
        self.specs = specs

        if self.fused_microbatches:
            step_fn = make_fused_train_step(self.model, opt_update,
                                            num_microbatches=num_microbatches)
        else:
            step_fn = make_train_step(self.model, opt_update,
                                      num_microbatches=num_microbatches)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self._perm: Optional[jax.Array] = None
        self.step = 0
        self.corpus = SyntheticCorpus(cfg.vocab_size, mean_len=seq_len / 4,
                                      sigma=data_sigma, seed=seed)
        self._doc_iter = self.corpus.documents()
        # ``straggler_scheduler`` is a schedule clause like every other
        # surface; it turns the mitigator's AWF weights into integer token
        # shares.  min_host_share floors every host at 10% of the even
        # share so a throttled host keeps reporting (and can rehabilitate).
        self.mitigator = StragglerMitigator(num_hosts=hosts,
                                            scheduler=straggler_scheduler,
                                            min_share=min_host_share)
        # per-host input placement (batch rows block-split over "host")
        self._in_shard = None if hosts == 1 else "pending"
        self.last_shares: Optional[np.ndarray] = None
        self._host_tokens: Optional[np.ndarray] = None
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_dir = ckpt_dir

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, jax.Array]:
        docs = [next(self._doc_iter) for _ in range(self.batch * 3)]
        packed = pack_with_scheduler(self.pack_sched, docs, self.batch,
                                     self.seq_len, history=self.history)
        batch = {"tokens": jnp.asarray(packed.tokens),
                 "labels": jnp.asarray(packed.labels),
                 "segment_ids": jnp.asarray(packed.segment_ids)}
        if self.num_microbatches > 1:
            costs = (packed.segment_ids > 0).sum(axis=1).astype(float)
            if self.fused_microbatches:
                # plan host-side (the UDS still decides the assignment),
                # but only ship the permutation — the gather itself runs
                # inside the fused jitted step, not as an eager dispatch
                from repro.sched.microbatch import plan_microbatch_permutation
                perm = plan_microbatch_permutation(
                    self.microbatch_sched, costs, self.num_microbatches)
                self._perm = jnp.asarray(perm)
            else:
                batch = plan_microbatches(batch, costs,
                                          self.num_microbatches,
                                          scheduler=self.microbatch_sched)
        if self.capacity is not None:
            batch["cap_e"] = jnp.asarray(self.capacity.plan())
        if self.cfg.frontend != "none":
            # stub frontend: embed tokens host-side stand-in
            emb = jax.random.normal(
                jax.random.PRNGKey(self.step),
                (self.batch, self.seq_len, self.cfg.d_model), jnp.bfloat16)
            batch["embeds"] = emb
        if self.cfg.mrope_sections is not None:
            pos = jnp.tile(jnp.arange(self.seq_len, dtype=jnp.int32)[None],
                           (self.batch, 1))
            batch["positions_3d"] = jnp.stack([pos, pos, pos])
        if self.hosts > 1:
            # plan: AWF token shares from the measured per-host rates (the
            # engine's plan cache makes this ~µs in steady state; each
            # observe_step's flush bumps the measured epoch, so changed
            # rates miss the cache and the shares REPLAN) -> uneven split.
            # The packer's numpy labels let the splitter count per-host
            # real tokens without a device round-trip (rows are never
            # permuted here: multi-host excludes microbatching).
            shares = self.mitigator.token_shares(self.batch * self.seq_len)
            batch, self._host_tokens = split_batch_by_shares(
                batch, shares, self.hosts, labels_np=packed.labels)
            self.last_shares = shares
        return batch

    def _observe_multihost(self, dt: float) -> None:
        """The multi-host measure stage for one step: split the step's
        wall time over per-host ledgers (attribution weights = real token
        count x injected skew — see ``host_skew``), flush (one measured
        epoch), and feed the same per-host times to the mitigator whose
        AWF weights drive the next split."""
        ht = self._host_tokens
        w = ht.astype(float) * self.host_skew
        if w.sum() <= 0:
            w = np.ones(self.hosts)
        # each step is its own invocation (record() otherwise appends to
        # the last one forever and the measured epoch never advances)
        self.history.open_invocation("train_step")
        # one ledger per host over the step's global token index space
        off = 0
        for h in range(self.hosts):
            size = max(int(ht[h]), 1)
            self.telemetry.begin(h, Chunk(off, off + size, h))
            off += size
        self.telemetry.add_time_weighted(
            dt, {h: w[h] for h in range(self.hosts)},
            tokens={h: int(ht[h]) for h in range(self.hosts)})
        self.telemetry.flush()
        host_times = {h: dt * w[h] / w.sum() for h in range(self.hosts)}
        self.mitigator.observe_step(
            host_times, host_tokens={h: max(int(ht[h]), 1)
                                     for h in range(self.hosts)})

    def run(self, steps: int, log_every: int = 10) -> list:
        losses = []
        with self.mesh, axis_rules(self.mesh, self.rules):
            for _ in range(steps):
                batch = self.next_batch()
                if self.hosts > 1:
                    if self._in_shard == "pending":
                        self._in_shard = batch_shardings(self.mesh,
                                                         self.rules, batch)
                    batch = jax.device_put(batch, self._in_shard)
                t0 = time.perf_counter()
                if self.fused_microbatches:
                    self.params, self.opt_state, metrics = self._step(
                        self.params, self.opt_state,
                        jnp.asarray(self.step, jnp.int32), batch, self._perm)
                else:
                    self.params, self.opt_state, metrics = self._step(
                        self.params, self.opt_state,
                        jnp.asarray(self.step, jnp.int32), batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                tokens = int(metrics.get("tokens", self.batch * self.seq_len))
                if self.hosts > 1:
                    self._observe_multihost(dt)
                else:
                    # measure: one record per step (host 0, size = tokens),
                    # in its own invocation flushed immediately, so each
                    # step is one measured epoch
                    self.history.open_invocation("train_step")
                    self.telemetry.record_chunk(0, 0, max(tokens, 1), dt,
                                                tokens=tokens)
                    self.telemetry.flush()
                    self.mitigator.observe_step(
                        {0: dt}, host_tokens={0: max(tokens, 1)})
                losses.append(loss)
                self.step += 1
                if self.ckpt and self.step % 10 == 0:
                    self.ckpt.save(self.step, {"params": self.params,
                                               "opt": self.opt_state})
                if self.step % log_every == 0:
                    print(f"step {self.step:5d} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms, {tokens/max(dt,1e-9):.0f} "
                          f"tok/s)", flush=True)
        if self.ckpt:
            self.ckpt.wait()
        return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--scheduler", default="fac2",
                    help='schedule clause: "fac2", "guided,4", '
                         '"uds:name(args)", "runtime" (late-bound from '
                         '$REPRO_SCHEDULE), or "auto" (selected online '
                         "from telemetry; see docs/SCHEDULING.md)")
    ap.add_argument("--microbatch-scheduler", default="dynamic,1",
                    help="schedule clause for the microbatch assignment")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fused-microbatches", action="store_true",
                    help="apply the UDS microbatch permutation on device "
                         "inside the jitted step (one dispatch per "
                         "optimizer step; numerically identical)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="data-parallel hosts; the AWF straggler loop "
                         "re-splits the batch unevenly across them "
                         "(emulate N on CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--straggler-scheduler", default="wf2",
                    help="schedule clause turning AWF host weights into "
                         'token shares (any weight-aware clause, or "auto" '
                         "to select one online from step telemetry)")
    ap.add_argument("--min-host-share", type=float, default=0.1,
                    help="per-host floor as a fraction of the even share "
                         "(0 = let a straggler starve, 1 = pin static "
                         "even shares)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoop(cfg, batch=args.batch, seq_len=args.seq_len,
                     scheduler=args.scheduler,
                     microbatch_scheduler=args.microbatch_scheduler,
                     num_microbatches=args.microbatches,
                     fused_microbatches=args.fused_microbatches, lr=args.lr,
                     ckpt_dir=args.ckpt_dir, hosts=args.hosts,
                     straggler_scheduler=args.straggler_scheduler,
                     min_host_share=args.min_host_share)
    losses = loop.run(args.steps)
    if args.hosts > 1 and loop.last_shares is not None:
        frac = loop.last_shares / max(int(loop.last_shares.sum()), 1)
        print(f"host token shares: {np.round(frac, 3).tolist()} "
              f"(measured epoch {loop.mitigator.epoch()})")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
