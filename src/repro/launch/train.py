"""Training driver: end-to-end loop with UDS scheduling, checkpoints,
straggler mitigation.

CPU-runnable (smoke configs / reduced settings); the same driver targets
TPU pods by picking a production mesh and full config:

    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 50
    python -m repro.launch.train --arch qwen3-moe-235b-a22b --smoke \
        --steps 30 --scheduler awf --microbatches 2

Multi-host (``hosts > 1``): the loop runs on a ``("host", "model")`` mesh
(emulate N hosts on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
jax import), records PER-HOST step wall times into the telemetry ledger,
feeds them through ``StragglerMitigator.observe_step`` every step, and on
each measured-epoch bump re-splits the global batch UNEVENLY across hosts
from the mitigator's AWF ``token_shares`` (``split_batch_by_shares`` —
masked, shape-static).  A slow host (``host_skew`` injects one in
emulation; real pods report real clocks) sees its token share shrink
within a few steps:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.train --arch qwen2.5-3b --smoke --hosts 4 \
        --straggler-scheduler "wf2"
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (Chunk, LoopHistory, LoopTelemetry, MembershipEvent,
                        get_engine)
from repro.core.spec import SpecLike, resolve
from repro.data import SyntheticCorpus
from repro.launch.mesh import (batch_shardings, make_host_mesh, make_mesh,
                               rules_for, shardings_for)
from repro.launch.steps import (apply_microbatch_plan, make_fused_train_step,
                                make_train_step, opt_state_specs,
                                plan_microbatches, split_batch_by_shares)
from repro.models import get_model
from repro.optim import cosine_schedule, make_optimizer, wsd_schedule
from repro.sched import (CapacityPlanner, StragglerMitigator,
                         pack_with_scheduler)
from repro.sched.microbatch import (plan_hier_microbatch_permutation,
                                    plan_microbatch_permutation)
from repro.sharding import axis_rules
from repro.checkpoint import AsyncCheckpointer

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Composable training loop; examples and tests drive this class."""

    def __init__(self, cfg, *, batch: int, seq_len: int,
                 mesh_shape=None, scheduler: SpecLike = "fac2",
                 microbatch_scheduler: SpecLike = "dynamic,1",
                 num_microbatches: int = 1,
                 fused_microbatches: bool = False, lr: float = 3e-4,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 data_sigma: float = 1.0, hosts: int = 1,
                 straggler_scheduler: SpecLike = "wf2",
                 min_host_share: float = 0.1,
                 host_skew: Optional[Sequence[float]] = None,
                 elastic: bool = False,
                 kill_hosts: Optional[Sequence[int]] = None,
                 kill_at_step: Optional[int] = None):
        self.cfg = cfg
        self.batch, self.seq_len = batch, seq_len
        self.model = get_model(cfg)
        self.history = LoopHistory()
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        if batch % hosts != 0:
            raise ValueError(f"global batch {batch} not divisible by "
                             f"{hosts} hosts")
        # ``scheduler`` accepts any schedule clause form, including a
        # hierarchical composition hier(host=..., device=..., tile=...).
        # A hier clause threads through every loop surface: the outermost
        # (host) level packs documents and drives the straggler token
        # shares, the device level assigns microbatch rows per host block.
        self.pack_sched = resolve(scheduler)
        self.hier = (self.pack_sched
                     if getattr(self.pack_sched, "hier_levels", None)
                     else None)
        if hosts > 1 and num_microbatches > 1:
            if self.hier is None:
                # the splitter's host model is "host h owns contiguous row
                # block h" of the (B, S) input; the microbatch reshape
                # (B,S) -> (M, B/M, S) inside jit lets GSPMD re-shard each
                # microbatch over the hosts, so for a FLAT clause physical
                # row ownership is no longer that block and shares /
                # attribution would land on the wrong hosts.  A hier
                # clause's host level owns the blocks and the microbatch
                # permutation is planned PER BLOCK, interleaved so every
                # microbatch's host-h shard holds only host-h rows
                # (plan_hier_microbatch_permutation).
                raise ValueError(
                    "hosts > 1 does not compose with num_microbatches > 1 "
                    "for a flat schedule clause — use a hierarchical one, "
                    "e.g. hier(host=awf, device=static) "
                    "(docs/SCHEDULING.md, Hierarchical composition)")
            if (batch // hosts) % num_microbatches != 0:
                raise ValueError(
                    f"per-host row block ({batch // hosts}) not divisible "
                    f"by num_microbatches ({num_microbatches})")
        self.hosts = hosts
        # per-host slowdown multipliers — the EMULATION's measurement model
        # (one process cannot clock N emulated hosts separately): host h's
        # share of each step's wall time is token_count[h] * host_skew[h].
        # Real multi-host deployments pass genuine per-host clocks to
        # ``mitigator.observe_step`` instead and leave this at ones.
        skew = np.ones(hosts) if host_skew is None else np.asarray(
            host_skew, float)
        if skew.shape != (hosts,) or not (skew > 0).all():
            raise ValueError(f"host_skew needs {hosts} positive entries")
        self.host_skew = skew
        # the measure stage: per-step wall time + token counts flushed into
        # the history under "train_step" — each flush bumps the measured
        # epoch, so adaptive schedules planning against this history replan
        # from real step times (and the packing history's own records feed
        # the AWF document packer).  Multi-host: one ledger per host, the
        # step's wall time split by ``add_time_weighted`` attribution.
        self.telemetry = LoopTelemetry(self.history, loop_id="train_step",
                                       num_workers=hosts)
        # ``microbatch_scheduler`` accepts any schedule clause form: a
        # spec, "guided,4", "uds:name(args)", "runtime", or a scheduler
        # instance.  A hier clause's device level (when present) takes
        # over the microbatch assignment.
        dev_level = self.hier.level("device") if self.hier else None
        self.microbatch_sched = (dev_level if dev_level is not None
                                 else microbatch_scheduler)
        self.num_microbatches = num_microbatches
        # fused: apply the UDS microbatch permutation ON DEVICE inside the
        # jitted step (one dispatch per optimizer step) instead of as a
        # host-side eager gather before it — numerically identical
        # (same permutation, lowered into the program).  A no-op request
        # at num_microbatches == 1 is simply ignored.
        self.fused_microbatches = bool(fused_microbatches
                                       and num_microbatches > 1)
        self.capacity = (CapacityPlanner(cfg, seq_len) if cfg.is_moe else None)

        devs = len(jax.devices())
        if hosts > 1:
            if devs < hosts:
                raise ValueError(
                    f"hosts={hosts} needs {hosts} devices, only {devs} "
                    f"available — emulate them with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={hosts} "
                    f"(before the first jax import)")
            if mesh_shape is not None:
                if mesh_shape[0] != hosts:
                    raise ValueError(f"mesh_shape {tuple(mesh_shape)} "
                                     f"disagrees with hosts={hosts}")
                model_par = mesh_shape[1]
            else:
                model_par = 1
                per_host = devs // hosts
                while model_par * 2 <= per_host and model_par < 4:
                    model_par *= 2
            self.mesh = make_host_mesh(hosts, model_par)
        else:
            if mesh_shape is None:
                model_par = 1
                while model_par * 2 <= devs and model_par < 4:
                    model_par *= 2
                mesh_shape = (max(devs // model_par, 1), model_par)
            else:
                model_par = mesh_shape[-1]
            self.mesh = make_mesh(mesh_shape, ("data", "model"))
        self.model_par = model_par
        self.rules = rules_for(cfg, self.mesh, "train", batch)
        # elastic scheduling: membership change (worker loss) becomes a
        # replan event — see apply_membership().  The original clause
        # strings are kept so the active specs can be RE-RESOLVED over the
        # new team size after churn (auto reselects from fresh telemetry).
        self.elastic = bool(elastic)
        self._scheduler_clause = scheduler
        # a hierarchical --scheduler owns the host-share policy too: the
        # mitigator plans the FULL hier clause (its worker_iters are the
        # host level's shares, and the ComposedPlan's provenance is what a
        # membership requeue recovers a dead host's block from)
        self._straggler_clause = (self.hier.spec if self.hier is not None
                                  else straggler_scheduler)
        self.membership_events: list = []
        self.requeue_audits: list = []
        self._kill_hosts = (tuple(int(h) for h in kill_hosts)
                            if kill_hosts else None)
        self._kill_at = kill_at_step
        if self._kill_hosts is not None and not self.elastic:
            raise ValueError("kill_hosts injection requires elastic=True "
                             "(--elastic)")
        self._pending_unsplit = None
        self._churn_shares: Optional[np.ndarray] = None
        self.step_log: list = []    # per-step {step, dt_s, tokens, hosts}

        if cfg.name.startswith("minicpm"):
            sched_fn = wsd_schedule(lr, 20, 10_000, 1_000)   # the WSD paper
        else:
            sched_fn = cosine_schedule(lr, 20, 10_000)
        opt_init, opt_update = make_optimizer(cfg.optimizer, sched_fn)

        key = jax.random.PRNGKey(seed)
        with self.mesh, axis_rules(self.mesh, self.rules):
            params, specs = self.model.init(key, jnp.bfloat16)
            pshard = shardings_for(specs, self.rules, self.mesh, tree=params)
            params = jax.device_put(params, pshard)
            opt_state = opt_init(params)
            oshard = shardings_for(
                opt_state_specs(cfg.optimizer, params, specs),
                self.rules, self.mesh, tree=opt_state)
            opt_state = jax.device_put(opt_state, oshard)
        self.params, self.opt_state = params, opt_state
        self.pshard, self.oshard = pshard, oshard
        self.specs = specs

        if self.fused_microbatches:
            step_fn = make_fused_train_step(self.model, opt_update,
                                            num_microbatches=num_microbatches)
        else:
            step_fn = make_train_step(self.model, opt_update,
                                      num_microbatches=num_microbatches)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self._perm: Optional[jax.Array] = None
        self.step = 0
        self.corpus = SyntheticCorpus(cfg.vocab_size, mean_len=seq_len / 4,
                                      sigma=data_sigma, seed=seed)
        self._doc_iter = self.corpus.documents()
        # ``straggler_scheduler`` is a schedule clause like every other
        # surface; it turns the mitigator's AWF weights into integer token
        # shares.  min_host_share floors every host at 10% of the even
        # share so a throttled host keeps reporting (and can rehabilitate).
        self.mitigator = StragglerMitigator(num_hosts=hosts,
                                            scheduler=self._straggler_clause,
                                            min_share=min_host_share)
        # per-host input placement (batch rows block-split over "host")
        self._in_shard = None if hosts == 1 else "pending"
        self.last_shares: Optional[np.ndarray] = None
        self._host_tokens: Optional[np.ndarray] = None
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_dir = ckpt_dir

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, jax.Array]:
        docs = [next(self._doc_iter) for _ in range(self.batch * 3)]
        packed = pack_with_scheduler(self.pack_sched, docs, self.batch,
                                     self.seq_len, history=self.history)
        batch = {"tokens": jnp.asarray(packed.tokens),
                 "labels": jnp.asarray(packed.labels),
                 "segment_ids": jnp.asarray(packed.segment_ids)}
        costs = ((packed.segment_ids > 0).sum(axis=1).astype(float)
                 if self.num_microbatches > 1 else None)
        if self.num_microbatches > 1 and self.hosts == 1:
            if self.fused_microbatches:
                # plan host-side (the UDS still decides the assignment),
                # but only ship the permutation — the gather itself runs
                # inside the fused jitted step, not as an eager dispatch
                perm = plan_microbatch_permutation(
                    self.microbatch_sched, costs, self.num_microbatches)
                self._perm = jnp.asarray(perm)
            else:
                batch = plan_microbatches(batch, costs,
                                          self.num_microbatches,
                                          scheduler=self.microbatch_sched)
        if self.capacity is not None:
            batch["cap_e"] = jnp.asarray(self.capacity.plan())
        if self.cfg.frontend != "none":
            # stub frontend: embed tokens host-side stand-in
            emb = jax.random.normal(
                jax.random.PRNGKey(self.step),
                (self.batch, self.seq_len, self.cfg.d_model), jnp.bfloat16)
            batch["embeds"] = emb
        if self.cfg.mrope_sections is not None:
            pos = jnp.tile(jnp.arange(self.seq_len, dtype=jnp.int32)[None],
                           (self.batch, 1))
            batch["positions_3d"] = jnp.stack([pos, pos, pos])
        if self.hosts > 1:
            # the UNSPLIT batch + host-side labels are held until the next
            # step completes: a membership change mid-step re-splits this
            # exact batch over the survivors (no step dropped at churn)
            if self.elastic:
                self._pending_unsplit = (dict(batch), packed.labels, costs)
            # plan: AWF token shares from the measured per-host rates (the
            # engine's plan cache makes this ~µs in steady state; each
            # observe_step's flush bumps the measured epoch, so changed
            # rates miss the cache and the shares REPLAN) -> uneven split.
            # The packer's numpy labels let the splitter count per-host
            # real tokens without a device round-trip.  Splitting happens
            # BEFORE any microbatch permutation: shares and attribution
            # are defined over the ORIGINAL contiguous host blocks.
            shares = self.mitigator.token_shares(self.batch * self.seq_len)
            batch, self._host_tokens = split_batch_by_shares(
                batch, shares, self.hosts, labels_np=packed.labels)
            self.last_shares = shares
            if self.num_microbatches > 1:
                # hier path (flat clauses were refused in __init__): the
                # device level permutes each host's block independently,
                # interleaved so microbatch m's host-h shard holds only
                # host-h rows — block ownership survives the reshape
                perm = plan_hier_microbatch_permutation(
                    self.microbatch_sched, costs, self.num_microbatches,
                    self.hosts, history=self.history)
                if self.fused_microbatches:
                    self._perm = jnp.asarray(perm)
                else:
                    batch = apply_microbatch_plan(batch, perm)
        return batch

    # ------------------------------------------------------- membership
    def apply_membership(self, lost: Sequence[int]) -> MembershipEvent:
        """Worker loss as a replan event: rebuild the spine for the
        survivors (requires ``elastic=True``).

        The full plan → execute → measure → replan treatment of a kill:

        1. **requeue** — if a scheduler-produced share plan was live, the
           dead hosts' token budgets are recovered from its chunk→worker
           provenance and replanned over the surviving team
           (``PlanEngine.requeue_plan``); survivors keep their own
           budgets.  Otherwise (uniform shares) the resized mitigator's
           cold-start shares are exactly uniform over the survivors.
           Either way the post-churn shares sum to the full token budget
           — no tokens silently lost.
        2. **mesh** — ``plan_degraded_mesh`` picks the surviving shape
           (warning about any idled devices), params/optimizer state are
           re-sharded onto the new ``("host", "model")`` mesh, and the
           jitted step recompiles against the new input shardings.
        3. **measure/replan** — a :class:`MembershipEvent` sentinel bumps
           the ``train_step`` measured epoch (cached adaptive plans
           invalidate), the mitigator resizes (rate windows floor at the
           churn), and the schedule clauses re-resolve over the new team
           size, so ``auto`` reselects from post-churn telemetry.

        Survivors are renumbered densely ``0..new_hosts-1`` in old-id
        order; the held unsplit batch (if any) is re-split by
        ``_resplit_pending`` so the in-flight step runs on the survivors.
        """
        from repro.runtime.elastic import plan_degraded_mesh
        if not self.elastic:
            raise RuntimeError("membership change requires elastic=True "
                               "(--elastic)")
        lost = sorted({int(h) for h in lost})
        if not lost:
            raise ValueError("no hosts named in the membership change")
        bad = [h for h in lost if not 0 <= h < self.hosts]
        if bad:
            raise ValueError(f"lost hosts {bad} outside the current team "
                             f"0..{self.hosts - 1}")
        survivors = [h for h in range(self.hosts) if h not in lost]
        if not survivors:
            raise ValueError("cannot lose every host")
        old_hosts = self.hosts
        shape = plan_degraded_mesh(len(survivors) * self.model_par,
                                   self.model_par)
        new_hosts = shape[0]
        while new_hosts > 1 and (
                self.batch % new_hosts
                or (self.num_microbatches > 1
                    and (self.batch // new_hosts) % self.num_microbatches)):
            new_hosts //= 2      # keep batch AND per-host blocks divisible
        event = MembershipEvent(kind="loss", old_size=old_hosts,
                                new_size=new_hosts, lost=tuple(lost),
                                step=self.step)

        # -- 1. requeue the dead hosts' unfinished token budget ---------
        total = self.batch * self.seq_len
        self._churn_shares = None
        plan = self.mitigator.last_plan
        if (plan is not None and self.last_shares is not None
                and len(survivors) == new_hosts
                and np.array_equal(plan.worker_iters(), self.last_shares)):
            new_plan, iters = get_engine().requeue_plan(
                plan, self._straggler_clause, lost_workers=lost,
                num_workers=new_hosts, history=self.mitigator.history)
            carried = np.asarray([self.last_shares[s] for s in survivors],
                                 np.int64)
            shares = carried + new_plan.worker_iters()
            self.requeue_audits.append({
                "step": self.step, "lost": list(lost),
                "ranges": plan.unfinished_ranges(lost),
                "requeued_iters": int(len(iters)),
                "carried": carried.tolist(),
                "shares": shares.tolist(),
            })
            if int(shares.sum()) != total:
                raise AssertionError(
                    f"requeued shares {shares.tolist()} do not cover "
                    f"{total} tokens — membership requeue lost work")
            self._churn_shares = shares

        # -- 2. rebuild mesh + resharding for the survivors -------------
        self.mesh = make_host_mesh(new_hosts, self.model_par)
        self.rules = rules_for(self.cfg, self.mesh, "train", self.batch)
        with self.mesh, axis_rules(self.mesh, self.rules):
            pshard = shardings_for(self.specs, self.rules, self.mesh,
                                   tree=self.params)
            self.params = jax.device_put(self.params, pshard)
            oshard = shardings_for(
                opt_state_specs(self.cfg.optimizer, self.params, self.specs),
                self.rules, self.mesh, tree=self.opt_state)
            self.opt_state = jax.device_put(self.opt_state, oshard)
        self.pshard, self.oshard = pshard, oshard
        self.hosts = new_hosts
        self.host_skew = np.asarray(
            [self.host_skew[s] for s in survivors[:new_hosts]], float)
        self._in_shard = None if new_hosts == 1 else "pending"

        # -- 3. epoch bump + resize + re-resolve over the new team ------
        self.telemetry.record_membership(event)
        self.mitigator.resize(new_hosts, lost=lost, step=self.step)
        self.pack_sched = resolve(self._scheduler_clause)
        if self.hier is not None:
            self.hier = self.pack_sched
        self.membership_events.append(event)
        return event

    def _resplit_pending(self):
        """Re-split the held unsplit batch over the post-churn team: the
        in-flight step survives the kill instead of being dropped.  Uses
        the requeued shares when a plan was live (survivor budgets
        carried, dead budgets replanned), else the resized mitigator's
        cold-start shares (exactly uniform — the split is a no-op and
        every real token of the step survives verbatim)."""
        if self._pending_unsplit is None:
            raise RuntimeError("no pending batch to re-split")
        batch, labels_np, costs = self._pending_unsplit
        if self.hosts == 1:
            self._host_tokens = np.asarray([(labels_np >= 0).sum()],
                                           np.int64)
            self.last_shares = np.asarray([self.batch * self.seq_len],
                                          np.int64)
            return self._replan_microbatches(batch, costs)
        shares = self._churn_shares
        if shares is None:
            shares = self.mitigator.token_shares(self.batch * self.seq_len)
        self._churn_shares = None
        batch, self._host_tokens = split_batch_by_shares(
            batch, shares, self.hosts, labels_np=labels_np)
        self.last_shares = shares
        return self._replan_microbatches(batch, costs)

    def _replan_microbatches(self, batch, costs):
        """Re-plan the microbatch permutation for the post-churn team: the
        held batch was stored UNPERMUTED, and the block-aligned interleave
        geometry depends on the (now changed) host count."""
        if self.num_microbatches <= 1 or costs is None:
            return batch
        if self.hosts > 1:
            perm = plan_hier_microbatch_permutation(
                self.microbatch_sched, costs, self.num_microbatches,
                self.hosts, history=self.history)
        else:
            perm = plan_microbatch_permutation(
                self.microbatch_sched, costs, self.num_microbatches,
                history=self.history)
        if self.fused_microbatches:
            self._perm = jnp.asarray(perm)
        else:
            batch = apply_microbatch_plan(batch, perm)
        return batch

    def _observe_multihost(self, dt: float) -> None:
        """The multi-host measure stage for one step: split the step's
        wall time over per-host ledgers (attribution weights = real token
        count x injected skew — see ``host_skew``), flush (one measured
        epoch), and feed the same per-host times to the mitigator whose
        AWF weights drive the next split."""
        ht = self._host_tokens
        w = ht.astype(float) * self.host_skew
        if w.sum() <= 0:
            w = np.ones(self.hosts)
        # each step is its own invocation (record() otherwise appends to
        # the last one forever and the measured epoch never advances)
        self.history.open_invocation("train_step")
        # one ledger per host over the step's global token index space
        off = 0
        for h in range(self.hosts):
            size = max(int(ht[h]), 1)
            self.telemetry.begin(h, Chunk(off, off + size, h))
            off += size
        self.telemetry.add_time_weighted(
            dt, {h: w[h] for h in range(self.hosts)},
            tokens={h: int(ht[h]) for h in range(self.hosts)})
        self.telemetry.flush()
        host_times = {h: dt * w[h] / w.sum() for h in range(self.hosts)}
        self.mitigator.observe_step(
            host_times, host_tokens={h: max(int(ht[h]), 1)
                                     for h in range(self.hosts)})

    def run(self, steps: int, log_every: int = 10) -> list:
        """One mesh context per STEP (not per run): a membership change
        mid-run swaps ``self.mesh`` for the survivors' mesh, and the next
        step must enter the new one."""
        losses = []
        for _ in range(steps):
            batch = self.next_batch()
            if (self._kill_at is not None and self._kill_hosts is not None
                    and self.step == self._kill_at):
                # injected kill between batch planning and execution — the
                # worst moment: the step's batch is already split for a
                # team that no longer exists.  Replan + re-split; the step
                # still runs (on the survivors), so no step is lost.
                self._kill_at = None
                self.apply_membership(self._kill_hosts)
                batch = self._resplit_pending()
            with self.mesh, axis_rules(self.mesh, self.rules):
                if self.hosts > 1:
                    if self._in_shard == "pending":
                        self._in_shard = batch_shardings(self.mesh,
                                                         self.rules, batch)
                    batch = jax.device_put(batch, self._in_shard)
                t0 = time.perf_counter()
                if self.fused_microbatches:
                    self.params, self.opt_state, metrics = self._step(
                        self.params, self.opt_state,
                        jnp.asarray(self.step, jnp.int32), batch, self._perm)
                else:
                    self.params, self.opt_state, metrics = self._step(
                        self.params, self.opt_state,
                        jnp.asarray(self.step, jnp.int32), batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                tokens = int(metrics.get("tokens", self.batch * self.seq_len))
                if self.hosts > 1:
                    self._observe_multihost(dt)
                else:
                    # measure: one record per step (host 0, size = tokens),
                    # in its own invocation flushed immediately, so each
                    # step is one measured epoch
                    self.history.open_invocation("train_step")
                    self.telemetry.record_chunk(0, 0, max(tokens, 1), dt,
                                                tokens=tokens)
                    self.telemetry.flush()
                    self.mitigator.observe_step(
                        {0: dt}, host_tokens={0: max(tokens, 1)})
            self._pending_unsplit = None    # step survived; drop the hold
            losses.append(loss)
            self.step_log.append({"step": self.step, "dt_s": dt,
                                  "tokens": tokens, "hosts": self.hosts})
            self.step += 1
            if self.ckpt and self.step % 10 == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state})
            if self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms, {tokens/max(dt,1e-9):.0f} "
                      f"tok/s)", flush=True)
        if self.ckpt:
            self.ckpt.wait()
        return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--scheduler", default="fac2",
                    help='schedule clause: "fac2", "guided,4", '
                         '"uds:name(args)", "runtime" (late-bound from '
                         '$REPRO_SCHEDULE), "auto" (selected online from '
                         'telemetry), or a hierarchical composition '
                         '"hier(host=awf, device=guided,4)" whose host '
                         "level drives packing + token shares and whose "
                         "device level assigns microbatch rows per host "
                         "block (see docs/SCHEDULING.md)")
    ap.add_argument("--microbatch-scheduler", default="dynamic,1",
                    help="schedule clause for the microbatch assignment")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fused-microbatches", action="store_true",
                    help="apply the UDS microbatch permutation on device "
                         "inside the jitted step (one dispatch per "
                         "optimizer step; numerically identical)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="data-parallel hosts; the AWF straggler loop "
                         "re-splits the batch unevenly across them "
                         "(emulate N on CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--straggler-scheduler", default="wf2",
                    help="schedule clause turning AWF host weights into "
                         'token shares (any weight-aware clause, or "auto" '
                         "to select one online from step telemetry)")
    ap.add_argument("--min-host-share", type=float, default=0.1,
                    help="per-host floor as a fraction of the even share "
                         "(0 = let a straggler starve, 1 = pin static "
                         "even shares)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--elastic", action="store_true",
                    help="treat worker loss as a replan event: on a "
                         "membership change the loop rebuilds the mesh "
                         "for the survivors, requeues the dead hosts' "
                         "token budgets from plan provenance, and "
                         "re-resolves the schedule clauses over the new "
                         "team (see docs/SCHEDULING.md, Elastic "
                         "scheduling)")
    ap.add_argument("--kill-hosts", default=None,
                    help='injected-kill hook: comma-separated host ids to '
                         'lose at --kill-at (e.g. "2,3"); requires '
                         "--elastic")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="step index at which the injected kill fires "
                         "(between batch planning and execution)")
    args = ap.parse_args()

    kill_hosts = ([int(h) for h in args.kill_hosts.split(",")]
                  if args.kill_hosts else None)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoop(cfg, batch=args.batch, seq_len=args.seq_len,
                     scheduler=args.scheduler,
                     microbatch_scheduler=args.microbatch_scheduler,
                     num_microbatches=args.microbatches,
                     fused_microbatches=args.fused_microbatches, lr=args.lr,
                     ckpt_dir=args.ckpt_dir, hosts=args.hosts,
                     straggler_scheduler=args.straggler_scheduler,
                     min_host_share=args.min_host_share,
                     elastic=args.elastic, kill_hosts=kill_hosts,
                     kill_at_step=args.kill_at)
    losses = loop.run(args.steps)
    if args.hosts > 1 and loop.last_shares is not None:
        frac = loop.last_shares / max(int(loop.last_shares.sum()), 1)
        print(f"host token shares: {np.round(frac, 3).tolist()} "
              f"(measured epoch {loop.mitigator.epoch()})")
    for ev in loop.membership_events:
        print(f"membership: {ev.kind} at step {ev.step} — "
              f"{ev.old_size} -> {ev.new_size} hosts (lost "
              f"{list(ev.lost)}); no step dropped, batch re-split over "
              f"the survivors")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
