"""Logical-axis sharding: rule tables, spec construction, and in-model
activation constraints.

GSPMD sharding propagation into scanned (while-loop) bodies is weak: left
unannotated, XLA happily replicates the batch axis of every activation
inside the layer loop (observed: a 19 GB/chip carry stack on a 3B model).
The fix — standard in production JAX frameworks — is explicit
``with_sharding_constraint`` on activations at layer boundaries, expressed
here through the same logical-axis rule table as the parameters, so a
single rule change re-shards the whole program (the §Perf lever).

Usage (models):
    from repro.sharding import constrain
    x = constrain(x, "batch", None, "act_embed")

Usage (launch layer):
    with axis_rules(mesh, rules):
        lowered = jitted.lower(...)
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "BATCH_AXES", "spec_for", "shardings_for", "axis_rules",
           "constrain", "current_rules"]

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Logical axes of every model-input batch key (leading "batch" axis where
# present) — the one table behind jit argument shardings
# (launch/steps.input_shardings) and live-batch placement
# (launch/mesh.batch_shardings): the data-parallel split of a batch dict is
# defined HERE, once, whatever mesh axis ("data", ("pod", "data"), "host")
# the active rule table maps "batch" onto.
BATCH_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", None),
    "embeds": ("batch", None, None),
    "positions_3d": (None, "batch", None),
    "labels": ("batch", None),
    "segment_ids": ("batch", None),
    "cap_e": (None,),
}

_tls = threading.local()


def spec_for(logical: Tuple[Optional[str], ...], rules: Rules,
             shape: Optional[Tuple[int, ...]] = None,
             axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """Logical axes tuple -> PartitionSpec.

    * A mesh axis shards at most one tensor dim (duplicates dropped).
    * With ``shape``+``axis_sizes``: dims not divisible by their mesh-axes
      product fall back to replication (jit argument shardings and
      with_sharding_constraint require exact divisibility; e.g. minicpm's
      vocab=122753 cannot 16-way shard — the table replicates, noted in
      EXPERIMENTS.md).
    """
    used = set()
    out = []
    for i, ax in enumerate(logical):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        maxes = (m,) if isinstance(m, str) else tuple(m)
        free = tuple(a for a in maxes if a not in used)
        if not free:
            out.append(None)
            continue
        if shape is not None and axis_sizes is not None:
            total = math.prod(axis_sizes.get(a, 1) for a in free)
            if shape[i] % total != 0:
                out.append(None)
                continue
        used.update(free)
        out.append(free if len(free) > 1 else free[0])
    return P(*out)


def _sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def shardings_for(specs: Any, rules: Rules, mesh: Mesh,
                  tree: Any = None) -> Any:
    """Tree of logical-axes tuples -> tree of NamedShardings.

    ``tree``: optional matching tree of arrays/ShapeDtypeStructs enabling
    the divisibility fallback.
    """
    def is_spec(x):
        return isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x)

    if tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, spec_for(s, rules)),
            specs, is_leaf=is_spec)
    flat_t, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    sizes = _sizes(mesh)
    out = [NamedSharding(mesh, spec_for(s, rules, shape=t.shape,
                                        axis_sizes=sizes))
           for s, t in zip(flat_s, flat_t)]
    return treedef.unflatten(out)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Rules):
    """Activate (mesh, rules) for in-model ``constrain`` calls during trace."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = (mesh, rules, _sizes(mesh))
    try:
        yield
    finally:
        _tls.ctx = prev


def current_rules() -> Optional[Tuple[Mesh, Rules, Dict[str, int]]]:
    return getattr(_tls, "ctx", None)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via the active rule table; no-op outside an
    ``axis_rules`` context (single-device tests run unannotated)."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules, sizes = ctx
    if len(logical) != x.ndim:
        raise ValueError(f"constrain: {len(logical)} axes for rank-{x.ndim}")
    spec = spec_for(tuple(logical), rules, shape=x.shape, axis_sizes=sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
