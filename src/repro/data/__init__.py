from repro.data.pipeline import (
    PackedBatch,
    SyntheticCorpus,
    batch_iterator,
    pack_documents,
)

__all__ = ["SyntheticCorpus", "PackedBatch", "pack_documents",
           "batch_iterator"]
