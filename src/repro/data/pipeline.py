"""Data pipeline: synthetic corpus, UDS-scheduled document packing, sharding.

The corpus generator produces variable-length "documents" (zipfian tokens,
log-normal lengths) — the irregular-iteration workload of the paper.  The
packer treats documents as loop iterations and a UDS as the packing policy:
``dequeue`` assigns document chunks to sequence slots, balancing token load
across data-parallel workers (see sched/packing.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import numpy as np

__all__ = ["SyntheticCorpus", "PackedBatch", "pack_documents",
           "batch_iterator"]


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic synthetic document stream."""

    vocab_size: int
    mean_len: float = 512.0
    sigma: float = 1.0          # log-normal length spread (irregularity knob)
    max_len: int = 8192
    seed: int = 0

    def documents(self) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(self.seed)
        zipf_p = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1
        zipf_p /= zipf_p.sum()
        while True:
            n = int(np.clip(rng.lognormal(np.log(self.mean_len), self.sigma),
                            8, self.max_len))
            yield rng.choice(self.vocab_size, size=n, p=zipf_p
                             ).astype(np.int32)


@dataclasses.dataclass
class PackedBatch:
    tokens: np.ndarray        # (B, S) int32
    labels: np.ndarray        # (B, S) int32, -100 on padding
    segment_ids: np.ndarray   # (B, S) int32, 0 = padding
    fill_fraction: float      # packing efficiency


def pack_documents(docs: Sequence[np.ndarray], batch: int, seq_len: int,
                   assignment: Optional[Sequence[int]] = None) -> PackedBatch:
    """Greedy packing of documents into (batch, seq_len) rows.

    ``assignment``: optional per-document row ids from a UDS plan
    (sched/packing.py) — None falls back to first-fit.
    """
    tokens = np.zeros((batch, seq_len), np.int32)
    labels = np.full((batch, seq_len), -100, np.int32)
    segs = np.zeros((batch, seq_len), np.int32)
    fill = np.zeros(batch, np.int64)
    seg_count = np.zeros(batch, np.int32)
    for i, doc in enumerate(docs):
        n = min(len(doc), seq_len)
        if assignment is not None:
            row = int(assignment[i])
            if fill[row] + n > seq_len:
                continue   # dropped by plan overflow (counted in fill)
        else:
            fits = np.where(fill + n <= seq_len)[0]
            if len(fits) == 0:
                continue
            row = int(fits[np.argmin(fill[fits])])
        o = fill[row]
        tokens[row, o:o + n] = doc[:n]
        labels[row, o:o + n - 1] = doc[1:n]
        seg_count[row] += 1
        segs[row, o:o + n] = seg_count[row]
        fill[row] += n
    return PackedBatch(tokens=tokens, labels=labels, segment_ids=segs,
                       fill_fraction=float(fill.sum()) / (batch * seq_len))


def batch_iterator(corpus: SyntheticCorpus, batch: int, seq_len: int,
                   docs_per_batch: Optional[int] = None
                   ) -> Iterator[PackedBatch]:
    """Stream of packed batches (first-fit baseline packing)."""
    it = corpus.documents()
    docs_per_batch = docs_per_batch or batch * 4
    while True:
        docs = [next(it) for _ in range(docs_per_batch)]
        yield pack_documents(docs, batch, seq_len)
