"""Decoder-only transformer covering the dense / MoE / audio / VLM archs.

One implementation, feature-flagged by ``ModelConfig``:
  * GQA attention with optional qk-norm (qwen3), qkv-bias (qwen2 family),
    RoPE / M-RoPE (qwen2-vl) / sinusoidal (musicgen) positions;
  * SwiGLU or GELU MLP, or MoE FFN with UDS-planned capacities;
  * token or stub-frontend (precomputed embeddings) inputs;
  * scan-over-layers with configurable remat for O(1) HLO depth;
  * full train forward, 32k prefill (blockwise attention), KV-cache decode.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.common import (ParamBuilder, _repeat_kv, apply_mrope,
                                 apply_rope, decode_attention,
                                 gather_kv_paged, make_rope, mlp_gelu,
                                 mlp_swiglu, rms_norm, scatter_kv,
                                 scatter_kv_paged, sinusoidal_positions)
from repro.models.moe import moe_ffn
from repro.sharding import constrain, current_rules

__all__ = ["init_params", "forward", "init_cache", "init_batched_cache",
           "decode_step", "batched_decode_step", "fused_decode_steps",
           "insert_prefill", "prefill", "init_paged_cache",
           "paged_decode_step", "fused_paged_decode_steps",
           "prefill_paged_chunk"]

Tree = Dict[str, Any]


# ---------------------------------------------------------------------- init
def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: jnp.dtype = jnp.bfloat16,
                abstract: bool = False) -> Tuple[Tree, Tree]:
    pb = ParamBuilder(key, dtype, abstract=abstract)
    d, hd = cfg.d_model, cfg.head_dim
    L, f = cfg.num_layers, cfg.d_ff
    v = cfg.padded_vocab      # pad so the vocab axis shards evenly (minicpm)

    pb.dense("embed/tok", (v, d), ("vocab", "embed"), scale=1.0)

    # --- per-layer stacked params (leading `layers` axis, consumed by scan)
    pb.dense("layers/attn/wq", (L, d, cfg.q_dim), ("layers", "embed", "heads"))
    pb.dense("layers/attn/wk", (L, d, cfg.kv_dim), ("layers", "embed", "kv"))
    pb.dense("layers/attn/wv", (L, d, cfg.kv_dim), ("layers", "embed", "kv"))
    pb.dense("layers/attn/wo", (L, cfg.q_dim, d), ("layers", "heads", "embed"))
    if cfg.qkv_bias:
        pb.zeros("layers/attn/bq", (L, cfg.q_dim), ("layers", "heads"))
        pb.zeros("layers/attn/bk", (L, cfg.kv_dim), ("layers", "kv"))
        pb.zeros("layers/attn/bv", (L, cfg.kv_dim), ("layers", "kv"))
    if cfg.qk_norm:
        pb.ones("layers/attn/q_norm", (L, hd), ("layers", None))
        pb.ones("layers/attn/k_norm", (L, hd), ("layers", None))
    pb.ones("layers/ln1", (L, d), ("layers", "embed"))
    pb.ones("layers/ln2", (L, d), ("layers", "embed"))

    if cfg.is_moe:
        E = cfg.num_experts
        pb.dense("layers/moe/router", (L, d, E), ("layers", "embed", None))
        pb.dense("layers/moe/w_gate", (L, E, d, f),
                 ("layers", "experts", "embed", "mlp"))
        pb.dense("layers/moe/w_up", (L, E, d, f),
                 ("layers", "experts", "embed", "mlp"))
        pb.dense("layers/moe/w_down", (L, E, f, d),
                 ("layers", "experts", "mlp", "embed"))
    elif cfg.mlp == "swiglu":
        pb.dense("layers/mlp/wi_gate", (L, d, f), ("layers", "embed", "mlp"))
        pb.dense("layers/mlp/wi_up", (L, d, f), ("layers", "embed", "mlp"))
        pb.dense("layers/mlp/wo", (L, f, d), ("layers", "mlp", "embed"))
    else:  # gelu (musicgen)
        pb.dense("layers/mlp/wi", (L, d, f), ("layers", "embed", "mlp"))
        pb.zeros("layers/mlp/bi", (L, f), ("layers", "mlp"))
        pb.dense("layers/mlp/wo", (L, f, d), ("layers", "mlp", "embed"))
        pb.zeros("layers/mlp/bo", (L, d), ("layers", "embed"))

    pb.ones("final_norm", (d,), ("embed",))
    if not cfg.tie_embeddings:
        pb.dense("lm_head", (d, v), ("embed", "vocab"))
    return pb.build()


# ------------------------------------------------------------------- layers
def _head_shards(cfg: ModelConfig) -> int:
    """Product of mesh-axis sizes the act_heads rule maps to (1 if none)."""
    ctx = current_rules()
    if ctx is None:
        return 1
    _, rules, sizes = ctx
    ax = rules.get("act_heads")
    axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return max(n, 1)


def _padded_attention(cfg: ModelConfig, q, k, v, **kw):
    """Attention with the head dim padded to a shardable multiple.

    Archs whose head count doesn't divide the model axis (minicpm 36H,
    qwen2-vl 28H on a 16-way axis) otherwise force GSPMD to replicate the
    per-head score tensors — measured 12.4 TB/chip of block-wise
    all-gathers on minicpm prefill_32k.  Zero-padded heads produce uniform
    softmax outputs that are sliced off before the output projection
    (48/36 = 1.33x attention FLOPs for a ~60x collective reduction).
    """
    from repro.models.common import attention as _attn
    H = q.shape[2]
    n = _head_shards(cfg)
    if n <= 1 or H % n == 0:
        return _attn(q, k, v, **kw)
    Hp = -(-H // n) * n
    kv = k.shape[2]
    while Hp % kv and (Hp // kv) * kv != Hp:   # keep GQA groups integral
        Hp += n
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    if kv == H:                                 # MHA: pad k/v alongside
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    qp = constrain(qp, "batch", None, "act_heads", None)
    out = _attn(qp, k, v, **kw)
    return out[:, :, :H]


def _attn_qkv(lp: Tree, cfg: ModelConfig, h: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = h.shape
    q = jnp.einsum("bsd,dq->bsq", h, lp["attn"]["wq"])
    k = jnp.einsum("bsd,dq->bsq", h, lp["attn"]["wk"])
    v = jnp.einsum("bsd,dq->bsq", h, lp["attn"]["wv"])
    if cfg.qkv_bias:
        q = q + lp["attn"]["bq"]
        k = k + lp["attn"]["bk"]
        v = v + lp["attn"]["bv"]
    q = constrain(q.reshape(B, S, cfg.num_heads, cfg.head_dim),
                  "batch", None, "act_heads", None)
    k = constrain(k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim),
                  "batch", None, "act_kv", None)
    v = constrain(v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim),
                  "batch", None, "act_kv", None)
    if cfg.qk_norm:
        q = rms_norm(q, lp["attn"]["q_norm"])
        k = rms_norm(k, lp["attn"]["k_norm"])
    return q, k, v


def _position_rotate(cfg: ModelConfig, q: jax.Array, k: jax.Array,
                     positions: jax.Array,
                     positions_3d: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    if cfg.positional != "rope":
        return q, k
    if cfg.mrope_sections is not None:
        assert positions_3d is not None, "qwen2-vl requires positions_3d (3,B,S)"
        q = apply_mrope(q, positions_3d, cfg.head_dim, cfg.rope_theta,
                        cfg.mrope_sections)
        k = apply_mrope(k, positions_3d, cfg.head_dim, cfg.rope_theta,
                        cfg.mrope_sections)
        return q, k
    cos, sin = make_rope(positions, cfg.head_dim, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def _layer(cfg: ModelConfig, x: jax.Array, lp: Tree,
           positions: jax.Array, positions_3d: Optional[jax.Array],
           segment_ids: Optional[jax.Array],
           cap_e: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """One decoder block. Returns (x, expert_load or zeros)."""
    x = constrain(x, "batch", None, "act_embed")
    h = rms_norm(x, lp["ln1"])
    q, k, v = _attn_qkv(lp, cfg, h)
    q, k = _position_rotate(cfg, q, k, positions, positions_3d)
    a = _padded_attention(cfg, q, k, v, causal=True, segment_ids=segment_ids,
                          block_q=cfg.attn_block_q,
                          block_kv=cfg.attn_block_kv,
                          flash_threshold=cfg.flash_threshold)
    B, S = x.shape[:2]
    a = constrain(a.reshape(B, S, cfg.q_dim), "batch", None, "act_heads")
    x = x + jnp.einsum("bsq,qd->bsd", a, lp["attn"]["wo"])
    x = constrain(x, "batch", None, "act_embed")

    h = rms_norm(x, lp["ln2"])
    if cfg.is_moe:
        out, load = moe_ffn(h, lp["moe"]["router"], lp["moe"]["w_gate"],
                            lp["moe"]["w_up"], lp["moe"]["w_down"], cfg, cap_e)
    elif cfg.mlp == "swiglu":
        out = mlp_swiglu(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                         lp["mlp"]["wo"])
        load = jnp.zeros((1,), jnp.float32)
    else:
        out = mlp_gelu(h, lp["mlp"]["wi"], lp["mlp"]["bi"],
                       lp["mlp"]["wo"], lp["mlp"]["bo"])
        load = jnp.zeros((1,), jnp.float32)
    return constrain(x + out, "batch", None, "act_embed"), load


# ------------------------------------------------------------------ forward
def _embed_inputs(cfg: ModelConfig, params: Tree, inputs: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Returns (x (B,S,D), positions (B,S) or (S,), positions_3d or None)."""
    if cfg.frontend != "none":
        x = inputs["embeds"].astype(params["embed"]["tok"].dtype)
    else:
        x = params["embed"]["tok"][inputs["tokens"]]
    x = constrain(x, "batch", None, "act_embed")
    B, S = x.shape[:2]
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.positional == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x, positions, inputs.get("positions_3d")


def forward(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            *, remat: str = "full", return_hidden: bool = False,
            cap_e: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Full causal forward. Returns (logits (B,S,V), expert_loads (L,E)|(L,1)).

    ``inputs``: tokens (B,S) int32 | embeds (B,S,D), optional positions,
    positions_3d (3,B,S), segment_ids (B,S) for packed sequences.
    ``remat``: "full" | "none" — activation checkpointing policy of the scan.
    ``return_hidden``: return final-norm hidden states instead of logits
    (the chunked-CE loss path never materializes (B,S,V) logits).
    """
    x, positions, pos3d = _embed_inputs(cfg, params, inputs)
    segment_ids = inputs.get("segment_ids")

    def body(x, lp):
        y, load = _layer(cfg, x, lp, positions, pos3d, segment_ids, cap_e)
        return y, load

    if remat == "full":
        body = jax.checkpoint(body)
    x, loads = jax.lax.scan(body, x, params["layers"])

    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, loads
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)[..., :cfg.vocab_size]
    return logits, loads


# -------------------------------------------------------------------- decode
def cache_dtype(cfg: ModelConfig, default=jnp.bfloat16):
    if cfg.kv_cache_dtype == "fp8":
        return jnp.float8_e4m3fn
    return default


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: jnp.dtype = jnp.bfloat16,
               abstract: bool = False) -> Tuple[Tree, Tree]:
    """KV cache: (L, B, max_len, KV*hd) per k/v + current length scalar.

    The kv-heads dim is stored *flattened* with head_dim so the "kv" logical
    axis shards evenly even when num_kv_heads < model-axis size (grok: 8 kv
    heads on a 16-way axis shard as 1024 = 8·128 columns / 64 per chip).
    ``cfg.kv_cache_dtype="fp8"`` stores the cache in f8e4m3 (half the HBM;
    attention math upcasts on read — the standard serving memory lever).
    """
    dtype = cache_dtype(cfg, dtype)
    shape = (cfg.num_layers, batch, max_len, cfg.kv_dim)
    z = (jax.ShapeDtypeStruct if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    cache = {
        "k": z(shape, dtype),
        "v": z(shape, dtype),
        "len": z((), jnp.int32),
    }
    specs = {
        "k": ("layers", "batch", "seq_cache", "kv"),
        "v": ("layers", "batch", "seq_cache", "kv"),
        "len": (),
    }
    return cache, specs


def init_batched_cache(cfg: ModelConfig, slots: int, max_len: int,
                       dtype: jnp.dtype = jnp.bfloat16,
                       abstract: bool = False) -> Tuple[Tree, Tree]:
    """Stacked serving cache: one ``(L, slots, max_len, KV*hd)`` buffer per
    k/v shared by every decode slot, with a **per-slot** length vector
    ``len (slots,)`` — each slot's sequence has its own fill, so one jitted
    decode call serves all slots at their respective positions (the batched
    ``ServeLoop`` layout; see ``batched_decode_step``)."""
    cache, specs = init_cache(cfg, slots, max_len, dtype, abstract=abstract)
    z = (jax.ShapeDtypeStruct if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    cache["len"] = z((slots,), jnp.int32)
    specs["len"] = ("batch",)
    return cache, specs


def insert_prefill(cache: Tree, pref: Tree, slot: jax.Array) -> Tree:
    """Admission scatter: copy a single-request prefill cache (batch=1,
    same ``max_len``) into row ``slot`` of a stacked batched cache and set
    that slot's fill to the prompt length.  Other slots are untouched, so
    admission composes with in-flight decode on every other slot."""
    k = jax.lax.dynamic_update_index_in_dim(
        cache["k"], pref["k"][:, 0].astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_index_in_dim(
        cache["v"], pref["v"][:, 0].astype(cache["v"].dtype), slot, axis=1)
    ln = jax.lax.dynamic_update_index_in_dim(
        cache["len"], pref["len"].astype(jnp.int32), slot, axis=0)
    return {"k": k, "v": v, "len": ln}


def _decode_forward(params: Tree, cfg: ModelConfig,
                    inputs: Dict[str, jax.Array], cache: Tree,
                    positions: jax.Array, kv_append, attend_len: jax.Array,
                    cap_e: Optional[jax.Array],
                    kv_view=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The one-token decode body shared by per-slot, batched and paged paths.

    The paths differ ONLY in how a layer's new K/V row lands in the
    cache (``kv_append(cache_2d, new_(B,1,kv))``: ``dynamic_update_slice``
    at a scalar length vs a masked per-row scatter vs a block-table paged
    scatter), in how the cache is read back (``kv_view``: identity for the
    dense layouts, a block-table gather for the paged pool), and in the
    position/length values fed to rotary and attention masking — everything
    else (qkv, attention, residual, MLP/MoE, final norm, head) is this one
    function, so the engines cannot drift apart.

    Returns (logits (B, V), new_k, new_v).
    """
    if cfg.frontend != "none":
        x = inputs["embeds"].astype(params["embed"]["tok"].dtype)
    else:
        x = params["embed"]["tok"][inputs["tokens"]]
    B = x.shape[0]
    if cfg.positional == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    pos3d = inputs.get("positions_3d")  # (3,B,1) for qwen2-vl

    def body(x, layer):
        lp, kc, vc = layer                      # kc/vc: (B, S, KV*hd) flat
        h = rms_norm(x, lp["ln1"])
        q, k, v = _attn_qkv(lp, cfg, h)
        q, k = _position_rotate(cfg, q, k, positions, pos3d)
        kc = kv_append(kc, k.reshape(B, 1, cfg.kv_dim))
        vc = kv_append(vc, v.reshape(B, 1, cfg.kv_dim))
        kcv = kv_view(kc) if kv_view is not None else kc
        vcv = kv_view(vc) if kv_view is not None else vc
        S_max = kcv.shape[1]
        a = decode_attention(
            q,
            kcv.reshape(B, S_max, cfg.num_kv_heads, cfg.head_dim
                        ).astype(q.dtype),
            vcv.reshape(B, S_max, cfg.num_kv_heads, cfg.head_dim
                        ).astype(q.dtype),
            attend_len)
        a = a.reshape(B, 1, cfg.q_dim)
        x = x + jnp.einsum("bsq,qd->bsd", a, lp["attn"]["wo"])
        h = rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            out, _ = moe_ffn(h, lp["moe"]["router"], lp["moe"]["w_gate"],
                             lp["moe"]["w_up"], lp["moe"]["w_down"], cfg, cap_e)
        elif cfg.mlp == "swiglu":
            out = mlp_swiglu(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                             lp["mlp"]["wo"])
        else:
            out = mlp_gelu(h, lp["mlp"]["wi"], lp["mlp"]["bi"],
                           lp["mlp"]["wo"], lp["mlp"]["bo"])
        return x + out, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0, :cfg.vocab_size]
    return logits, new_k, new_v


def batched_decode_step(params: Tree, cfg: ModelConfig,
                        inputs: Dict[str, jax.Array], cache: Tree, *,
                        active: Optional[jax.Array] = None,
                        cap_e: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Tree]:
    """One-token decode across every slot of a stacked cache.

    ``cache`` comes from :func:`init_batched_cache`: per-slot lengths
    ``len (B,)``.  ``active (B,) bool`` masks the update: inactive slots
    neither append to their KV rows nor advance their length (their logits
    row is computed but meaningless — the serve loop discards it), so the
    math of every active slot is bit-identical to a batch-1 ``decode_step``
    on that slot's cache — the tested equivalence guarantee.

    Returns (logits (B, V), updated cache).
    """
    cur = cache["len"]                              # (B,) per-slot fill
    B = cur.shape[0]
    active = (jnp.ones((B,), bool) if active is None
              else jnp.asarray(active).astype(bool))
    logits, new_k, new_v = _decode_forward(
        params, cfg, inputs, cache,
        positions=cur[:, None],                     # (B, 1) per-slot
        kv_append=lambda c, new: scatter_kv(c, new, cur, active),
        attend_len=cur + 1,
        cap_e=cap_e)
    new_cache = {"k": new_k, "v": new_v,
                 "len": cur + active.astype(jnp.int32)}
    return logits, new_cache


def fused_decode_steps(params: Tree, cfg: ModelConfig,
                       inputs: Dict[str, jax.Array], cache: Tree, *,
                       num_steps: int,
                       active: Optional[jax.Array] = None,
                       remaining: Optional[jax.Array] = None,
                       eos_id: Optional[jax.Array] = None,
                       cap_e: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, Tree, jax.Array, jax.Array]:
    """Run up to ``num_steps`` greedy decode tokens per slot ON DEVICE.

    One ``lax.scan`` over :func:`batched_decode_step` — ONE dispatch per
    ``num_steps`` tokens instead of one per token, which is the whole
    point: at production rates the Python→XLA round-trip per token is the
    serve bottleneck, not the model math.  The dispatch quantum
    ``num_steps`` is a schedule parameter (``ServeLoop(decode_steps=T)``);
    ``num_steps=1`` is exactly one ``batched_decode_step`` and reproduces
    the stepwise engine token for token (greedy decode is deterministic,
    so any T does).

    Per-slot stop/length handling lives in the loop carry:

    * ``remaining (B,) int32`` — tokens the slot still wants.  A slot
      freezes in place (no KV append, no length bump, no further tokens)
      the step its count hits zero, so slots with fewer than ``num_steps``
      tokens left simply ride out the dispatch frozen.
    * ``eos_id`` — optional scalar; a slot that emits it freezes on the
      next step (the EOS token itself is emitted and counted).
    * cache capacity — a slot whose fill reaches ``max_len`` freezes
      rather than scattering out of bounds (belt-and-braces: admission
      budgets already clamp ``remaining`` to cache capacity).

    Returns ``(tokens (B, num_steps) int32, cache, active, remaining)``.
    Slot ``b``'s real output is the first ``remaining_in[b] -
    remaining_out[b]`` entries of ``tokens[b]``; frozen steps emit -1.
    """
    tok = inputs["tokens"]                          # (B, 1) int32
    B = cache["len"].shape[0]
    max_len = cache["k"].shape[2]
    act = (jnp.ones((B,), bool) if active is None
           else jnp.asarray(active).astype(bool))
    rem = (jnp.full((B,), num_steps, jnp.int32) if remaining is None
           else jnp.asarray(remaining).astype(jnp.int32))
    act = act & (rem > 0)
    eos = (jnp.asarray(-1, jnp.int32) if eos_id is None
           else jnp.asarray(eos_id).astype(jnp.int32))

    def body(carry, _):
        tok, k, v, ln, act, rem = carry
        logits, new_cache = batched_decode_step(
            params, cfg, {"tokens": tok}, {"k": k, "v": v, "len": ln},
            active=act, cap_e=cap_e)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B,)
        emit = jnp.where(act, nxt, -1)
        rem = rem - act.astype(jnp.int32)
        ln = new_cache["len"]
        act = act & (rem > 0) & (nxt != eos) & (ln < max_len)
        # frozen slots keep feeding their old token (never appended again)
        tok = jnp.where(act, nxt, tok[:, 0])[:, None]
        return (tok, new_cache["k"], new_cache["v"], ln, act, rem), emit

    (tok, k, v, ln, act, rem), toks = jax.lax.scan(
        body, (tok, cache["k"], cache["v"], cache["len"], act, rem),
        None, length=num_steps)
    return toks.T, {"k": k, "v": v, "len": ln}, act, rem


# -------------------------------------------------------------- paged KV
def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype: jnp.dtype = jnp.bfloat16,
                     abstract: bool = False) -> Tuple[Tree, Tree]:
    """Paged serving cache: one ``(L, num_blocks, block_size, KV*hd)``
    block pool per k/v, shared by every in-flight request.  There is no
    per-slot ``len`` here: fills and block tables belong to the host-side
    manager (``repro.serve_mem``), which renders tables per dispatch —
    cache *memory* is the scheduled resource, so its bookkeeping lives
    with the scheduler, not the device state."""
    dtype = cache_dtype(cfg, dtype)
    shape = (cfg.num_layers, num_blocks, block_size, cfg.kv_dim)
    z = (jax.ShapeDtypeStruct if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    cache = {"k": z(shape, dtype), "v": z(shape, dtype)}
    specs = {"k": ("layers", None, "seq_cache", "kv"),
             "v": ("layers", None, "seq_cache", "kv")}
    return cache, specs


def paged_decode_step(params: Tree, cfg: ModelConfig,
                      inputs: Dict[str, jax.Array], cache: Tree, *,
                      tables: jax.Array, lengths: jax.Array,
                      active: Optional[jax.Array] = None,
                      cap_e: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Tree, jax.Array]:
    """One-token decode across every row of a paged-KV pool.

    ``tables (B, W)`` int32 maps each row's logical blocks onto pool
    blocks (``-1`` = unassigned); ``lengths (B,)`` is each row's fill.
    The body is the same :func:`_decode_forward` as the dense engines —
    only the append (block-table scatter) and the cache read (block-table
    gather to a ``(B, W*BS, C)`` view) differ, so an active row's math is
    identical to :func:`batched_decode_step` over a dense ``max_len =
    W*BS`` cache holding the same sequence — the paged-vs-dense
    equivalence guarantee.

    Returns (logits (B, V), updated cache, updated lengths).
    """
    cur = jnp.asarray(lengths, jnp.int32)
    B = cur.shape[0]
    active = (jnp.ones((B,), bool) if active is None
              else jnp.asarray(active).astype(bool))
    logits, new_k, new_v = _decode_forward(
        params, cfg, inputs, cache,
        positions=cur[:, None],
        kv_append=lambda c, new: scatter_kv_paged(c, new, cur, active,
                                                  tables),
        attend_len=cur + 1,
        cap_e=cap_e,
        kv_view=lambda c: gather_kv_paged(c, tables))
    return logits, {"k": new_k, "v": new_v}, cur + active.astype(jnp.int32)


def fused_paged_decode_steps(params: Tree, cfg: ModelConfig,
                             inputs: Dict[str, jax.Array], cache: Tree, *,
                             num_steps: int, tables: jax.Array,
                             lengths: jax.Array, limits: jax.Array,
                             active: Optional[jax.Array] = None,
                             remaining: Optional[jax.Array] = None,
                             eos_id: Optional[jax.Array] = None,
                             cap_e: Optional[jax.Array] = None
                             ) -> Tuple[jax.Array, Tree, jax.Array,
                                        jax.Array, jax.Array]:
    """Run up to ``num_steps`` greedy tokens per row through the paged
    pool ON DEVICE — the paged twin of :func:`fused_decode_steps`.

    Block tables are fixed for the duration of a dispatch (the host
    allocates before dispatching); ``limits (B,)`` is each row's
    currently-covered capacity in tokens (``allocated_blocks * BS``) —
    a row whose fill reaches its limit freezes in place rather than
    scattering into a block it does not own, which is the memory-pressure
    edge the serve loop turns into a preemption decision.  Budget and EOS
    freezes behave exactly as in the dense fused engine.

    Returns ``(tokens (B, num_steps), cache, lengths, active,
    remaining)``; frozen steps emit -1.
    """
    tok = inputs["tokens"]                          # (B, 1) int32
    ln = jnp.asarray(lengths, jnp.int32)
    B = ln.shape[0]
    limits = jnp.asarray(limits, jnp.int32)
    act = (jnp.ones((B,), bool) if active is None
           else jnp.asarray(active).astype(bool))
    rem = (jnp.full((B,), num_steps, jnp.int32) if remaining is None
           else jnp.asarray(remaining).astype(jnp.int32))
    act = act & (rem > 0) & (ln < limits)
    eos = (jnp.asarray(-1, jnp.int32) if eos_id is None
           else jnp.asarray(eos_id).astype(jnp.int32))

    def body(carry, _):
        tok, k, v, ln, act, rem = carry
        logits, new_cache, new_ln = paged_decode_step(
            params, cfg, {"tokens": tok}, {"k": k, "v": v},
            tables=tables, lengths=ln, active=act, cap_e=cap_e)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B,)
        emit = jnp.where(act, nxt, -1)
        rem = rem - act.astype(jnp.int32)
        act = act & (rem > 0) & (nxt != eos) & (new_ln < limits)
        tok = jnp.where(act, nxt, tok[:, 0])[:, None]
        return (tok, new_cache["k"], new_cache["v"], new_ln, act, rem), emit

    (tok, k, v, ln, act, rem), toks = jax.lax.scan(
        body, (tok, cache["k"], cache["v"], ln, act, rem),
        None, length=num_steps)
    return toks.T, {"k": k, "v": v}, ln, act, rem


def prefill_paged_chunk(params: Tree, cfg: ModelConfig,
                        inputs: Dict[str, jax.Array], cache: Tree, *,
                        tables: jax.Array, start: jax.Array,
                        length: jax.Array,
                        cap_e: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Tree]:
    """Process ONE chunk of one request's prompt through the paged cache.

    This is what makes prefill schedulable: instead of one monolithic
    prompt pass, the serve loop feeds bucket-padded chunks —
    ``inputs["tokens"] (1, Cb)`` holding ``length`` real tokens — and
    interleaves them with decode dispatches.  The chunk's queries attend
    the request's already-cached prefix (``start`` tokens, gathered from
    the pool through ``tables (W,)``) plus themselves causally, exactly
    the keys the full prefill would have seen, and the chunk's rotated
    K/V are scattered into the request's blocks at positions
    ``start .. start+length-1`` (pad positions are dropped, never
    written).  ``length``/``start`` are traced scalars, so one compile
    serves every chunk of a given padded width ``Cb`` — the
    one-compile-per-bucket guarantee carries over from dense prefill.

    Returns (logits (1, V) at the chunk's last real position, updated
    cache) — the logits only matter for the final chunk of a prompt,
    where they produce the request's first generated token.
    """
    start = jnp.asarray(start, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    Cb = inputs["tokens"].shape[1]
    positions = start + jnp.arange(Cb, dtype=jnp.int32)[None, :]  # (1, Cb)
    x, positions, pos3d = _embed_inputs(
        cfg, params, dict(inputs, positions=positions))
    B = x.shape[0]
    W = tables.shape[-1]
    BS = cache["k"].shape[2]
    S_past = W * BS
    tab_b = jnp.broadcast_to(jnp.asarray(tables, jnp.int32)[None, :],
                             (1, W))
    # key validity: past pool positions are real iff below the fill at
    # chunk start; chunk positions attend causally within the chunk
    past_ok = jnp.arange(S_past)[None, :] < start            # (1, S_past)
    tri = (jnp.arange(Cb)[:, None] >= jnp.arange(Cb)[None, :])
    mask = jnp.concatenate(
        [jnp.broadcast_to(past_ok, (Cb, S_past)), tri], axis=1)
    mask = mask[None, None]                                  # (1,1,Cb,S)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    chunk_pos = start + jnp.arange(Cb, dtype=jnp.int32)      # (Cb,)

    def body(x, layer):
        lp, kc, vc = layer                      # kc/vc: (NB, BS, C)
        h = rms_norm(x, lp["ln1"])
        q, k, v = _attn_qkv(lp, cfg, h)
        q, k = _position_rotate(cfg, q, k, positions, pos3d)
        past_k = gather_kv_paged(kc, tab_b)     # (1, S_past, C)
        past_v = gather_kv_paged(vc, tab_b)
        keys = jnp.concatenate(
            [past_k.reshape(B, S_past, cfg.num_kv_heads, cfg.head_dim
                            ).astype(q.dtype), k], axis=1)
        vals = jnp.concatenate(
            [past_v.reshape(B, S_past, cfg.num_kv_heads, cfg.head_dim
                            ).astype(q.dtype), v], axis=1)
        groups = q.shape[2] // keys.shape[2]
        kk = _repeat_kv(keys, groups)
        vv = _repeat_kv(vals, groups)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, -1e30)
        probs = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        a = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
        a = a.reshape(B, Cb, cfg.q_dim)
        x = x + jnp.einsum("bsq,qd->bsd", a, lp["attn"]["wo"])
        h = rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            out, _ = moe_ffn(h, lp["moe"]["router"], lp["moe"]["w_gate"],
                             lp["moe"]["w_up"], lp["moe"]["w_down"], cfg,
                             cap_e)
        elif cfg.mlp == "swiglu":
            out = mlp_swiglu(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                             lp["mlp"]["wo"])
        else:
            out = mlp_gelu(h, lp["mlp"]["wi"], lp["mlp"]["bi"],
                           lp["mlp"]["wo"], lp["mlp"]["bo"])
        # scatter the chunk's ROTATED keys (decode appends rotated keys
        # too) into the request's blocks; positions >= length are pad and
        # dropped.  Each chunk position is its own "row" of the scatter.
        write_ok = jnp.arange(Cb) < length
        kc = scatter_kv_paged(
            kc, k.reshape(Cb, 1, cfg.kv_dim), chunk_pos, write_ok,
            jnp.broadcast_to(tables, (Cb, W)))
        vc = scatter_kv_paged(
            vc, v.reshape(Cb, 1, cfg.kv_dim), chunk_pos, write_ok,
            jnp.broadcast_to(tables, (Cb, W)))
        return x + out, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])
    x_last = jax.lax.dynamic_index_in_dim(x, length - 1, axis=1,
                                          keepdims=False)
    logits = jnp.einsum("bd,dv->bv", x_last, head)[:, :cfg.vocab_size]
    return logits, {"k": ks, "v": vs}


def decode_step(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                cache: Tree, *, cap_e: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Tree]:
    """One-token decode: inputs token (B,1) (or embeds (B,1,D)); returns
    (logits (B,V), updated cache).  ``cache["len"]`` is a scalar shared by
    every row (see :func:`init_cache`)."""
    cur = cache["len"]
    B = (inputs["embeds"] if cfg.frontend != "none"
         else inputs["tokens"]).shape[0]
    logits, new_k, new_v = _decode_forward(
        params, cfg, inputs, cache,
        positions=jnp.full((B, 1), cur, dtype=jnp.int32),
        kv_append=lambda c, new: jax.lax.dynamic_update_slice_in_dim(
            c, new.astype(c.dtype), cur, axis=1),
        attend_len=cur + 1,
        cap_e=cap_e)
    new_cache = {"k": new_k, "v": new_v, "len": cur + 1}
    return logits, new_cache


def prefill(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            max_len: Optional[int] = None,
            *, remat: str = "full",
            length: Optional[jax.Array] = None,
            cap_e: Optional[jax.Array] = None) -> Tuple[jax.Array, Tree]:
    """Process a full prompt, building the KV cache; returns
    (last-position logits (B,V), cache).

    ``length`` (scalar, traced) marks the REAL prompt length inside a
    right-padded ``tokens`` buffer: logits are read at position
    ``length - 1`` and the cache fill is set to ``length``, not the padded
    width.  Under causal masking positions < length never attend the pad
    tail, so a prompt padded to a shared length bucket produces the same
    prefix math — the lever that lets serving compile ONE prefill per
    bucket instead of one per distinct prompt length.  Pad positions do
    land garbage K/V in the cache, but decode overwrites them in order
    (appends happen exactly at ``len``, ``len+1``, …) and attention masks
    everything at or beyond the current fill, so they are never read."""
    x, positions, pos3d = _embed_inputs(cfg, params, inputs)
    B, S = x.shape[:2]
    max_len = max_len or S
    segment_ids = inputs.get("segment_ids")

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        q, k, v = _attn_qkv(lp, cfg, h)
        qr, kr = _position_rotate(cfg, q, k, positions, pos3d)
        a = _padded_attention(cfg, qr, kr, v, causal=True,
                              segment_ids=segment_ids,
                              block_q=cfg.attn_block_q,
                              block_kv=cfg.attn_block_kv,
                              flash_threshold=cfg.flash_threshold)
        a = a.reshape(B, S, cfg.q_dim)
        x = x + jnp.einsum("bsq,qd->bsd", a, lp["attn"]["wo"])
        h = rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            out, _ = moe_ffn(h, lp["moe"]["router"], lp["moe"]["w_gate"],
                             lp["moe"]["w_up"], lp["moe"]["w_down"], cfg, cap_e)
        elif cfg.mlp == "swiglu":
            out = mlp_swiglu(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                             lp["mlp"]["wo"])
        else:
            out = mlp_gelu(h, lp["mlp"]["wi"], lp["mlp"]["bi"],
                           lp["mlp"]["wo"], lp["mlp"]["bo"])
        # cache stores *rotated* keys (decode appends rotated keys too),
        # flattened to (B, S, KV*hd) — see init_cache
        return x + out, (kr.reshape(B, S, cfg.kv_dim),
                         v.reshape(B, S, cfg.kv_dim))

    if remat == "full":
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])

    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ks = ks.astype(cache_dtype(cfg, ks.dtype))
    vs = vs.astype(cache_dtype(cfg, vs.dtype))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])
    if length is None:
        x_last = x[:, -1]
        fill = jnp.asarray(S, jnp.int32)
    else:
        fill = jnp.asarray(length, jnp.int32)
        x_last = jax.lax.dynamic_index_in_dim(x, fill - 1, axis=1,
                                              keepdims=False)
    logits = jnp.einsum("bd,dv->bv", x_last, head)[:, :cfg.vocab_size]
    cache = {"k": ks, "v": vs, "len": fill}
    return logits, cache
