"""Shared model components: norms, positional embeddings, attention, MLPs.

Conventions
-----------
* Parameters are nested dicts of ``jnp`` arrays.  Every init function returns
  ``(params, specs)`` where ``specs`` mirrors the tree with tuples of
  *logical axis names* — the sharding layer maps logical axes to mesh axes
  through a rule table (MaxText-style), which is the hillclimb lever.
* Layer-stacked params carry a leading ``layers`` axis and are consumed with
  ``jax.lax.scan`` (keeps HLO size O(1) in depth — essential for compiling
  94-layer configs on the dry-run host).
* Attention switches to a blockwise (flash) implementation above
  ``cfg.flash_threshold`` so 32k-token prefill fits compile-time memory;
  the Pallas kernel in ``repro/kernels/flash_attention`` is the TPU-optimized
  twin of the same algorithm (same oracle).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import constrain

__all__ = [
    "ParamBuilder", "rms_norm", "make_rope", "apply_rope", "apply_mrope",
    "sinusoidal_positions", "attention", "blockwise_attention", "mlp_swiglu",
    "mlp_gelu", "decode_attention", "scatter_kv", "gather_kv_paged",
    "scatter_kv_paged", "paged_decode_attention",
]

Tree = Dict[str, Any]


class ParamBuilder:
    """Builds a (params, specs) pair with matching structure.

    ``abstract=True`` emits ShapeDtypeStructs instead of arrays — the
    allocation-free init used by the multi-pod dry-run (full configs are
    never materialized on the CPU host).
    """

    def __init__(self, key: jax.Array, dtype: jnp.dtype = jnp.float32,
                 abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Tree = {}
        self.specs: Tree = {}

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, path: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              scale: Optional[float] = None, zeros: bool = False) -> None:
        """He/Glorot-ish init: normal(0, scale), scale defaults 1/sqrt(fan_in)."""
        if len(shape) != len(axes):
            raise ValueError(f"{path}: shape {shape} vs axes {axes}")
        if self.abstract:
            arr = jax.ShapeDtypeStruct(shape, self.dtype)
        elif zeros:
            arr = jnp.zeros(shape, self.dtype)
        else:
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(self._next(), shape, jnp.float32)
                   * scale).astype(self.dtype)
        self._set(path, arr, tuple(axes))

    def ones(self, path: str, shape: Tuple[int, ...],
             axes: Tuple[Optional[str], ...]) -> None:
        if self.abstract:
            self._set(path, jax.ShapeDtypeStruct(shape, self.dtype), tuple(axes))
            return
        self._set(path, jnp.ones(shape, self.dtype), tuple(axes))

    def zeros(self, path: str, shape: Tuple[int, ...],
              axes: Tuple[Optional[str], ...]) -> None:
        if self.abstract:
            self._set(path, jax.ShapeDtypeStruct(shape, self.dtype), tuple(axes))
            return
        self._set(path, jnp.zeros(shape, self.dtype), tuple(axes))

    def const(self, path: str, arr: jax.Array,
              axes: Tuple[Optional[str], ...]) -> None:
        if self.abstract:
            self._set(path, jax.ShapeDtypeStruct(arr.shape, self.dtype),
                      tuple(axes))
            return
        self._set(path, arr.astype(self.dtype), tuple(axes))

    def _set(self, path: str, arr: jax.Array, spec: Tuple) -> None:
        parts = path.split("/")
        p, s = self.params, self.specs
        for part in parts[:-1]:
            p = p.setdefault(part, {})
            s = s.setdefault(part, {})
        p[parts[-1]] = arr
        s[parts[-1]] = spec

    def build(self) -> Tuple[Tree, Tree]:
        return self.params, self.specs


# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- positions
def make_rope(positions: jax.Array, head_dim: int, theta: float
              ) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, head_dim: int,
                theta: float, sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL M-RoPE: the rotary half-dim is split into (t, h, w) sections,
    each rotated by its own position stream.  positions_3d: (3, B, S)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang_tbw = positions_3d.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    sec_ids = np.repeat(np.arange(3), sections)                    # (half,)
    # select, per rotary dim j, the position stream sections[j] belongs to
    sel = jax.nn.one_hot(jnp.asarray(sec_ids), 3, dtype=jnp.float32)  # (half,3)
    ang = jnp.einsum("tbsh,ht->bsh", ang_tbw, sel)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return apply_rope(x, cos, sin)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """MusicGen-style sinusoidal embeddings. positions: (S,) or (B,S)."""
    half = d_model // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------- attention
def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) by repeat (GQA)."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)
                            ).reshape(b, s, kv * groups, hd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              *, causal: bool = True,
              segment_ids: Optional[jax.Array] = None,
              block_q: int = 512, block_kv: int = 1024,
              flash_threshold: int = 8192) -> jax.Array:
    """Multi-head attention, GQA-aware.

    q: (B, S, H, hd); k/v: (B, T, KV, hd).  Dispatches to the blockwise
    (flash) path for long sequences; both paths share the same semantics and
    are cross-checked in tests (and against kernels/flash_attention/ref.py).
    """
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if q.shape[1] >= flash_threshold:
        return blockwise_attention(q, k, v, causal=causal,
                                   segment_ids=segment_ids,
                                   block_q=block_q, block_kv=block_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = constrain(logits, "batch", "act_heads", None, None)
    mask = None
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        offs = sk - sq  # allow cached prefixes
        mask = (jnp.arange(sq)[:, None] + offs) >= jnp.arange(sk)[None, :]
    if segment_ids is not None:
        seg_mask = (segment_ids[:, :, None] == segment_ids[:, None, :])[:, None]
        mask = seg_mask if mask is None else (mask[None, None] & seg_mask)
    elif mask is not None:
        mask = mask[None, None]
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        segment_ids: Optional[jax.Array] = None,
                        block_q: int = 512, block_kv: int = 1024) -> jax.Array:
    """Memory-O(S·block) flash attention in pure JAX (online softmax over KV
    blocks, scanned over Q blocks).  This is the compile-memory-safe path for
    prefill_32k and the oracle for the Pallas kernel."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nq = -(-sq // block_q)
    pad_q = nq * block_q - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nk = -(-sk // block_kv)
    pad_k = nk * block_kv - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if segment_ids is not None:
        seg_q = jnp.pad(segment_ids, ((0, 0), (0, pad_q)), constant_values=-1)
        seg_k = jnp.pad(segment_ids, ((0, 0), (0, pad_k)), constant_values=-2)
        seg_qb = seg_q.reshape(b, nq, block_q)
        seg_kb = seg_k.reshape(b, nk, block_kv)

    qb = q.reshape(b, nq, block_q, h, hd)
    kb = k.reshape(b, nk, block_kv, h, hd)
    vb = v.reshape(b, nk, block_kv, h, hd)
    offs = sk - sq  # query i attends keys <= i + offs

    def q_step(_, qi):
        qblk, qidx = qi  # (b, block_q, h, hd), scalar block index
        q_pos = qidx * block_q + jnp.arange(block_q) + offs

        def kv_step(carry, ki):
            acc, m, l = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = constrain(s, "batch", "act_heads", None, None)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
            mask = jnp.broadcast_to(mask[None, None], s.shape)
            if segment_ids is not None:
                sm = (seg_qb[:, qidx][:, :, None] == seg_kb[:, kidx][:, None, :])
                mask = mask & sm[:, None]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        m0 = jnp.full((b, h, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        if causal:
            # skip fully-masked KV blocks: last kv block index needed
            last = jnp.minimum(
                (qidx * block_q + block_q - 1 + offs) // block_kv, nk - 1)
        else:
            last = nk - 1
        # lax.scan over all nk blocks; masked blocks contribute exp(-inf)=0,
        # which is exact.  (The Pallas kernel *skips* them — perf only.)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).astype(q.dtype)  # (b, h, block_q, hd)
        return None, jnp.einsum("bhqd->bqhd", out)

    _, out = jax.lax.scan(q_step, None,
                          (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * block_q, h, hd)
    return out[:, :sq]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array) -> jax.Array:
    """Single-token decode: q (B, 1, H, hd) vs cache (B, S, KV, hd); positions
    >= cur_len are masked out.  ``cur_len`` is a scalar shared by every row
    or a (B,) vector of per-row lengths (the batched serving cache, where
    each slot's sequence has its own fill)."""
    groups = q.shape[2] // k_cache.shape[2]
    k = _repeat_kv(k_cache, groups)
    v = _repeat_kv(v_cache, groups)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    cur_len = jnp.reshape(cur_len, (-1, 1, 1, 1))   # () -> (1,..); (B,) -> (B,..)
    valid = jnp.arange(k.shape[1])[None, None, None, :] < cur_len
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def scatter_kv(cache: jax.Array, new: jax.Array, cur: jax.Array,
               active: jax.Array) -> jax.Array:
    """Masked per-row KV append: write ``new`` (B, 1, C) into ``cache``
    (B, S, C) at position ``cur[b]`` for every row with ``active[b]``;
    inactive rows (and every other position) pass through untouched.

    This is the batched-decode twin of ``dynamic_update_slice_in_dim``: each
    slot of a stacked serving cache appends at its *own* sequence position.
    """
    S = cache.shape[1]
    hit = (jnp.arange(S)[None, :] == jnp.reshape(cur, (-1, 1)))   # (B, S)
    hit = hit & jnp.reshape(active, (-1, 1))
    return jnp.where(hit[..., None], new.astype(cache.dtype), cache)


# ------------------------------------------------------------- paged KV
def gather_kv_paged(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Materialize per-request KV views from a paged pool.

    ``pool`` is one layer's block store ``(NB, BS, C)`` — ``NB`` blocks
    of ``BS`` token positions each; ``tables (B, W)`` int32 maps request
    ``b``'s logical block ``w`` (token positions ``[w*BS, (w+1)*BS)``)
    onto a pool block, ``-1`` padding unassigned entries.  Returns the
    dense view ``(B, W*BS, C)`` — identical in shape and content (at
    every position below the request's fill) to the stacked dense
    cache's row, so the attention math downstream is the same function.
    Unassigned/garbage entries are gathered from block 0 and must be
    masked by the caller's length masking, exactly like the dense
    cache's unwritten tail.
    """
    B, W = tables.shape
    _, BS, C = pool.shape
    got = jnp.take(pool, jnp.clip(tables, 0), axis=0)    # (B, W, BS, C)
    return got.reshape(B, W * BS, C)


def scatter_kv_paged(pool: jax.Array, new: jax.Array, cur: jax.Array,
                     active: jax.Array, tables: jax.Array) -> jax.Array:
    """Masked per-request KV append into a paged pool.

    The paged twin of :func:`scatter_kv`: write ``new (B, 1, C)`` at
    request ``b``'s logical position ``cur[b]`` — pool block
    ``tables[b, cur[b] // BS]``, offset ``cur[b] % BS`` — for every row
    with ``active[b]``.  Inactive rows, rows whose position falls on an
    unassigned (``-1``) table entry, and rows past their table's width
    are dropped via an out-of-bounds index (XLA ``mode="drop"``), so a
    frozen or unallocated slot can never corrupt a live block.
    """
    NB, BS, _ = pool.shape
    B, W = tables.shape
    cur = jnp.asarray(cur, jnp.int32)
    widx = jnp.clip(cur // BS, 0, W - 1)
    blk = jnp.take_along_axis(tables, widx[:, None], axis=1)[:, 0]
    ok = (jnp.asarray(active).astype(bool) & (blk >= 0)
          & (cur < W * BS))
    blk = jnp.where(ok, blk, NB)                 # OOB -> dropped write
    return pool.at[blk, cur % BS].set(new[:, 0].astype(pool.dtype),
                                      mode="drop")


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, tables: jax.Array,
                           cur_len: jax.Array) -> jax.Array:
    """Single-token decode attention reading K/V through block tables.

    ``q (B, 1, H, hd)`` against one layer's paged pools ``(NB, BS, C)``
    where ``C = KV*hd``: the per-request views are gathered
    (:func:`gather_kv_paged`) and fed to the one true
    :func:`decode_attention` with per-row length masking — positions at
    or beyond ``cur_len[b]`` (including every gathered garbage entry)
    are masked, so the result equals dense decode attention over a
    ``max_len = W*BS`` cache row holding the same sequence.
    """
    B = q.shape[0]
    hd = q.shape[-1]
    k = gather_kv_paged(k_pool, tables)          # (B, W*BS, C)
    v = gather_kv_paged(v_pool, tables)
    S = k.shape[1]
    kv_heads = k.shape[-1] // hd
    return decode_attention(
        q, k.reshape(B, S, kv_heads, hd).astype(q.dtype),
        v.reshape(B, S, kv_heads, hd).astype(q.dtype), cur_len)


# ----------------------------------------------------------------- MLPs
def mlp_swiglu(x: jax.Array, wi_gate: jax.Array, wi_up: jax.Array,
               wo: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, wi_gate)
    u = jnp.einsum("bsd,df->bsf", x, wi_up)
    g = constrain(g, "batch", None, "act_mlp")
    u = constrain(u, "batch", None, "act_mlp")
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, wo)


def mlp_gelu(x: jax.Array, wi: jax.Array, bi: jax.Array,
             wo: jax.Array, bo: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi) + bi)
    h = constrain(h, "batch", None, "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, wo) + bo
