"""Chunked linear-attention scan — shared math for RWKV6 (vector decay) and
Mamba2/SSD (scalar-per-head decay).

Recurrence (per head; dk = key dim, dv = value dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S: (dk, dv), w_t in (0,1]
    y_t = q_t S_t                  (inclusive, Mamba2)
    y_t = q_t (S_{t-1} + diag(u) k_t v_t^T)      (exclusive + bonus, RWKV6)

The chunked form processes C timesteps at once: O(T·C·dk·dv) work like the
sequential scan, but MXU-friendly matmuls instead of T outer products.

Numerical safety: every exponential here is of a *non-positive* number
(sums of log-decays between two timesteps), so nothing overflows — unlike
the common factored form q̃=q·exp(A), k̃=k·exp(−A) whose exp(−A) explodes for
strong decay.  This is the formulation the Pallas kernel implements on TPU
(kernels/linear_scan), with this module as its oracle.

The chunk size is a UDS-schedulable parameter (cfg.scan_chunk): the paper's
"chunk" — grouping iterations (timesteps) into scheduling items.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_linear_attention", "linear_attention_step"]


def chunked_linear_attention(
    q: jax.Array,            # (B, H, T, dk)
    k: jax.Array,            # (B, H, T, dk)
    v: jax.Array,            # (B, H, T, dv)
    log_w: jax.Array,        # (B, H, T, dk) vector decay or (B, H, T) scalar
    *,
    u: Optional[jax.Array] = None,   # (H, dk) bonus (RWKV6); implies exclusive
    inclusive: bool = True,
    chunk: int = 32,
    initial_state: Optional[jax.Array] = None,  # (B, H, dk, dv)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,H,T,dv), final_state (B,H,dk,dv)).  Computed in f32."""
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    scalar_decay = (log_w.ndim == 3)
    if u is not None and inclusive:
        raise ValueError("bonus-u form is exclusive by definition (RWKV6)")

    orig_T = T
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        padw = ((0, 0), (0, 0), (0, pad)) + (((0, 0),) if not scalar_decay else ())
        log_w = jnp.pad(log_w, padw)
        T += pad
    nc = T // chunk

    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, H, nc, chunk, dk)
    kc = k.astype(f32).reshape(B, H, nc, chunk, dk)
    vc = v.astype(f32).reshape(B, H, nc, chunk, dv)
    if scalar_decay:
        lw = log_w.astype(f32).reshape(B, H, nc, chunk)
    else:
        lw = log_w.astype(f32).reshape(B, H, nc, chunk, dk)

    if initial_state is None:
        S0 = jnp.zeros((B, H, dk, dv), f32)
    else:
        S0 = initial_state.astype(f32)

    tri_incl = jnp.tril(jnp.ones((chunk, chunk), bool))            # τ <= t
    tri_excl = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)      # τ <  t

    def step(S, inp):
        if scalar_decay:
            qb, kb, vb, lwb = inp                                  # lwb (B,H,C)
            Ai = jnp.cumsum(lwb, axis=-1)                          # inclusive
            Ae = Ai - lwb                                          # exclusive
            q_dec = Ai if inclusive else Ae
            # inter-chunk: y += (q ⊙ exp(A)) @ S
            y = jnp.einsum("bhtn,bhnv->bhtv", qb * jnp.exp(q_dec)[..., None], S)
            # intra-chunk (decay uniform over dk -> factorizable)
            gap = (q_dec[..., :, None] - Ai[..., None, :])         # (B,H,C,C)
            mask = tri_incl if inclusive else tri_excl
            M = jnp.where(mask, jnp.exp(jnp.where(mask, gap, 0.0)), 0.0)
            scores = jnp.einsum("bhtn,bhsn->bhts", qb, kb) * M
            y = y + jnp.einsum("bhts,bhsv->bhtv", scores, vb)
            # state update
            Alast = Ai[..., -1:]
            kdec = kb * jnp.exp(Alast - Ai)[..., None]
            S = S * jnp.exp(Ai[..., -1])[..., None, None]
            S = S + jnp.einsum("bhtn,bhtv->bhnv", kdec, vb)
        else:
            qb, kb, vb, lwb = inp                                  # lwb (B,H,C,dk)
            Ai = jnp.cumsum(lwb, axis=-2)
            Ae = Ai - lwb
            q_dec = Ai if inclusive else Ae
            y = jnp.einsum("bhtn,bhnv->bhtv", qb * jnp.exp(q_dec), S)
            mask = tri_incl if inclusive else tri_excl
            gap = q_dec[..., :, None, :] - Ai[..., None, :, :]     # (B,H,C,C,dk)
            M = jnp.where(mask[..., None],
                          jnp.exp(jnp.where(mask[..., None], gap, 0.0)), 0.0)
            scores = jnp.einsum("bhtn,bhsn,bhtsn->bhts", qb, kb, M)
            y = y + jnp.einsum("bhts,bhsv->bhtv", scores, vb)
            Alast = Ai[..., -1:, :]
            kdec = kb * jnp.exp(Alast - Ai)
            S = S * jnp.exp(Alast[..., 0, :])[..., None] \
                + jnp.einsum("bhtn,bhtv->bhnv", kdec, vb)
        if u is not None:
            # bonus diagonal: y_t += ((q_t ⊙ u) · k_t) v_t
            y = y + (qb * u[None, :, None, :] * kb).sum(-1, keepdims=True) * vb
        return S, y

    import os
    if not os.environ.get("REPRO_NO_INNER_REMAT"):   # baseline knob (§Perf)
        # recompute the (C,C,·) decay/score tensors in bwd: without this the
        # outer layer-remat saves them stacked over ALL chunks (measured
        # 66 TB/chip of traffic + 10 GB of stacks on rwkv6 train_4k)
        step = jax.checkpoint(step)
    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(lw, 2, 0))
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, dv)[:, :, :orig_T]
    return y.astype(v.dtype), S


def linear_attention_step(
    q: jax.Array,            # (B, H, dk)
    k: jax.Array,            # (B, H, dk)
    v: jax.Array,            # (B, H, dv)
    log_w: jax.Array,        # (B, H, dk) or (B, H)
    S: jax.Array,            # (B, H, dk, dv)
    *,
    u: Optional[jax.Array] = None,   # (H, dk)
    inclusive: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent step (decode path). Returns (y (B,H,dv), S')."""
    f32 = jnp.float32
    out_dtype = v.dtype
    q, k, v, S = q.astype(f32), k.astype(f32), v.astype(f32), S.astype(f32)
    w = jnp.exp(log_w.astype(f32))
    if log_w.ndim == 2:
        w = w[..., None]
    kv = jnp.einsum("bhn,bhv->bhnv", k, v)
    S_new = S * w[..., None] + kv
    if u is not None:
        y = jnp.einsum("bhn,bhnv->bhv", q, S + u[None, :, :, None] * kv)
    elif inclusive:
        y = jnp.einsum("bhn,bhnv->bhv", q, S_new)
    else:
        y = jnp.einsum("bhn,bhnv->bhv", q, S)
    return y.astype(out_dtype), S_new
