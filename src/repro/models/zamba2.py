"""Zamba2 — Mamba2 backbone with a shared attention block (arXiv:2411.15242).

54 Mamba2 (SSD) layers; after every 6th layer, ONE shared transformer block
(weights reused across all 9 applications) runs on concat(hidden, embedding)
(2·d_model wide), projecting back to d_model — the Zamba "shared attention"
design that amortizes attention parameters over an SSM backbone.
Simplification noted in DESIGN.md: the per-application LoRA deltas on the
shared block are omitted; one shared block instead of Zamba2's two.

Mamba2/SSD per layer: in_proj -> (z, x, B, C, dt); causal depthwise conv on
(x,B,C); h_t = exp(-exp(A)·dt_t)·h_{t-1} + dt_t·x_t⊗B_t; y = C_t·h_t + D·x_t;
out = out_proj(RMSNorm(y)·silu(z)).  The scan is the shared chunked
linear-attention (scalar-per-head decay path) — same oracle as the Pallas
kernel.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.common import (
    ParamBuilder, apply_rope, attention, decode_attention, make_rope,
    mlp_swiglu, rms_norm,
)
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step
from repro.sharding import constrain

__all__ = ["init_params", "forward", "init_state", "decode_step",
           "prefill", "mamba_dims"]

Tree = Dict[str, Any]
EXPAND = 2
MAMBA_HEAD = 64


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(d_inner, n_heads, d_state)."""
    din = EXPAND * cfg.d_model
    return din, din // MAMBA_HEAD, cfg.ssm_state


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: jnp.dtype = jnp.bfloat16,
                abstract: bool = False) -> Tuple[Tree, Tree]:
    pb = ParamBuilder(key, dtype, abstract=abstract)
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    din, nh, N = mamba_dims(cfg)
    K = cfg.conv_kernel
    every = cfg.shared_attention_every
    assert every and L % every == 0, "num_layers must divide shared_attention_every"

    pb.dense("embed/tok", (v, d), ("vocab", "embed"), scale=1.0)

    # Mamba2 layers (stacked over all L)
    proj_out = 2 * din + 2 * N + nh                  # z, x, B, C, dt
    pb.dense("layers/m/in_proj", (L, d, proj_out), ("layers", "embed", "heads"))
    pb.dense("layers/m/conv_w", (L, K, din + 2 * N), ("layers", None, "heads"),
             scale=1.0 / math.sqrt(K))
    pb.zeros("layers/m/conv_b", (L, din + 2 * N), ("layers", "heads"))
    pb.const("layers/m/A_log",
             jnp.tile(jnp.log(jnp.linspace(1.0, 16.0, nh))[None], (L, 1)),
             ("layers", "heads"))
    pb.ones("layers/m/D", (L, nh), ("layers", "heads"))
    pb.zeros("layers/m/dt_bias", (L, nh), ("layers", "heads"))
    pb.dense("layers/m/out_proj", (L, din, d), ("layers", "heads", "embed"))
    pb.ones("layers/m/norm", (L, din), ("layers", "heads"))
    pb.ones("layers/ln", (L, d), ("layers", "embed"))

    # ONE shared attention+MLP block on concat(hidden, embed) (2d wide)
    pb.dense("shared/wq", (2 * d, cfg.q_dim), ("embed", "heads"))
    pb.dense("shared/wk", (2 * d, cfg.kv_dim), ("embed", "kv"))
    pb.dense("shared/wv", (2 * d, cfg.kv_dim), ("embed", "kv"))
    pb.dense("shared/wo", (cfg.q_dim, d), ("heads", "embed"))
    pb.dense("shared/wi_gate", (2 * d, cfg.d_ff), ("embed", "mlp"))
    pb.dense("shared/wi_up", (2 * d, cfg.d_ff), ("embed", "mlp"))
    pb.dense("shared/wo_mlp", (cfg.d_ff, d), ("mlp", "embed"))
    pb.ones("shared/ln1", (2 * d,), ("embed",))
    pb.ones("shared/ln2", (2 * d,), ("embed",))

    pb.ones("final_norm", (d,), ("embed",))
    pb.dense("lm_head", (d, v), ("embed", "vocab"))
    return pb.build()


# --------------------------------------------------------------- mamba layer
def _mamba_split(cfg: ModelConfig, proj: jax.Array):
    din, nh, N = mamba_dims(cfg)
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,C); w: (K,C); returns (y, last K-1 x)."""
    K = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -(K - 1):]


def _mamba_layer(cfg: ModelConfig, x: jax.Array, lp: Tree,
                 ssm_state: Optional[jax.Array] = None,
                 conv_state: Optional[jax.Array] = None):
    """x: (B,S,D) -> (out, new_ssm_state, new_conv_state)."""
    B, S, D = x.shape
    din, nh, N = mamba_dims(cfg)
    x = constrain(x, "batch", None, "act_embed")
    h = rms_norm(x, lp["ln"])
    proj = constrain(jnp.einsum("bsd,dp->bsp", h, lp["m"]["in_proj"]),
                     "batch", None, "act_heads")
    z, xin, Bc, Cc, dt = _mamba_split(cfg, proj)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_new = _causal_conv(conv_in, lp["m"]["conv_w"],
                                      lp["m"]["conv_b"], conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + lp["m"]["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    a = -jnp.exp(lp["m"]["A_log"].astype(jnp.float32))              # (nh,)
    log_w = dt * a[None, None, :]                                   # (B,S,nh) ≤0

    xh = xin.reshape(B, S, nh, MAMBA_HEAD)
    v = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    v = v.transpose(0, 2, 1, 3)                             # (B,nh,S,hd)
    k = jnp.broadcast_to(Bc[:, None], (B, nh, S, N))        # shared across heads
    q = jnp.broadcast_to(Cc[:, None], (B, nh, S, N))
    lw = log_w.transpose(0, 2, 1)                           # (B,nh,S) scalar decay

    if S == 1:
        # decode fast path: one recurrent step, no chunk padding
        S0 = (ssm_state if ssm_state is not None
              else jnp.zeros((B, nh, N, MAMBA_HEAD), jnp.float32))
        y1, S_fin = linear_attention_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], lw[:, :, 0], S0,
            inclusive=True)
        y = y1[:, None]                                     # (B,1,nh,hd)
    else:
        y, S_fin = chunked_linear_attention(
            q, k, v, lw, inclusive=True, chunk=cfg.scan_chunk,
            initial_state=ssm_state)
        y = y.transpose(0, 2, 1, 3)                         # (B,S,nh,hd)
    y = y + xh * lp["m"]["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, din)
    y = rms_norm(y, lp["m"]["norm"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = constrain(y, "batch", None, "act_heads")
    out = jnp.einsum("bsp,pd->bsd", y, lp["m"]["out_proj"])
    return constrain(x + out, "batch", None, "act_embed"), S_fin, conv_new


# --------------------------------------------------------------- shared attn
def _shared_block(cfg: ModelConfig, x: jax.Array, x0: jax.Array, sp: Tree,
                  positions: jax.Array,
                  kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cur_len: Optional[jax.Array] = None):
    """Shared attention+MLP on concat(x, x0). Returns (x + delta, (k,v))."""
    B, S, D = x.shape
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(cat, sp["ln1"])
    q = constrain(jnp.einsum("bsd,dq->bsq", h, sp["wq"]).reshape(
        B, S, cfg.num_heads, cfg.head_dim), "batch", None, "act_heads", None)
    k = constrain(jnp.einsum("bsd,dq->bsq", h, sp["wk"]).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim), "batch", None, "act_kv", None)
    v = constrain(jnp.einsum("bsd,dq->bsq", h, sp["wv"]).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim), "batch", None, "act_kv", None)
    cos, sin = make_rope(positions, cfg.head_dim, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if kv_cache is None:
        a = attention(q, k, v, causal=True,
                      block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
                      flash_threshold=cfg.flash_threshold)
        new_kv = (k, v)
    else:
        kc, vc = kv_cache                          # (B, S_max, KV*hd) flat
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k.reshape(B, S, cfg.kv_dim).astype(kc.dtype), cur_len, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v.reshape(B, S, cfg.kv_dim).astype(vc.dtype), cur_len, axis=1)
        S_max = kc.shape[1]
        a = decode_attention(
            q,
            kc.reshape(B, S_max, cfg.num_kv_heads, cfg.head_dim),
            vc.reshape(B, S_max, cfg.num_kv_heads, cfg.head_dim),
            cur_len + 1)
        new_kv = (kc, vc)
    x = x + jnp.einsum("bsq,qd->bsd", a.reshape(B, S, cfg.q_dim), sp["wo"])
    h2 = rms_norm(jnp.concatenate([x, x0], axis=-1), sp["ln2"])
    x = x + mlp_swiglu(h2, sp["wi_gate"], sp["wi_up"], sp["wo_mlp"])
    return x, new_kv


# ------------------------------------------------------------------ forward
def _group_params(cfg: ModelConfig, params: Tree) -> Tree:
    """Reshape stacked (L, ...) mamba params to (G, every, ...) for the
    two-level scan (outer groups, inner mamba layers)."""
    every = cfg.shared_attention_every
    G = cfg.num_layers // every
    return jax.tree.map(lambda a: a.reshape(G, every, *a.shape[1:]),
                        params["layers"])


def forward(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            *, remat: str = "full", return_hidden: bool = False,
            cap_e=None) -> Tuple[jax.Array, jax.Array]:
    tokens = inputs["tokens"]
    x0 = params["embed"]["tok"][tokens]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    grouped = _group_params(cfg, params)

    def inner(x, lp):
        y, _, _ = _mamba_layer(cfg, x, lp)
        return y, None

    if remat == "full":
        inner = jax.checkpoint(inner)

    def outer(x, glp):
        x, _ = jax.lax.scan(inner, x, glp)
        x, _ = _shared_block(cfg, x, x0, params["shared"], positions)
        return x, jnp.zeros((1,), jnp.float32)

    x, loads = jax.lax.scan(outer, x0, grouped)
    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, loads
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, loads


def prefill(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            max_len=None, *, remat: str = "full",
            cap_e=None) -> Tuple[jax.Array, Tree]:
    """Process a prompt: (last-token logits, hybrid state) — O(1) SSM state
    per layer + KV cache for the shared attention blocks."""
    tokens = inputs["tokens"]
    x0 = params["embed"]["tok"][tokens]
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.arange(S, dtype=jnp.int32)
    grouped = _group_params(cfg, params)

    def inner(x, lp):
        y, ssm_fin, conv_fin = _mamba_layer(cfg, x, lp)
        return y, (ssm_fin, conv_fin)

    if remat == "full":
        inner = jax.checkpoint(inner)

    def outer(x, glp):
        x, (ssm_fin, conv_fin) = jax.lax.scan(inner, x, glp)
        x, (k, v) = _shared_block(cfg, x, x0, params["shared"], positions)
        pad = max_len - S
        kf = k.reshape(B, S, cfg.kv_dim)
        vf = v.reshape(B, S, cfg.kv_dim)
        if pad > 0:
            kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
        return x, (ssm_fin, conv_fin, kf.astype(x.dtype), vf.astype(x.dtype))

    x, (ssm, conv, ks, vs) = jax.lax.scan(outer, x0, grouped)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"])
    L = cfg.num_layers
    state = {
        "ssm": ssm.reshape(L, *ssm.shape[2:]),
        "conv": conv.reshape(L, *conv.shape[2:]),
        "shared_k": ks, "shared_v": vs,
        "len": jnp.asarray(S, jnp.int32),
    }
    return logits, state


# ------------------------------------------------------------------- decode
def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype: jnp.dtype = jnp.bfloat16,
               abstract: bool = False) -> Tuple[Tree, Tree]:
    din, nh, N = mamba_dims(cfg)
    L, K = cfg.num_layers, cfg.conv_kernel
    G = L // cfg.shared_attention_every
    z = (jax.ShapeDtypeStruct if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    state = {
        "ssm": z((L, batch, nh, N, MAMBA_HEAD), jnp.float32),
        "conv": z((L, batch, K - 1, din + 2 * N), dtype),
        "shared_k": z((G, batch, max_len, cfg.kv_dim), dtype),  # flat KV
        "shared_v": z((G, batch, max_len, cfg.kv_dim), dtype),
        "len": z((), jnp.int32),
    }
    specs = {
        "ssm": ("layers", "batch", "heads", None, None),
        "conv": ("layers", "batch", None, "heads"),
        "shared_k": ("layers", "batch", "seq_cache", "kv"),
        "shared_v": ("layers", "batch", "seq_cache", "kv"),
        "len": (),
    }
    return state, specs


def decode_step(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                state: Tree, *, cap_e=None) -> Tuple[jax.Array, Tree]:
    tokens = inputs["tokens"]                       # (B,1)
    x0 = params["embed"]["tok"][tokens]             # (B,1,D)
    B = tokens.shape[0]
    cur = state["len"]
    positions = jnp.full((B, 1), cur, jnp.int32)
    every = cfg.shared_attention_every
    G = cfg.num_layers // every
    grouped = _group_params(cfg, params)
    ssm_g = jax.tree.map(
        lambda a: a.reshape(G, every, *a.shape[1:]), state["ssm"])
    conv_g = state["conv"].reshape(G, every, *state["conv"].shape[1:])

    def inner(x, layer):
        lp, ssm, conv = layer
        y, ssm_new, conv_new = _mamba_layer(cfg, x, lp, ssm, conv)
        return y, (ssm_new, conv_new)

    def outer(x, glayer):
        glp, gssm, gconv, kc, vc = glayer
        x, (ssm_new, conv_new) = jax.lax.scan(inner, x, (glp, gssm, gconv))
        x, (kc_new, vc_new) = _shared_block(
            cfg, x, x0, params["shared"], positions, (kc, vc), cur)
        return x, (ssm_new, conv_new, kc_new, vc_new)

    x, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
        outer, x0, (grouped, ssm_g, conv_g,
                    state["shared_k"], state["shared_v"]))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    new_state = {
        "ssm": ssm_new.reshape(cfg.num_layers, *ssm_new.shape[2:]),
        "conv": conv_new.reshape(cfg.num_layers, *conv_new.shape[2:]),
        "shared_k": k_new, "shared_v": v_new,
        "len": cur + 1,
    }
    return logits, new_state
