"""Model zoo facade: family-dispatched init/forward/decode.

``get_model(cfg)`` returns a ``Model`` namespace with a uniform API so the
training/serving steps, dry-run, and tests never branch on architecture:

    model.init(key, dtype)            -> (params, specs)
    model.forward(params, inputs)     -> (logits, aux)     # train/prefill
    model.init_decode(batch, max_len) -> (cache/state, specs)
    model.decode(params, inputs, st)  -> (logits, new st)
    model.prefill(params, inputs, max_len) -> (logits, cache)  # attn archs

Attention (KV-cache) archs additionally expose the batched serving path —
one stacked cache with per-slot lengths, one decode call for all slots:

    model.init_batched_decode(slots, max_len) -> (cache, specs)
    model.batched_decode(params, inputs, cache, active=mask)
                                      -> (logits (B,V), new cache)
    model.insert_prefill(cache, prefill_cache, slot) -> cache
    model.fused_decode(params, inputs, cache, num_steps=T,
                       active=mask, remaining=rem, eos_id=eos)
                -> (tokens (B,T), cache, active, remaining)  # T per dispatch

They are ``None`` for state-space / hybrid families (``ServeLoop`` falls
back to per-slot decode there).

Attention archs also expose the paged-KV serving path (block-pool cache,
host-side block tables — see ``repro.serve_mem``):

    model.init_paged_decode(num_blocks, block_size) -> (pool, specs)
    model.paged_decode(params, inputs, pool, tables=, lengths=, ...)
                                      -> (logits, pool, lengths)
    model.fused_paged_decode(params, inputs, pool, num_steps=T, tables=,
                             lengths=, limits=, ...)
                -> (tokens (B,T), pool, lengths, active, remaining)
    model.paged_prefill_chunk(params, inputs, pool, tables=, start=,
                              length=) -> (logits (1,V), pool)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer, rwkv6, zamba2

__all__ = ["ModelConfig", "Model", "get_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    init_decode: Callable
    decode: Callable
    prefill: Optional[Callable] = None
    # batched serving path (stacked cache, per-slot lengths); None when the
    # family has no batched decode implementation
    init_batched_decode: Optional[Callable] = None
    batched_decode: Optional[Callable] = None
    insert_prefill: Optional[Callable] = None
    # fused multi-token decode: T greedy tokens per dispatch via an
    # on-device lax.scan over batched_decode, with per-slot stop/length
    # handling carried in the loop state (None = no batched path)
    fused_decode: Optional[Callable] = None
    # paged-KV serving path (block pool + host-side block tables); None
    # when the family has no paged implementation
    init_paged_decode: Optional[Callable] = None
    paged_decode: Optional[Callable] = None
    fused_paged_decode: Optional[Callable] = None
    paged_prefill_chunk: Optional[Callable] = None

    @property
    def name(self) -> str:
        return self.cfg.name


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":          # rwkv6
        return Model(
            cfg=cfg,
            init=partial(rwkv6.init_params, cfg),
            forward=(lambda params, inputs, **kw:
                     rwkv6.forward(params, cfg, inputs, **kw)),
            init_decode=(lambda batch, max_len, **kw:
                         rwkv6.init_state(cfg, batch, **kw)),
            decode=(lambda params, inputs, state, **kw:
                    rwkv6.decode_step(params, cfg, inputs, state, **kw)),
            prefill=(lambda params, inputs, max_len=None, **kw:
                     rwkv6.prefill(params, cfg, inputs, max_len, **kw)),
        )
    if cfg.family == "hybrid":       # zamba2
        return Model(
            cfg=cfg,
            init=partial(zamba2.init_params, cfg),
            forward=(lambda params, inputs, **kw:
                     zamba2.forward(params, cfg, inputs, **kw)),
            init_decode=(lambda batch, max_len, **kw:
                         zamba2.init_state(cfg, batch, max_len, **kw)),
            decode=(lambda params, inputs, state, **kw:
                    zamba2.decode_step(params, cfg, inputs, state, **kw)),
            prefill=(lambda params, inputs, max_len=None, **kw:
                     zamba2.prefill(params, cfg, inputs, max_len, **kw)),
        )
    # dense / moe / audio / vlm all share the transformer implementation
    return Model(
        cfg=cfg,
        init=partial(transformer.init_params, cfg),
        forward=(lambda params, inputs, **kw:
                 transformer.forward(params, cfg, inputs, **kw)),
        init_decode=(lambda batch, max_len, **kw:
                     transformer.init_cache(cfg, batch, max_len, **kw)),
        decode=(lambda params, inputs, cache, **kw:
                transformer.decode_step(params, cfg, inputs, cache, **kw)),
        prefill=(lambda params, inputs, max_len=None, **kw:
                 transformer.prefill(params, cfg, inputs, max_len, **kw)),
        init_batched_decode=(lambda slots, max_len, **kw:
                             transformer.init_batched_cache(cfg, slots,
                                                            max_len, **kw)),
        batched_decode=(lambda params, inputs, cache, **kw:
                        transformer.batched_decode_step(params, cfg, inputs,
                                                        cache, **kw)),
        insert_prefill=transformer.insert_prefill,
        fused_decode=(lambda params, inputs, cache, **kw:
                      transformer.fused_decode_steps(params, cfg, inputs,
                                                     cache, **kw)),
        init_paged_decode=(lambda num_blocks, block_size, **kw:
                           transformer.init_paged_cache(cfg, num_blocks,
                                                        block_size, **kw)),
        paged_decode=(lambda params, inputs, cache, **kw:
                      transformer.paged_decode_step(params, cfg, inputs,
                                                    cache, **kw)),
        fused_paged_decode=(lambda params, inputs, cache, **kw:
                            transformer.fused_paged_decode_steps(
                                params, cfg, inputs, cache, **kw)),
        paged_prefill_chunk=(lambda params, inputs, cache, **kw:
                             transformer.prefill_paged_chunk(
                                 params, cfg, inputs, cache, **kw)),
    )
