"""Mixture-of-Experts layer with UDS-planned expert capacities.

Scheduling view (the paper's adaptation): experts are *units of processing*,
tokens are *units of work*.  The capacity vector ``cap_e`` — how many tokens
each expert may accept this step — is planned host-side by a UDS (weighted
factoring over measured expert loads, see ``repro/sched/moe_capacity.py``)
and passed in as a dynamic (traced) argument: buffer shapes stay static,
capacity *contents* change step to step without recompilation.

Dispatch is scatter-based (per top-k slot), not mask-einsum based: the
classic (tokens × experts × capacity) dispatch mask is O(10^13) elements at
our shapes; scatters keep the dispatch buffer at (B, E, C, D) which shards
cleanly as batch→data, experts→model (the all-to-all falls out of GSPMD
sharding propagation on the dispatch/combine scatter-gathers).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import constrain

__all__ = ["moe_capacity", "moe_buffer_capacity", "moe_ffn", "router_topk"]


def moe_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """Uniform per-sequence, per-expert capacity C (the slot *budget* is
    E*C; the buffer adds headroom so the WF2 planner can raise hot experts
    above C while staying within the budget)."""
    tokens = seq_len * cfg.experts_per_token
    return max(1, math.ceil(tokens / cfg.num_experts * cfg.moe_capacity_factor))


def moe_buffer_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """Static dispatch-buffer capacity: C x headroom."""
    return max(1, math.ceil(moe_capacity(cfg, seq_len) * cfg.moe_cap_headroom))


def router_topk(x: jax.Array, w_router: jax.Array, k: int
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (B,S,k) f32 renormalized, expert_ids (B,S,k) int32,
    probs (B,S,E) f32 — for aux losses / load stats)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    gates = topv / jnp.maximum(topv.sum(axis=-1, keepdims=True), 1e-9)
    return gates, topi.astype(jnp.int32), probs


def moe_ffn(x: jax.Array,
            w_router: jax.Array,
            w_gate: jax.Array,   # (E, D, F)
            w_up: jax.Array,     # (E, D, F)
            w_down: jax.Array,   # (E, F, D)
            cfg: ModelConfig,
            cap_e: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), expert_load (E,) f32 fraction).

    ``cap_e``: optional (E,) int32 — UDS-planned per-expert capacity
    (≤ static buffer capacity C); tokens over capacity are dropped
    (contribute zero), the standard capacity-based MoE semantics.

    Under an active mesh (axis_rules context) the shard_map fast path runs:
    dispatch scatters are *local per shard* (the GSPMD partitioner cannot
    shard this scatter pattern and falls back to global replication —
    measured 243 TB/chip of all-reduce on qwen3-moe train_4k; see
    EXPERIMENTS.md §Perf iteration 2), each model shard computes only its
    expert slice, and a single psum combines — the same collective cost
    as one TP layer.
    """
    import os
    from repro.sharding import current_rules
    ctx = current_rules()
    if (ctx is not None and ctx[0].size > 1
            and not os.environ.get("REPRO_MOE_LOCAL")):  # baseline knob
        return _moe_ffn_shardmap(x, w_router, w_gate, w_up, w_down, cfg,
                                 cap_e, ctx)
    return _moe_ffn_local(x, w_router, w_gate, w_up, w_down, cfg, cap_e)


def _moe_ffn_local(x, w_router, w_gate, w_up, w_down, cfg, cap_e
                   ) -> Tuple[jax.Array, jax.Array]:
    """Single-device reference path (also the shard_map oracle in tests)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = moe_buffer_capacity(cfg, S)

    gates, topi, probs = router_topk(x, w_router, k)

    # position of each slot within its expert, per batch row (so the cumsum
    # never crosses data shards: batch is the data-parallel axis)
    e_flat = topi.reshape(B, S * k)                                # (B, S*k)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)                # (B, S*k, E)
    pos_flat = (jnp.cumsum(oh, axis=1) - 1)
    pos_flat = jnp.take_along_axis(pos_flat, e_flat[..., None],
                                   axis=-1)[..., 0]                # (B, S*k)
    pos = pos_flat.reshape(B, S, k)

    if cap_e is not None:
        cap = jnp.minimum(cap_e.astype(jnp.int32), C)              # (E,)
        lim = cap[topi]                                            # (B, S, k)
    else:
        # no plan: uniform budget C/headroom (same total slots as planned)
        lim = jnp.full_like(pos, moe_capacity(cfg, S))
    # send over-capacity slots out of bounds -> dropped by scatter mode
    pos = jnp.where(pos < lim, pos, C)

    # ONE fused scatter for all k slots: a per-slot loop makes GSPMD
    # replicate + all-reduce the (B,E,C,D) dest across the model axis k
    # times — measured 8x collective/memory blow-up on qwen3-moe
    # (EXPERIMENTS.md §Perf, iteration 1)
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]                # (B, 1)
    p_flat = pos.reshape(B, S * k)
    upd = jnp.broadcast_to(x[:, :, None, :], (B, S, k, D)
                           ).reshape(B, S * k, D)
    dispatched = constrain(jnp.zeros((B, E, C, D), x.dtype),
                           "batch", "act_experts", None, "act_embed")
    dispatched = dispatched.at[b_idx, e_flat, p_flat].set(upd, mode="drop")
    dispatched = constrain(dispatched,
                           "batch", "act_experts", None, "act_embed")

    # expert FFN (SwiGLU), batched over experts
    g = jnp.einsum("becd,edf->becf", dispatched, w_gate)
    u = jnp.einsum("becd,edf->becf", dispatched, w_up)
    g = constrain(g, "batch", "act_experts", None, "act_mlp")
    u = constrain(u, "batch", "act_experts", None, "act_mlp")
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    eout = jnp.einsum("becf,efd->becd", h, w_down)                 # (B,E,C,D)
    eout = constrain(eout, "batch", "act_experts", None, "act_embed")

    # ONE fused gather for the combine (same argument as the scatter)
    got = eout.at[b_idx, e_flat, p_flat].get(
        mode="fill", fill_value=0).reshape(B, S, k, D)
    out = jnp.einsum("bskd,bsk->bsd", got, gates.astype(x.dtype))

    # expert load (fraction of routed slots per expert) — the measurement the
    # WF2/AWF capacity scheduler consumes (end-loop-body analogue)
    load = oh.astype(jnp.float32).sum(axis=(0, 1)) / float(B * S * k)
    return out, load


# ---------------------------------------------------------------------------
def _axis_tuple(v):
    if v is None:
        return ()
    return (v,) if isinstance(v, str) else tuple(v)


def _moe_ffn_shardmap(x, w_router, w_gate, w_up, w_down, cfg, cap_e, ctx
                      ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map.

    Layout (from the rule table):
      x        : batch over data axes, (S, D) full per shard
      router   : replicated (tiny)
      w_gate/up: experts over `experts` axis (if any), D over `embed`
                 (FSDP) axis — gathered per layer inside the shard
      w_down   : experts over `experts`, F over `mlp`, D-out full
    Each model shard scatters only the tokens routed to ITS experts
    (locally — no cross-shard scatter semantics), computes its slice, and
    one psum over the model axis assembles the output (row-parallel
    pattern: same collective cost as a TP MLP layer).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh, rules, sizes = ctx
    batch_axes = _axis_tuple(rules.get("batch"))
    fsdp_axes = _axis_tuple(rules.get("embed"))
    expert_axes = _axis_tuple(rules.get("experts"))
    mlp_axes = _axis_tuple(rules.get("mlp"))
    # drop axes not in this mesh / sized 1
    def live(axes):
        return tuple(a for a in axes if sizes.get(a, 1) > 1)
    batch_axes, fsdp_axes = live(batch_axes), live(fsdp_axes)
    expert_axes, mlp_axes = live(expert_axes), live(mlp_axes)
    # an axis may shard at most one dim of the expert weights
    # (priority: experts > embed/fsdp > mlp — mirrors spec_for's dedup)
    fsdp_axes = tuple(a for a in fsdp_axes if a not in expert_axes)
    mlp_axes = tuple(a for a in mlp_axes
                     if a not in expert_axes and a not in fsdp_axes)

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = moe_buffer_capacity(cfg, S)
    e_shards = 1
    for a in expert_axes:
        e_shards *= sizes[a]
    if E % max(e_shards, 1):
        e_shards = 1
        expert_axes = ()
    E_loc = E // max(e_shards, 1)

    x_spec = P(batch_axes if batch_axes else None, None, None)
    wg_spec = P(expert_axes or None, fsdp_axes or None, mlp_axes or None)
    wd_spec = P(expert_axes or None, mlp_axes or None, fsdp_axes or None)
    cap_spec = P(None)

    def local(x_l, router, wg_l, wu_l, wd_l, cap):
        # gather the FSDP-sharded dims of this layer's expert weights
        for ax in fsdp_axes:
            wg_l = jax.lax.all_gather(wg_l, ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, ax, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, ax, axis=2, tiled=True)
        Bl = x_l.shape[0]
        gates, topi, _ = router_topk(x_l, router, k)
        e_flat = topi.reshape(Bl, S * k)
        oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        pos_flat = jnp.cumsum(oh, axis=1) - 1
        pos_flat = jnp.take_along_axis(pos_flat, e_flat[..., None],
                                       axis=-1)[..., 0]
        if cap is not None:
            lim = jnp.minimum(cap.astype(jnp.int32), C)[e_flat]
        else:
            lim = jnp.full_like(pos_flat, moe_capacity(cfg, S))
        pos_eff = jnp.where(pos_flat < lim, pos_flat, C)

        # restrict to THIS shard's expert slice
        if expert_axes:
            m = jax.lax.axis_index(expert_axes[0])
            for ax in expert_axes[1:]:
                m = m * sizes[ax] + jax.lax.axis_index(ax)
            e_lo = m * E_loc
        else:
            e_lo = 0
        e_local = e_flat - e_lo
        in_range = (e_local >= 0) & (e_local < E_loc)
        e_local = jnp.clip(e_local, 0, E_loc - 1)
        pos_eff = jnp.where(in_range, pos_eff, C)     # out-of-range -> drop

        b_idx = jnp.arange(Bl, dtype=jnp.int32)[:, None]
        # gather-based dispatch: scatter only the int32 slot->token map,
        # then gather rows of x — avoids materializing k copies of x as
        # scatter updates (8x the residual bytes on qwen3-moe; §Perf iter 3)
        tok_of_slot = (jnp.arange(S * k, dtype=jnp.int32) // k)[None, :]
        src = jnp.full((Bl, E_loc, C), S, jnp.int32)
        src = src.at[b_idx, e_local, pos_eff].set(
            jnp.broadcast_to(tok_of_slot, (Bl, S * k)), mode="drop")
        x_pad = jnp.pad(x_l, ((0, 0), (0, 1), (0, 0)))   # row S = zeros
        dest = jax.vmap(lambda xp, s: xp[s])(x_pad, src)  # (Bl,E_loc,C,D)

        g = jnp.einsum("becd,edf->becf", dest, wg_l)
        u = jnp.einsum("becd,edf->becf", dest, wu_l)
        h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
             ).astype(x_l.dtype)
        eout = jnp.einsum("becf,efd->becd", h, wd_l)

        got = eout.at[b_idx, e_local, pos_eff].get(
            mode="fill", fill_value=0).reshape(Bl, S, k, D)
        out = jnp.einsum("bskd,bsk->bsd", got, gates.astype(x_l.dtype))
        reduce_axes = tuple(expert_axes) + tuple(mlp_axes)
        if reduce_axes:
            out = jax.lax.psum(out, reduce_axes)
        load = oh.astype(jnp.float32).sum(axis=(0, 1)) / float(Bl * S * k)
        if batch_axes:
            load = jax.lax.pmean(load, batch_axes)
        return out, load

    if cap_e is None:
        cap_e = jnp.full((E,), moe_capacity(cfg, S), jnp.int32)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), wg_spec, wg_spec, wd_spec, cap_spec),
        out_specs=(x_spec, P(None)),
        check_rep=False)
    return fn(x, w_router, w_gate, w_up, w_down, cap_e)


def load_balancing_loss(probs: jax.Array, topi: jax.Array, E: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    f = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(axis=(-2)).mean(
        axis=tuple(range(probs.ndim - 1)))  # fraction routed per expert
    p = probs.mean(axis=tuple(range(probs.ndim - 1)))
    return E * jnp.sum(f * p)
