"""RWKV6 "Finch" — attention-free RNN LM with data-dependent decay.

Faithful core (arXiv:2404.05892): per layer a time-mix block (the wkv linear
recurrence with **data-dependent per-channel decay** w_t = exp(-exp(w0 +
tanh(x W_a) W_b)) and bonus u) and a channel-mix block (squared-ReLU FFN with
receptance gate).  Simplifications, documented in DESIGN.md: static token-
shift mixing coefficients (the low-rank *dynamic* mixing of the five streams
is omitted; the *decay* — the headline Finch feature — keeps its full
data-dependent low-rank form), and RMSNorm in place of LayerNorm/GroupNorm.

Shapes: d_model=2560, wkv head dim 64 -> H=40 heads; state (B,H,64,64).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.common import ParamBuilder, rms_norm
from repro.sharding import constrain
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step

__all__ = ["init_params", "forward", "init_state", "decode_step", "prefill"]

Tree = Dict[str, Any]
LORA = 64  # low-rank dim of the data-dependent decay


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: jnp.dtype = jnp.bfloat16,
                abstract: bool = False) -> Tuple[Tree, Tree]:
    pb = ParamBuilder(key, dtype, abstract=abstract)
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    n = cfg.wkv_head_dim
    H = d // n

    pb.dense("embed/tok", (v, d), ("vocab", "embed"), scale=1.0)

    # ---- time-mix ----
    for name in ("wr", "wk", "wv", "wg", "wo"):
        pb.dense(f"layers/tm/{name}", (L, d, d), ("layers", "embed", "heads"))
    # static token-shift mix coefficients for r,k,v,g,w streams
    pb.dense("layers/tm/mix", (L, 5, d), ("layers", None, "embed"), scale=0.02)
    # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
    pb.dense("layers/tm/decay_w0", (L, d), ("layers", "embed"), scale=0.1)
    pb.dense("layers/tm/decay_a", (L, d, LORA), ("layers", "embed", None))
    pb.dense("layers/tm/decay_b", (L, LORA, d), ("layers", None, "embed"))
    pb.dense("layers/tm/bonus_u", (L, H, n), ("layers", "heads", None), scale=0.5)
    pb.ones("layers/tm/out_norm", (L, d), ("layers", "embed"))

    # ---- channel-mix ----
    pb.dense("layers/cm/wk", (L, d, f), ("layers", "embed", "mlp"))
    pb.dense("layers/cm/wv", (L, f, d), ("layers", "mlp", "embed"))
    pb.dense("layers/cm/wr", (L, d, d), ("layers", "embed", "heads"))
    pb.dense("layers/cm/mix", (L, 2, d), ("layers", None, "embed"), scale=0.02)

    pb.ones("layers/ln1", (L, d), ("layers", "embed"))
    pb.ones("layers/ln2", (L, d), ("layers", "embed"))
    pb.ones("final_norm", (d,), ("embed",))
    pb.dense("lm_head", (d, v), ("embed", "vocab"))
    return pb.build()


def _shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Token shift: x_{t-1} (first position gets `prev` or zeros)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _decay_logw(h_w: jax.Array, lp: Tree) -> jax.Array:
    """log w_t = -exp(w0 + tanh(h W_a) W_b)  (≤ 0, data-dependent)."""
    z = jnp.tanh(jnp.einsum("bsd,dr->bsr", h_w, lp["tm"]["decay_a"]))
    raw = lp["tm"]["decay_w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", z, lp["tm"]["decay_b"]).astype(jnp.float32)
    return -jnp.exp(raw)


def _time_mix(cfg: ModelConfig, x: jax.Array, lp: Tree,
              prev_x: Optional[jax.Array] = None,
              state: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, final_state, last_x). x: (B,S,D)."""
    B, S, D = x.shape
    n = cfg.wkv_head_dim
    H = D // n
    xs = _shift(x, prev_x)
    mix = lp["tm"]["mix"]                                   # (5, D)
    streams = [x + (xs - x) * mix[i] for i in range(5)]     # r,k,v,g,w
    hr, hk, hv, hg, hw = streams
    r = constrain(jnp.einsum("bsd,de->bse", hr, lp["tm"]["wr"]),
                  "batch", None, "act_heads")
    k = constrain(jnp.einsum("bsd,de->bse", hk, lp["tm"]["wk"]),
                  "batch", None, "act_heads")
    v = constrain(jnp.einsum("bsd,de->bse", hv, lp["tm"]["wv"]),
                  "batch", None, "act_heads")
    g = constrain(jnp.einsum("bsd,de->bse", hg, lp["tm"]["wg"]),
                  "batch", None, "act_heads")
    log_w = constrain(_decay_logw(hw, lp), "batch", None, "act_heads")

    def heads(t):  # (B,S,D) -> (B,H,S,n)
        return constrain(t.reshape(B, S, H, n).transpose(0, 2, 1, 3),
                         "batch", "act_heads", None, None)

    y, S_fin = chunked_linear_attention(
        heads(r), heads(k), heads(v), heads(log_w),
        u=lp["tm"]["bonus_u"].astype(jnp.float32),
        inclusive=False, chunk=cfg.scan_chunk, initial_state=state)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y, lp["tm"]["out_norm"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bsd,de->bse", y, lp["tm"]["wo"])
    return out, S_fin, x[:, -1]


def _channel_mix(x: jax.Array, lp: Tree,
                 prev_x: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    xs = _shift(x, prev_x)
    mix = lp["cm"]["mix"]
    hk = x + (xs - x) * mix[0]
    hr = x + (xs - x) * mix[1]
    kk = constrain(jnp.einsum("bsd,df->bsf", hk, lp["cm"]["wk"]),
                   "batch", None, "act_mlp")
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, lp["cm"]["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", hr, lp["cm"]["wr"]
                                   ).astype(jnp.float32)).astype(x.dtype)
    return rr * vv, x[:, -1]


def forward(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            *, remat: str = "full", return_hidden: bool = False,
            cap_e=None) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward. Returns (logits (B,S,V), dummy loads)."""
    x = params["embed"]["tok"][inputs["tokens"]]

    def body(x, lp):
        x = constrain(x, "batch", None, "act_embed")
        h = rms_norm(x, lp["ln1"])
        tm, _, _ = _time_mix(cfg, h, lp)
        x = x + tm
        h = rms_norm(x, lp["ln2"])
        cm, _ = _channel_mix(h, lp)
        return constrain(x + cm, "batch", None, "act_embed"),             jnp.zeros((1,), jnp.float32)

    if remat == "full":
        body = jax.checkpoint(body)
    x, loads = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, loads
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, loads


def prefill(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            max_len=None, *, remat: str = "full",
            cap_e=None) -> Tuple[jax.Array, Tree]:
    """Process a prompt, producing (last-token logits, recurrent state).
    For an RNN the "KV cache" is O(1): per-layer wkv state + token shifts."""
    del max_len  # state is O(1) in context length
    x = params["embed"]["tok"][inputs["tokens"]]

    def body(x, lp):
        x = constrain(x, "batch", None, "act_embed")
        h = rms_norm(x, lp["ln1"])
        tm, S_fin, _ = _time_mix(cfg, h, lp)
        sh_tm = h[:, -1]
        x = x + tm
        h2 = rms_norm(x, lp["ln2"])
        cm, _ = _channel_mix(h2, lp)
        sh_cm = h2[:, -1]
        return (constrain(x + cm, "batch", None, "act_embed"),
                (S_fin, sh_tm, sh_cm))

    if remat == "full":
        body = jax.checkpoint(body)
    x, (wkv, sh_tm, sh_cm) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"])
    state = {"wkv": wkv, "shift_tm": sh_tm.astype(x.dtype),
             "shift_cm": sh_cm.astype(x.dtype),
             "len": jnp.asarray(inputs["tokens"].shape[1], jnp.int32)}
    return logits, state


def init_state(cfg: ModelConfig, batch: int,
               dtype: jnp.dtype = jnp.float32,
               abstract: bool = False) -> Tuple[Tree, Tree]:
    """Recurrent decode state (takes the place of a KV cache)."""
    n = cfg.wkv_head_dim
    H = cfg.d_model // n
    L = cfg.num_layers
    z = (jax.ShapeDtypeStruct if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    state = {
        "wkv": z((L, batch, H, n, n), jnp.float32),
        "shift_tm": z((L, batch, cfg.d_model), dtype),
        "shift_cm": z((L, batch, cfg.d_model), dtype),
        "len": z((), jnp.int32),
    }
    specs = {
        "wkv": ("layers", "batch", "heads", None, None),
        "shift_tm": ("layers", "batch", "embed"),
        "shift_cm": ("layers", "batch", "embed"),
        "len": (),
    }
    return state, specs


def decode_step(params: Tree, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                state: Tree, *, cap_e=None) -> Tuple[jax.Array, Tree]:
    """One-token decode. inputs tokens (B,1). O(1) in context length —
    this is why rwkv6 runs the long_500k cell."""
    x = params["embed"]["tok"][inputs["tokens"]][:, 0]    # (B,D)
    B, D = x.shape
    n = cfg.wkv_head_dim
    H = D // n

    def body(x, layer):
        lp, wkv, sh_tm, sh_cm = layer
        h = rms_norm(x, lp["ln1"])
        mix = lp["tm"]["mix"]
        streams = [h + (sh_tm.astype(h.dtype) - h) * mix[i] for i in range(5)]
        hr, hk, hv, hg, hw = streams
        r = jnp.einsum("bd,de->be", hr, lp["tm"]["wr"])
        k = jnp.einsum("bd,de->be", hk, lp["tm"]["wk"])
        v = jnp.einsum("bd,de->be", hv, lp["tm"]["wv"])
        g = jnp.einsum("bd,de->be", hg, lp["tm"]["wg"])
        z = jnp.tanh(jnp.einsum("bd,dr->br", hw, lp["tm"]["decay_a"]))
        raw = lp["tm"]["decay_w0"].astype(jnp.float32) + jnp.einsum(
            "br,rd->bd", z, lp["tm"]["decay_b"]).astype(jnp.float32)
        log_w = -jnp.exp(raw)

        def hshape(t):
            return t.reshape(B, H, n)

        y, wkv_new = linear_attention_step(
            hshape(r), hshape(k), hshape(v), hshape(log_w), wkv,
            u=lp["tm"]["bonus_u"].astype(jnp.float32), inclusive=False)
        y = y.reshape(B, D)
        y = rms_norm(y, lp["tm"]["out_norm"])
        y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
        x = x + jnp.einsum("bd,de->be", y, lp["tm"]["wo"])

        h2 = rms_norm(x, lp["ln2"])
        cmix = lp["cm"]["mix"]
        hk2 = h2 + (sh_cm.astype(h2.dtype) - h2) * cmix[0]
        hr2 = h2 + (sh_cm.astype(h2.dtype) - h2) * cmix[1]
        kk = jnp.einsum("bd,df->bf", hk2, lp["cm"]["wk"])
        kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
        vv = jnp.einsum("bf,fd->bd", kk, lp["cm"]["wv"])
        rr = jax.nn.sigmoid(jnp.einsum("bd,de->be", hr2, lp["cm"]["wr"]
                                       ).astype(jnp.float32)).astype(x.dtype)
        x = x + rr * vv
        return x, (wkv_new, h.astype(sh_tm.dtype), h2.astype(sh_cm.dtype))

    x, (wkv_new, sh_tm_new, sh_cm_new) = jax.lax.scan(
        body, x, (params["layers"], state["wkv"],
                  state["shift_tm"], state["shift_cm"]))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
    new_state = {"wkv": wkv_new, "shift_tm": sh_tm_new,
                 "shift_cm": sh_cm_new, "len": state["len"] + 1}
    return logits, new_state
