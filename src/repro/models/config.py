"""Model configuration: one dataclass covers all ten assigned architectures.

Every field is explicit (no HF config loading — the exact dims come from the
assignment table and are pinned in ``repro/configs/<arch>.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free (rwkv uses wkv heads)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_cap_headroom: float = 1.6   # buffer slack for WF2 capacity planning

    # --- SSM / linear attention --------------------------------------------
    ssm_state: int = 0               # mamba2 N
    wkv_head_dim: int = 64           # rwkv6 head size
    conv_kernel: int = 4             # mamba2 depthwise conv width
    scan_chunk: int = 64             # chunk size of the chunked linear scan

    # --- attention variants --------------------------------------------------
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2/2.5, qwen2-vl
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    attention: str = "full"          # full | none (rwkv) | hybrid (zamba2)
    positional: str = "rope"         # rope | sinusoidal (musicgen) | none

    # --- MLP variants --------------------------------------------------------
    mlp: str = "swiglu"              # swiglu | gelu (musicgen)

    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attention_every: int = 0  # a shared attn block after every k layers

    # --- embeddings / frontends ----------------------------------------------
    pad_vocab_multiple: int = 0      # pad embed/head rows so vocab shards evenly
    tie_embeddings: bool = False
    frontend: str = "none"           # none | audio | vision (stub embeddings in)

    # --- serving ----------------------------------------------------------------
    kv_cache_dtype: str = "bf16"     # bf16 | fp8 (f8e4m3fn; halves decode HBM)

    # --- attention impl for long sequences -------------------------------------
    attn_block_q: int = 512          # query block of blockwise (flash) attention
    attn_block_kv: int = 1024
    flash_threshold: int = 8192      # use blockwise attention for seq >= this

    # --- UDS integration --------------------------------------------------------
    scheduler: str = "fac2"          # default UDS for packing/microbatching
    moe_scheduler: str = "wf2"       # UDS for expert capacity planning

    # --- sharding ---------------------------------------------------------------
    # per-arch overrides of the logical->mesh rule table, e.g. grok-1 keeps
    # experts unsharded (8 experts < 16-way model axis) and TP-shards each
    # expert's huge d_ff instead:  (("experts", None), ("mlp", "model"))
    sharding_overrides: Tuple[Tuple[str, Any], ...] = ()
    # optimizer: "adamw" (<=32B) or "adafactor" (paLM-style, for >=200B)
    optimizer: str = "adamw"

    def __post_init__(self):
        if self.num_heads and self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ sizes
    @property
    def q_dim(self) -> int:
        return self.num_heads * (self.head_dim or 0)

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * (self.head_dim or 0)

    @property
    def padded_vocab(self) -> int:
        if self.pad_vocab_multiple:
            m = self.pad_vocab_multiple
            return ((self.vocab_size + m - 1) // m) * m
        return self.vocab_size

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(seq) context (SSM / linear attention);
        gates the long_500k shape per the assignment spec."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (validated against the real pytree in
        tests); used for MODEL_FLOPS = 6*N*D roofline bookkeeping."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += d * v                 # lm head
        total += d                         # final norm
        per_layer = 0
        if self.family == "ssm":           # rwkv6
            n_h = d // self.wkv_head_dim
            per_layer += 5 * d * d         # r,k,v,g,o projections
            per_layer += 2 * (d * 64 + 64 * d)  # low-rank decay + mix
            per_layer += d                 # bonus u (per channel)
            per_layer += 2 * d             # ln weights
            per_layer += d * f + f * d + d * d  # channel mix (k, v, r)
            return total + L * per_layer
        # attention (dense/moe/hybrid-shared/audio/vlm)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            attn += 2 * (self.head_dim or 0)
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f + d + f        # gelu w/ biases
        if self.family == "hybrid":        # zamba2: mamba2 layers + shared attn
            H = self.num_heads
            din = 2 * d                    # mamba2 inner dim (expand=2)
            N = self.ssm_state
            nheads = din // 64
            m = d * (2 * din + 2 * N + nheads)     # in_proj (z,x,B,C,dt)
            m += self.conv_kernel * (din + 2 * N)  # depthwise conv
            m += nheads * 2 + nheads               # A, D, dt_bias
            m += din * d                           # out_proj
            m += 2 * d                             # norms
            shared = (2 * d) * self.q_dim + 2 * (2 * d) * self.kv_dim \
                + self.q_dim * d + 3 * (2 * d) * f // 2 + 2 * 2 * d
            n_shared = 1
            return total + L * m + n_shared * shared
        per_layer = attn + 2 * d           # ln1, ln2
        if self.is_moe:
            per_layer += d * self.num_experts              # router
            per_layer += self.num_experts * 3 * d * f      # expert swiglu
        else:
            per_layer += mlp
        return total + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.num_experts * 3 * d * f
        active_experts = self.experts_per_token * 3 * d * f
        return self.param_count() - self.num_layers * (dense_experts - active_experts)
