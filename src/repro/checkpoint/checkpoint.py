"""Checkpointing: atomic, optionally async, mesh-independent restore.

Layout (one directory per step)::

    <dir>/step_000042/
        arrays.npz          flattened param/opt/state tree
        meta.json           treedef paths, dtypes, logical specs, step, extras
    <dir>/step_000042.tmp   (during write; atomic rename on commit)
    <dir>/LATEST            text file with the newest committed step

Restore reshards automatically: arrays are loaded host-side and
``jax.device_put`` with the *target* mesh's shardings — a checkpoint written
on a (16,16) mesh restores onto (8,16) after losing a pod row (elastic
scaling; see runtime/elastic.py).

Async mode hands the host copy to a commit thread so the train loop only
blocks for the device→host transfer, not the disk write (overlap trick).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extras: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save. Returns the committed path."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:08d}"
    tmp = d / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": int(step),
        "keys": [k for k, _ in flat],
        "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat},
        "extras": extras or {},
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic commit
    (d / "LATEST").write_text(str(step))
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    try:
        step = int(p.read_text().strip())
    except ValueError:
        return None
    if (Path(directory) / f"step_{step:08d}").exists():
        return step
    # LATEST points at a missing dir (crash between rename and pointer):
    # fall back to newest committed dir
    steps = sorted(int(q.name.split("_")[1]) for q in Path(directory).glob(
        "step_*") if q.is_dir() and not q.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       shardings: Any = None,
                       step: Optional[int] = None
                       ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``; device_put with
    ``shardings`` (same treedef) if given — this is where cross-mesh
    resharding happens."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = Path(directory) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}

    flat_like = _flatten_with_paths(tree_like)
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for key, like in flat_like:
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want = np.dtype(jax.numpy.asarray(like).dtype
                        if not hasattr(like, "dtype") else like.dtype)
        leaves.append(arr.astype(want, copy=False))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        flat_t, td = jax.tree_util.tree_flatten(tree)
        flat_s = td.flatten_up_to(shardings)
        tree = td.unflatten([jax.device_put(a, s)
                             for a, s in zip(flat_t, flat_s)])
    return tree, step, meta.get("extras", {})


class AsyncCheckpointer:
    """Overlapped checkpointing: device→host copy on the caller thread,
    serialization + atomic commit on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extras: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def commit():
            try:
                save_checkpoint(self.directory, step, host_tree, extras)
                self.last_committed = step
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=commit, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        d = Path(self.directory)
        steps = sorted(int(q.name.split("_")[1]) for q in d.glob("step_*")
                       if q.is_dir() and not q.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)
