"""Paged-KV serving memory: the block pool / block table subsystem.

Cache memory — not slot count — is the scheduled resource of the paged
serving engine: fixed-size KV blocks live in one device-resident pool,
every in-flight request holds a *block table* mapping its logical token
positions onto pool blocks, and the host-side manager here does the
allocate / grow / release / watermark accounting that admission,
chunked prefill, and preemption decisions are made against.

`repro.models.common.gather_kv_paged` / `scatter_kv_paged` are the
device twins: they read and write the pool through the same tables.
"""

from repro.serve_mem.blocks import BlockPool, BlockTables
from repro.serve_mem.trace import make_mixed_trace

__all__ = ["BlockPool", "BlockTables", "make_mixed_trace"]
