"""Block pool + block tables: KV cache memory as an allocatable resource.

The dense serving cache gives every slot one ``max_len`` KV row, so slot
count — not memory — caps concurrency.  The paged engine instead carves
the cache into ``num_blocks`` fixed-size blocks (``block_size`` token
positions each, all layers of one position in one block) and hands them
out on demand:

* :class:`BlockPool` — the free list.  ``alloc`` is all-or-nothing (a
  request never ends up half-grown holding blocks it cannot use),
  ``free`` returns blocks, and the pool keeps watermark accounting
  (``used`` / ``peak_used`` / ``utilization``) that admission and
  preemption decisions read.
* :class:`BlockTables` — per-request tables mapping logical token
  positions onto pool blocks.  ``ensure(rid, n_tokens)`` grows a table
  to cover a prefix of ``n_tokens`` positions; ``release(rid)`` frees
  every block back to the pool.  ``rows()`` renders tables as the
  padded ``(B, max_blocks)`` int32 array the device scatter/gather
  consumes (``-1`` marks unassigned entries).

Everything here is host-side numpy/python — the device never sees the
free list, only the rendered tables.  Invariants (locked by the
hypothesis suite in ``tests/test_paged.py``): a live block is owned by
exactly one table and never on the free list; releasing everything
returns the pool to full; used/free counts never go negative.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

__all__ = ["BlockPool", "BlockTables", "blocks_for_tokens"]


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to cover ``n_tokens`` positions (ceil division)."""
    if n_tokens <= 0:
        return 0
    return -(-int(n_tokens) // int(block_size))


class BlockPool:
    """Fixed-size KV block allocator with watermark accounting.

    The free list is LIFO over sorted ids, so allocation order is
    deterministic — evict→readmit reproducibility (and every test) rests
    on the pool never making a random placement decision.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO stack; initialized descending so .pop() hands out ids in
        # ascending order from a fresh pool
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.peak_used = 0          # high watermark (blocks)
        self.alloc_calls = 0
        self.failed_allocs = 0      # all-or-nothing refusals (pressure)
        self.freed_blocks = 0

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.num_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def utilization(self) -> float:
        """Live-block fraction of the pool right now."""
        return self.used / self.num_blocks

    # ---------------------------------------------------------- transfer
    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks, or None (and nothing) if the pool is short.

        All-or-nothing: under pressure the caller either preempts a
        victim to make room or leaves the requester queued — it never
        holds a useless partial grant.
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        self.alloc_calls += 1
        if n > len(self._free):
            self.failed_allocs += 1
            return None
        got = [self._free.pop() for _ in range(n)]
        self.peak_used = max(self.peak_used, self.used)
        return got

    def free(self, blocks: List[int]) -> None:
        """Return blocks to the pool (double-free and alien ids refused)."""
        for b in blocks:
            if not (0 <= b < self.num_blocks):
                raise ValueError(f"free: block {b} not in pool")
        live = set(self._free)
        for b in blocks:
            if b in live:
                raise ValueError(f"free: block {b} is already free")
        self._free.extend(sorted(blocks, reverse=True))
        self.freed_blocks += len(blocks)


class BlockTables:
    """Per-request block tables over one :class:`BlockPool`.

    ``max_blocks`` bounds a single request's table (its max context =
    ``max_blocks * block_size`` tokens — the paged analogue of the dense
    engine's ``max_len``).
    """

    def __init__(self, pool: BlockPool, max_blocks: int):
        if max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1, got {max_blocks}")
        self.pool = pool
        self.max_blocks = int(max_blocks)
        self._tables: Dict[int, List[int]] = {}

    # ------------------------------------------------------------ queries
    @property
    def block_size(self) -> int:
        return self.pool.block_size

    @property
    def max_context(self) -> int:
        """Longest sequence one table can address (tokens)."""
        return self.max_blocks * self.pool.block_size

    def holders(self) -> List[int]:
        return sorted(self._tables)

    def num_blocks_of(self, rid: int) -> int:
        return len(self._tables.get(rid, ()))

    def capacity(self, rid: int) -> int:
        """Token positions the request's current blocks cover."""
        return self.num_blocks_of(rid) * self.pool.block_size

    def row(self, rid: int) -> np.ndarray:
        """The request's table as a ``(max_blocks,)`` int32 row, ``-1``
        padding unassigned entries — the device scatter/gather form."""
        out = np.full((self.max_blocks,), -1, np.int32)
        tab = self._tables.get(rid, ())
        out[:len(tab)] = tab
        return out

    # ---------------------------------------------------------- lifecycle
    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow ``rid``'s table to cover ``n_tokens`` positions.

        Returns True when the table already covers them or the growth
        allocation succeeded; False (table untouched) when the pool is
        short — the caller's preemption cue.  A request asking for more
        than ``max_context`` is refused loudly: no table can serve it.
        """
        need = blocks_for_tokens(n_tokens, self.pool.block_size)
        if need > self.max_blocks:
            raise ValueError(
                f"request {rid}: {n_tokens} tokens need {need} blocks, "
                f"table capacity is {self.max_blocks} "
                f"({self.max_context} tokens)")
        tab = self._tables.setdefault(rid, [])
        grow = need - len(tab)
        if grow <= 0:
            return True
        got = self.pool.alloc(grow)
        if got is None:
            return False
        tab.extend(got)
        return True

    def release(self, rid: int) -> int:
        """Free every block the request holds; returns the count."""
        tab = self._tables.pop(rid, None)
        if not tab:
            return 0
        self.pool.free(tab)
        return len(tab)

    def rows(self, rids) -> np.ndarray:
        """Stack ``row(rid)`` for each rid — the ``(B, max_blocks)``
        dispatch-time table array."""
        if len(rids) == 0:
            return np.full((0, self.max_blocks), -1, np.int32)
        return np.stack([self.row(r) for r in rids])
