"""Deterministic long/short request-mix traces for serving tests + bench.

The paged engine's acceptance scenario — O(100) concurrent requests with
a long/short prompt mix under memory pressure — needs one trace both the
test suite and ``benchmarks/serve_adapt.py`` stage 6 agree on, or the
bench gates a workload the tests never exercised.  ``make_mixed_trace``
is that single source: seeded, host-only, and returning plain
``(prompt, max_new)`` material the caller wraps into ``Request``s.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["TraceItem", "make_mixed_trace"]


@dataclasses.dataclass(frozen=True)
class TraceItem:
    """One request blueprint: a prompt array and its generation budget."""

    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int


def make_mixed_trace(n: int, *, vocab_size: int, seed: int = 0,
                     long_frac: float = 0.25,
                     short_len: tuple = (4, 12),
                     long_len: tuple = (40, 57),
                     short_new: tuple = (4, 9),
                     long_new: tuple = (8, 17)) -> List[TraceItem]:
    """``n`` requests, a ``long_frac`` fraction of them long-prompt.

    Long requests are dealt round-robin through the trace (every
    ``1/long_frac``-th position) rather than randomly placed, so every
    window of the trace carries the mix — the "sustained" part of the
    concurrency gate.  Lengths/budgets are drawn uniformly from the
    half-open ranges; everything derives from ``seed`` alone.
    """
    if not 0.0 <= long_frac <= 1.0:
        raise ValueError(f"long_frac must be in [0, 1], got {long_frac}")
    rng = np.random.default_rng(seed)
    stride = int(round(1.0 / long_frac)) if long_frac > 0 else 0
    items: List[TraceItem] = []
    for i in range(n):
        is_long = stride > 0 and i % stride == 0
        lo, hi = long_len if is_long else short_len
        nlo, nhi = long_new if is_long else short_new
        prompt = rng.integers(0, vocab_size,
                              size=int(rng.integers(lo, hi))).astype(np.int32)
        items.append(TraceItem(rid=i, prompt=prompt,
                               max_new=int(rng.integers(nlo, nhi))))
    return items
