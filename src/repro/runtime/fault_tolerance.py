"""Fault-tolerant training runtime.

The supervisor owns the restart loop a 1000-node deployment needs:

  * periodic async checkpoints (train loop blocks only for device→host);
  * on failure (device loss, preemption, injected fault) — restore from the
    newest committed checkpoint and continue;
  * on **worker loss** (:class:`WorkerLost` / an injected ``host_loss``) —
    a first-class :class:`~repro.core.MembershipEvent`: checkpoint-restore,
    audit the dead hosts' unfinished token chunks from the mitigator's
    last share plan (``PlanEngine.requeue_plan`` — chunk→worker ownership
    is plan provenance, so no chunk is silently lost), resize the
    mitigator to the surviving team, and hand the event to
    ``on_membership`` (or the worker count to ``on_elastic``) so the
    caller rebuilds mesh/steps via ``runtime/elastic.rebuild``;
  * on *repeated* failure of the same device set — elastic downsize: halve
    the team and run the same membership path (the scheduler's ``init`` is
    simply re-run — paper semantics: start = init + enqueue);
  * a final checkpoint at loop exit, so tail steps past the last periodic
    save are never re-executed by a later restore;
  * straggler mitigation via AWF weights from measured per-host step times
    (sched/straggler.py).

Failures are injected through ``FailureInjector`` in tests/examples — the
supervisor logic is identical for real device errors (RuntimeError from the
runtime surfaces the same way, and a real control plane raises
``WorkerLost`` when its health checks expire).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core import MembershipEvent, get_engine
from repro.sched.straggler import StragglerMitigator

__all__ = ["FailureInjector", "SupervisorReport", "TrainSupervisor",
           "WorkerLost"]


class WorkerLost(RuntimeError):
    """A data-parallel worker (host) left the team mid-run.

    ``lost`` carries the departed hosts' (old-team) ids, or ``None`` when
    the failure source cannot name them (the supervisor then assumes the
    highest-id host died).
    """

    def __init__(self, message: str,
                 lost: Optional[Tuple[int, ...]] = None):
        super().__init__(message)
        self.lost = tuple(lost) if lost is not None else None


class FailureInjector:
    """Deterministic fault schedule: fail at given steps (once each).

    Kinds: ``"transient"`` / ``"device"`` raise a plain RuntimeError
    (restore-and-continue); ``"host_loss"`` raises :class:`WorkerLost`
    (membership replan) — optionally naming the casualties, e.g.
    ``"host_loss:2"`` or ``"host_loss:2,3"``.
    """

    def __init__(self, fail_at: Dict[int, str]):
        self.fail_at = dict(fail_at)        # step -> kind
        self.fired: List[int] = []

    def check(self, step: int) -> None:
        kind = self.fail_at.pop(step, None)
        if kind is None:
            return
        self.fired.append(step)
        if kind.startswith("host_loss"):
            lost = None
            if ":" in kind:
                lost = tuple(int(x) for x in
                             kind.split(":", 1)[1].split(","))
            raise WorkerLost(
                f"injected host loss at step {step}"
                + (f" (hosts {list(lost)})" if lost else ""), lost=lost)
        raise RuntimeError(f"injected {kind} failure at step {step}")


@dataclasses.dataclass
class SupervisorReport:
    steps_completed: int
    restarts: int
    restores: List[int]
    elastic_events: List[Tuple[int, int]]    # (step, new_data_shards)
    stragglers_flagged: List[int]
    losses: List[float]
    # membership replans (worker loss / elastic downsize), in order
    membership_events: List[MembershipEvent] = \
        dataclasses.field(default_factory=list)
    # per-event requeue audit: which token ranges the dead hosts owned
    # and how they were replanned over the survivors (None entries mean
    # no share plan was live — nothing was stranded)
    requeued: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    final_hosts: int = 1


class TrainSupervisor:
    """Drives (state, step) -> state train functions under failures.

    ``make_step(state, step) -> (state, metrics)`` — the compiled step;
    ``state`` is the full restorable pytree (params + opt + UDS history).
    ``on_membership(event) -> None`` — membership-change callback (mesh +
    step rebuild for the event's new team; preferred).
    ``on_elastic(new_workers) -> None`` — legacy worker-count-only form,
    used when ``on_membership`` is not given.
    """

    def __init__(self, make_step: Callable, init_state: Callable[[], Any],
                 ckpt_dir: str, *, ckpt_every: int = 10,
                 max_restarts: int = 8,
                 num_hosts: int = 1,
                 injector: Optional[FailureInjector] = None,
                 on_elastic: Optional[Callable[[int], None]] = None,
                 on_membership: Optional[
                     Callable[[MembershipEvent], None]] = None,
                 elastic_after_failures: int = 2):
        self.make_step = make_step
        self.init_state = init_state
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.on_elastic = on_elastic
        self.on_membership = on_membership
        self.elastic_after_failures = elastic_after_failures
        self.num_hosts = num_hosts
        self.mitigator = StragglerMitigator(num_hosts)

    # ------------------------------------------------------------ helpers
    def _flush_ckpt(self) -> None:
        """Settle any in-flight checkpoint commit before acting on a
        failure (the commit thread may itself be the thing that died)."""
        try:
            self.ckpt.wait()
        except RuntimeError:
            pass

    def _requeue_audit(self, step: int, lost: Tuple[int, ...],
                       survivors: int) -> Optional[Dict[str, Any]]:
        """Recover the dead hosts' unfinished token chunks from the last
        share plan's chunk→worker provenance and replan them over the
        surviving team — the no-chunk-silently-lost audit trail.  Must
        run BEFORE ``mitigator.resize`` (resize drops the old plan)."""
        plan = self.mitigator.last_plan
        if plan is None:
            return None           # uniform/no shares live — nothing owned
        new_plan, iters = get_engine().requeue_plan(
            plan, self.mitigator.scheduler, lost_workers=lost,
            num_workers=survivors, history=self.mitigator.history)
        return {
            "step": step,
            "lost": list(lost),
            "survivors": survivors,
            "unfinished_iters": int(len(iters)),
            "ranges": plan.unfinished_ranges(lost),
            "requeued_per_survivor": new_plan.worker_iters().tolist(),
        }

    def _membership_change(self, step: int, lost: Tuple[int, ...],
                           survivors: int, membership: List[MembershipEvent],
                           requeued: List[Dict[str, Any]],
                           elastic: List[Tuple[int, int]]) -> None:
        """The membership replan, in order: requeue audit off the OLD
        plan, resize the mitigator (epoch bump → every cached share plan
        invalidates), then the rebuild callback against the new team."""
        audit = self._requeue_audit(step, lost, survivors)
        if audit is not None:
            requeued.append(audit)
        event = self.mitigator.resize(survivors, lost=lost, step=step)
        self.num_hosts = survivors
        membership.append(event)
        elastic.append((step, survivors))
        if self.on_membership is not None:
            self.on_membership(event)
        elif self.on_elastic is not None:
            self.on_elastic(survivors)

    # ---------------------------------------------------------------- run
    def run(self, total_steps: int) -> SupervisorReport:
        restarts = 0
        restores: List[int] = []
        elastic: List[Tuple[int, int]] = []
        membership: List[MembershipEvent] = []
        requeued: List[Dict[str, Any]] = []
        losses: List[float] = []
        consecutive_failures = 0

        state = None
        step = 0
        steps_since_restore = 0
        last_saved = latest_step(self.ckpt_dir)
        while step < total_steps:
            try:
                if state is None:
                    if latest_step(self.ckpt_dir) is not None:
                        template = self.init_state()
                        state, step, _ = restore_checkpoint(
                            self.ckpt_dir, template)
                        restores.append(step)
                        steps_since_restore = 0
                    else:
                        state = self.init_state()
                        step = 0
                while step < total_steps:
                    if self.injector is not None:
                        self.injector.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.make_step(state, step)
                    dt = time.perf_counter() - t0
                    # per-host timing feed (single-host container: host 0;
                    # multi-host deployments report their own clocks)
                    self.mitigator.observe_step({0: dt})
                    losses.append(float(metrics.get("loss", np.nan)))
                    step += 1
                    steps_since_restore += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, state)
                        last_saved = step
                self.ckpt.wait()
            except WorkerLost as wl:
                # membership change: a worker is GONE, not flaky — restore
                # from the newest checkpoint (no step lost) and replan the
                # whole spine over the surviving team
                restarts += 1
                self._flush_ckpt()
                if restarts > self.max_restarts:
                    raise
                lost = tuple(sorted({int(h) for h in (wl.lost or ())
                                     if 0 <= int(h) < self.num_hosts}))
                if not lost:
                    lost = (self.num_hosts - 1,)
                survivors = max(self.num_hosts - len(lost), 1)
                self._membership_change(step, lost, survivors,
                                        membership, requeued, elastic)
                consecutive_failures = 0
                steps_since_restore = 0
                state = None          # force restore on next iteration
            except RuntimeError:
                restarts += 1
                self._flush_ckpt()
                # failures count as consecutive unless real progress
                # (>= 2 checkpoint periods) happened since the last restore
                if steps_since_restore >= 2 * self.ckpt_every:
                    consecutive_failures = 1
                else:
                    consecutive_failures += 1
                steps_since_restore = 0
                if restarts > self.max_restarts:
                    raise
                if (consecutive_failures >= self.elastic_after_failures
                        and (self.on_elastic is not None
                             or self.on_membership is not None)
                        and self.num_hosts > 1):
                    new_hosts = self.num_hosts // 2
                    lost = tuple(range(new_hosts, self.num_hosts))
                    self._membership_change(step, lost, new_hosts,
                                            membership, requeued, elastic)
                    consecutive_failures = 0
                state = None          # force restore on next iteration
        # final checkpoint: without it, tail steps past the last periodic
        # save (total_steps % ckpt_every != 0) are re-executed by ANY
        # subsequent restore of this directory
        if state is not None and step > 0 and last_saved != step:
            self.ckpt.save(step, state)
        self.ckpt.wait()
        return SupervisorReport(
            steps_completed=step,
            restarts=restarts,
            restores=restores,
            elastic_events=elastic,
            stragglers_flagged=self.mitigator.stragglers(),
            losses=losses,
            membership_events=membership,
            requeued=requeued,
            final_hosts=self.num_hosts,
        )
