"""Fault-tolerant training runtime.

The supervisor owns the restart loop a 1000-node deployment needs:

  * periodic async checkpoints (train loop blocks only for device→host);
  * on failure (device loss, preemption, injected fault) — restore from the
    newest committed checkpoint and continue;
  * on *repeated* failure of the same device set — elastic downsize: rebuild
    the mesh with fewer data shards, reshard the checkpoint onto it, and
    re-plan UDS work assignments for the new worker count (the scheduler's
    ``init`` is simply re-run — paper semantics: start = init + enqueue);
  * straggler mitigation via AWF weights from measured per-host step times
    (sched/straggler.py).

Failures are injected through ``FailureInjector`` in tests/examples — the
supervisor logic is identical for real device errors (RuntimeError from the
runtime surfaces the same way).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.sched.straggler import StragglerMitigator

__all__ = ["FailureInjector", "TrainSupervisor", "SupervisorReport"]


class FailureInjector:
    """Deterministic fault schedule: fail at given steps (once each)."""

    def __init__(self, fail_at: Dict[int, str]):
        self.fail_at = dict(fail_at)        # step -> kind ("transient"|"device")
        self.fired: List[int] = []

    def check(self, step: int) -> None:
        kind = self.fail_at.pop(step, None)
        if kind is not None:
            self.fired.append(step)
            raise RuntimeError(f"injected {kind} failure at step {step}")


@dataclasses.dataclass
class SupervisorReport:
    steps_completed: int
    restarts: int
    restores: List[int]
    elastic_events: List[Tuple[int, int]]    # (step, new_data_shards)
    stragglers_flagged: List[int]
    losses: List[float]


class TrainSupervisor:
    """Drives (state, step) -> state train functions under failures.

    ``make_step(state, step) -> (state, metrics)`` — the compiled step;
    ``state`` is the full restorable pytree (params + opt + UDS history).
    ``on_elastic(new_workers) -> None`` — callback to rebuild mesh/steps.
    """

    def __init__(self, make_step: Callable, init_state: Callable[[], Any],
                 ckpt_dir: str, *, ckpt_every: int = 10,
                 max_restarts: int = 8,
                 num_hosts: int = 1,
                 injector: Optional[FailureInjector] = None,
                 on_elastic: Optional[Callable[[int], None]] = None,
                 elastic_after_failures: int = 2):
        self.make_step = make_step
        self.init_state = init_state
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.on_elastic = on_elastic
        self.elastic_after_failures = elastic_after_failures
        self.mitigator = StragglerMitigator(num_hosts)

    def run(self, total_steps: int) -> SupervisorReport:
        restarts = 0
        restores: List[int] = []
        elastic: List[Tuple[int, int]] = []
        losses: List[float] = []
        consecutive_failures = 0
        num_hosts = self.mitigator.num_hosts

        state = None
        step = 0
        steps_since_restore = 0
        while step < total_steps:
            try:
                if state is None:
                    if latest_step(self.ckpt_dir) is not None:
                        template = self.init_state()
                        state, step, _ = restore_checkpoint(
                            self.ckpt_dir, template)
                        restores.append(step)
                        steps_since_restore = 0
                    else:
                        state = self.init_state()
                        step = 0
                while step < total_steps:
                    if self.injector is not None:
                        self.injector.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.make_step(state, step)
                    dt = time.perf_counter() - t0
                    # per-host timing feed (single-host container: host 0;
                    # multi-host deployments report their own clocks)
                    self.mitigator.observe_step({0: dt})
                    losses.append(float(metrics.get("loss", np.nan)))
                    step += 1
                    steps_since_restore += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, state)
                self.ckpt.wait()
            except RuntimeError:
                restarts += 1
                try:
                    self.ckpt.wait()       # flush any in-flight commit
                except RuntimeError:
                    pass
                # failures count as consecutive unless real progress
                # (>= 2 checkpoint periods) happened since the last restore
                if steps_since_restore >= 2 * self.ckpt_every:
                    consecutive_failures = 1
                else:
                    consecutive_failures += 1
                steps_since_restore = 0
                if restarts > self.max_restarts:
                    raise
                if (consecutive_failures >= self.elastic_after_failures
                        and self.on_elastic is not None and num_hosts > 1):
                    num_hosts //= 2
                    self.on_elastic(num_hosts)
                    elastic.append((step, num_hosts))
                    consecutive_failures = 0
                state = None          # force restore on next iteration
        self.ckpt.wait()
        return SupervisorReport(
            steps_completed=step,
            restarts=restarts,
            restores=restores,
            elastic_events=elastic,
            stragglers_flagged=self.mitigator.stragglers(),
            losses=losses,
        )
