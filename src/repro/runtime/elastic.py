"""Elastic scaling: rebuild mesh + steps + UDS plans for a new worker count.

On losing a slice, the healthy device set no longer matches the production
mesh; this module picks the largest (data', model) factorization that fits,
reshards the restored checkpoint (checkpoint/ restores host-side and
device_puts with the new shardings), and re-plans all UDS schedules for
data' workers — scheduler ``init`` is re-run with the new team size, which
is exactly the paper's contract (start = init + enqueue for the *current*
team).
"""

from __future__ import annotations

from typing import Optional, Tuple


from repro.launch.mesh import make_mesh

__all__ = ["plan_degraded_mesh", "rebuild"]


def plan_degraded_mesh(healthy_devices: int, model_parallel: int,
                       pod_axis: bool = False) -> Tuple[int, ...]:
    """Largest mesh shape (data, model) [or (pod, data, model)] that fits
    the healthy device count while preserving model-parallel degree (model
    sharding cannot shrink without resharding weights *within* a layer)."""
    if healthy_devices < model_parallel:
        raise ValueError(
            f"{healthy_devices} healthy devices cannot sustain "
            f"model_parallel={model_parallel}")
    data = healthy_devices // model_parallel
    # power-of-two data degree keeps batch divisibility stable
    d = 1
    while d * 2 <= data:
        d *= 2
    if pod_axis and d >= 2:
        return (2, d // 2, model_parallel)
    return (d, model_parallel)


def rebuild(healthy_devices: int, model_parallel: int,
            axes: Optional[Tuple[str, ...]] = None):
    """Mesh for the degraded fleet. Caller re-derives rules/shardings and
    re-jits steps against it (see examples/fault_tolerant_train.py)."""
    shape = plan_degraded_mesh(healthy_devices, model_parallel)
    axes = axes or (("data", "model") if len(shape) == 2
                    else ("pod", "data", "model"))
    return make_mesh(shape, axes)
