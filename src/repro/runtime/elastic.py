"""Elastic scaling: rebuild mesh + steps + UDS plans for a new worker count.

On losing a slice, the healthy device set no longer matches the production
mesh; this module picks the largest (data', model) factorization that fits,
reshards the restored checkpoint (checkpoint/ restores host-side and
device_puts with the new shardings), and re-plans all UDS schedules for
data' workers — scheduler ``init`` is re-run with the new team size, which
is exactly the paper's contract (start = init + enqueue for the *current*
team).

Capacity loss is never silent: a degraded shape that idles healthy devices
(non-power-of-two survivors) or drops a requested pod axis warns with the
exact count, so operators see what the downsize costs.
"""

from __future__ import annotations

import math
import warnings
from typing import Optional, Tuple


from repro.launch.mesh import make_mesh

__all__ = ["idle_devices", "plan_degraded_mesh", "rebuild"]


def idle_devices(healthy_devices: int, shape: Tuple[int, ...]) -> int:
    """Healthy devices a degraded mesh shape leaves unused."""
    return int(healthy_devices) - math.prod(shape)


def plan_degraded_mesh(healthy_devices: int, model_parallel: int,
                       pod_axis: bool = False) -> Tuple[int, ...]:
    """Largest mesh shape (data, model) [or (pod, data, model)] that fits
    the healthy device count while preserving model-parallel degree (model
    sharding cannot shrink without resharding weights *within* a layer).

    The data degree is rounded DOWN to a power of two (keeps batch
    divisibility stable across successive downsizes); when that rounding
    — or a remainder under ``model_parallel`` — idles healthy devices, a
    ``RuntimeWarning`` reports exactly how many, and a ``pod_axis``
    request that cannot be honored (fewer than 2 data shards) warns that
    the axis was dropped instead of silently returning a 2-D shape.
    """
    if healthy_devices < model_parallel:
        raise ValueError(
            f"{healthy_devices} healthy devices cannot sustain "
            f"model_parallel={model_parallel}")
    data = healthy_devices // model_parallel
    # power-of-two data degree keeps batch divisibility stable
    d = 1
    while d * 2 <= data:
        d *= 2
    if pod_axis and d < 2:
        warnings.warn(
            f"pod_axis requested but only {d} data shard(s) fit "
            f"{healthy_devices} healthy devices at "
            f"model_parallel={model_parallel}; the pod axis was dropped",
            RuntimeWarning, stacklevel=2)
    shape = ((2, d // 2, model_parallel) if pod_axis and d >= 2
             else (d, model_parallel))
    idle = idle_devices(healthy_devices, shape)
    if idle:
        warnings.warn(
            f"degraded mesh {shape} idles {idle} of {healthy_devices} "
            f"healthy devices (data degree rounded down to the largest "
            f"power of two, {d}, at model_parallel={model_parallel})",
            RuntimeWarning, stacklevel=2)
    return shape


def rebuild(healthy_devices: int, model_parallel: int,
            axes: Optional[Tuple[str, ...]] = None,
            pod_axis: bool = False):
    """Mesh for the degraded fleet. Caller re-derives rules/shardings and
    re-jits steps against it (see examples/fault_tolerant_train.py)."""
    shape = plan_degraded_mesh(healthy_devices, model_parallel,
                               pod_axis=pod_axis)
    axes = axes or (("data", "model") if len(shape) == 2
                    else ("pod", "data", "model"))
    return make_mesh(shape, axes)
