from repro.runtime.fault_tolerance import (
    FailureInjector,
    SupervisorReport,
    TrainSupervisor,
)
from repro.runtime.elastic import plan_degraded_mesh, rebuild

__all__ = ["FailureInjector", "TrainSupervisor", "SupervisorReport",
           "plan_degraded_mesh", "rebuild"]
