from repro.core import MembershipEvent
from repro.runtime.fault_tolerance import (
    FailureInjector,
    SupervisorReport,
    TrainSupervisor,
    WorkerLost,
)
from repro.runtime.elastic import idle_devices, plan_degraded_mesh, rebuild

__all__ = ["FailureInjector", "MembershipEvent", "SupervisorReport",
           "TrainSupervisor", "WorkerLost", "idle_devices",
           "plan_degraded_mesh", "rebuild"]
