"""Classic (non-adaptive) scheduling strategies.

Covers the OpenMP built-ins the paper uses as its baseline —
``schedule(static[,chunk])``, ``schedule(dynamic[,chunk])``,
``schedule(guided[,chunk])`` — plus the literature strategies the paper
cites as motivation: trapezoid self-scheduling (TSS) [Tzen & Ni 1993],
fixed-size chunking (FSC) [Kruskal & Weiss 1985], RAND [Ciorba et al. 2018],
and Intel-style static stealing.

All chunk-size formulas follow the published closed forms; tests in
``tests/test_schedulers.py`` assert the sequences match.
"""

from __future__ import annotations

import math
from types import SimpleNamespace
from typing import Any, Optional

import numpy as np

from repro.core.interface import Chunk, SchedulerContext, ceil_div
from repro.core.schedulers.base import CentralQueueSchedule, SixOpBase

__all__ = [
    "StaticChunk",
    "StaticBlock",
    "StaticCyclic",
    "SelfScheduling",
    "GuidedSS",
    "TrapezoidSS",
    "RandSS",
    "FixedSizeChunking",
    "StaticStealing",
]


class StaticChunk(SixOpBase):
    """OpenMP ``schedule(static, chunk)``: chunks of ``chunk`` iterations are
    assigned round-robin to threads *before* execution; each thread walks its
    own private counter by ``P * chunk`` (exactly the paper's Fig. 2
    ``mystatic`` example — thread-private ``next_lb[tid]``)."""

    name = "static"

    def __init__(self, chunk: Optional[int] = None):
        self.chunk = chunk

    def init(self, ctx: SchedulerContext) -> Any:
        n = ctx.loop.trip_count
        p = ctx.loop.num_workers
        chunk = self.chunk or ctx.loop.chunk or ceil_div(max(n, 1), p)
        return SimpleNamespace(
            ctx=ctx, n=n, p=p, chunk=chunk,
            next_lb=[w * chunk for w in range(p)],  # Fig. 2: lb + tid*chunksz
        )

    def dequeue(self, state: Any, worker: int) -> Optional[Chunk]:
        lo = state.next_lb[worker]
        if lo >= state.n:
            return None                      # Fig. 2: "return 0"
        hi = min(lo + state.chunk, state.n)
        state.next_lb[worker] = lo + state.p * state.chunk
        return Chunk(lo, hi, worker)


class StaticBlock(StaticChunk):
    """OpenMP ``schedule(static)``: one block of ceil(N/P) per thread."""

    name = "static_block"
    spec_chunk_param = None

    def __init__(self):
        super().__init__(chunk=None)


class StaticCyclic(StaticChunk):
    """``schedule(static, 1)``: iteration i -> thread i mod P."""

    name = "static_cyclic"
    spec_chunk_param = None

    def __init__(self):
        super().__init__(chunk=1)


class SelfScheduling(CentralQueueSchedule):
    """OpenMP ``schedule(dynamic, chunk)``; chunk=1 is pure self-scheduling
    (PSS/SS) [Tang & Yew 1986]."""

    name = "dynamic"

    def __init__(self, chunk: int = 1):
        self.chunk = chunk

    def chunk_size(self, state: Any, worker: int) -> int:
        return self.chunk or state.ctx.loop.chunk or 1


class GuidedSS(CentralQueueSchedule):
    """OpenMP ``schedule(guided, chunk)`` = guided self-scheduling (GSS)
    [Polychronopoulos & Kuck 1987]: next chunk = ceil(R / P), bounded below
    by the ``chunk`` parameter (except possibly the last chunk)."""

    name = "guided"

    def __init__(self, chunk: int = 1):
        self.min_chunk = max(1, chunk)

    def chunk_size(self, state: Any, worker: int) -> int:
        p = state.ctx.loop.num_workers
        size = ceil_div(state.remaining, p)
        return max(self.min_chunk, size)


class TrapezoidSS(CentralQueueSchedule):
    """Trapezoid self-scheduling (TSS) [Tzen & Ni 1993].

    Chunk sizes decrease *linearly* from ``first`` to ``last``:
        n_steps = ceil(2N / (first + last))
        delta   = (first - last) / (n_steps - 1)
        chunk_k = first - k * delta            (k = dequeue index)
    Defaults: first = ceil(N / 2P), last = 1 (the paper's recommendation).
    """

    name = "tss"
    spec_chunk_param = None

    def __init__(self, first: Optional[int] = None, last: int = 1):
        self.first = first
        self.last = last

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        n, p = state.n, ctx.loop.num_workers
        first = self.first if self.first is not None else ceil_div(n, 2 * p)
        first = max(first, 1)
        last = max(min(self.last, first), 1)
        steps = max(ceil_div(2 * n, first + last), 1)
        delta = (first - last) / (steps - 1) if steps > 1 else 0.0
        state.scratch.update(first=first, last=last, delta=delta)
        return state

    def chunk_size(self, state: Any, worker: int) -> int:
        s = state.scratch
        size = s["first"] - state.dequeues * s["delta"]
        return max(int(math.floor(size + 0.5)), s["last"])


class RandSS(CentralQueueSchedule):
    """RAND [Ciorba, Iwainsky & Buder 2018]: chunk drawn uniformly at random
    from [min_chunk, max_chunk]; defaults [1, ceil(N/P)] as in LaPeSD
    libGOMP.  Deterministic under ``seed`` (required for SPMD replay)."""

    name = "rand"
    spec_chunk_param = "min_chunk"

    def __init__(self, min_chunk: int = 1, max_chunk: Optional[int] = None,
                 seed: int = 0):
        self.min_chunk = max(1, min_chunk)
        self.max_chunk = max_chunk
        self.seed = seed

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        hi = self.max_chunk or ceil_div(max(state.n, 1),
                                        ctx.loop.num_workers)
        state.scratch.update(
            rng=np.random.default_rng(self.seed),
            lo=self.min_chunk,
            hi=max(hi, self.min_chunk),
        )
        return state

    def chunk_size(self, state: Any, worker: int) -> int:
        s = state.scratch
        return int(s["rng"].integers(s["lo"], s["hi"] + 1))


class FixedSizeChunking(CentralQueueSchedule):
    """FSC [Kruskal & Weiss 1985] — the optimal *fixed* chunk under iid
    iteration times with scheduling overhead h and iteration-time std σ:

        chunk = ( sqrt(2) * N * h / (sigma * P * sqrt(log P)) )^(2/3)

    (Intel's "static stealing with fixed-size chunks" descends from this.)
    Falls back to ceil(N/P)/2 when P == 1 or sigma == 0.
    """

    name = "fsc"
    spec_chunk_param = None

    def __init__(self, overhead: float = 1e-5, sigma: float = 1e-4):
        self.h = overhead
        self.sigma = sigma

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        n, p = state.n, ctx.loop.num_workers
        if p > 1 and self.sigma > 0 and n > 0:
            num = math.sqrt(2.0) * n * self.h
            den = self.sigma * p * math.sqrt(math.log(p))
            chunk = int(math.ceil((num / den) ** (2.0 / 3.0)))
        else:
            chunk = ceil_div(max(n, 1), max(2 * p, 1))
        state.scratch["chunk"] = max(1, chunk)
        return state

    def chunk_size(self, state: Any, worker: int) -> int:
        return state.scratch["chunk"]


class TrapezoidFactoring(CentralQueueSchedule):
    """TFSS (trapezoid factoring self-scheduling): TSS's linear decrement
    applied per *batch* of P equal chunks (factoring cadence) — the hybrid
    from the DLS literature the paper's taxonomy covers."""

    name = "tfss"
    spec_chunk_param = None

    def __init__(self, first: Optional[int] = None, last: int = 1):
        self.first = first
        self.last = last

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        n, p = state.n, ctx.loop.num_workers
        first = self.first if self.first is not None else ceil_div(n, 2 * p)
        first = max(first, 1)
        last = max(min(self.last, first), 1)
        steps = max(ceil_div(2 * n, first + last), 1)
        delta = (first - last) / (steps - 1) if steps > 1 else 0.0
        state.scratch.update(first=float(first), last=last, delta=delta,
                             batch_left=0, batch_chunk=first)
        return state

    def chunk_size(self, state: Any, worker: int) -> int:
        s = state.scratch
        if s["batch_left"] <= 0:
            s["batch_chunk"] = max(int(math.floor(s["first"] + 0.5)),
                                   s["last"])
            s["first"] = max(s["first"] - s["delta"], float(s["last"]))
            s["batch_left"] = state.ctx.loop.num_workers
        s["batch_left"] -= 1
        return s["batch_chunk"]


class Taper(CentralQueueSchedule):
    """TAPER [Lucco 1992]: self-scheduling with a variance-based taper —
    chunk ~= R/P shrunk by v*sqrt(chunk) where v = alpha * sigma/mu.
    Non-adaptive variant: (mu, sigma) are user-supplied estimates."""

    name = "taper"
    spec_chunk_param = "min_chunk"

    def __init__(self, mu: float = 1.0, sigma: float = 0.0,
                 alpha: float = 1.3, min_chunk: int = 1):
        self.v = alpha * (sigma / mu) if mu > 0 else 0.0
        self.min_chunk = max(1, min_chunk)

    def chunk_size(self, state: Any, worker: int) -> int:
        p = state.ctx.loop.num_workers
        t = state.remaining / p
        if self.v <= 0:
            return max(self.min_chunk, ceil_div(state.remaining, p))
        x = t + self.v * self.v / 2.0 - self.v * math.sqrt(2.0 * t
                                                           + self.v * self.v / 4.0)
        return max(self.min_chunk, int(math.ceil(x)))


class StaticStealing(SixOpBase):
    """Intel-style static stealing: iterations are pre-split into P private
    blocks (as ``schedule(static)``); a worker dequeues ``chunk`` iterations
    from its own block head, and when its block is exhausted it *steals the
    trailing half* of the largest remaining victim block (receiver-initiated
    load balancing without a central counter)."""

    name = "static_steal"

    def __init__(self, chunk: int = 1):
        self.chunk = max(1, chunk)

    def init(self, ctx: SchedulerContext) -> Any:
        n = ctx.loop.trip_count
        p = ctx.loop.num_workers
        block = ceil_div(max(n, 1), p)
        blocks = []
        for w in range(p):
            lo = min(w * block, n)
            hi = min(lo + block, n)
            blocks.append([lo, hi])  # mutable [head, tail)
        return SimpleNamespace(ctx=ctx, n=n, p=p, blocks=blocks)

    def dequeue(self, state: Any, worker: int) -> Optional[Chunk]:
        blk = state.blocks[worker]
        if blk[0] >= blk[1]:
            if not self._steal(state, worker):
                return None
            blk = state.blocks[worker]
        hi = min(blk[0] + self.chunk, blk[1])
        chunk = Chunk(blk[0], hi, worker)
        blk[0] = hi
        return chunk

    def _steal(self, state: Any, thief: int) -> bool:
        victim, best = -1, 0
        for w, (lo, hi) in enumerate(state.blocks):
            if w != thief and hi - lo > best:
                victim, best = w, hi - lo
        if victim < 0 or best < 1:
            return False
        vlo, vhi = state.blocks[victim]
        split = vhi - (vhi - vlo) // 2 if best > 1 else vlo
        # thief takes the trailing half [split, vhi)
        state.blocks[victim][1] = split
        state.blocks[thief] = [split, vhi]
        return split < vhi
