"""The scheduler library — every strategy from the paper's literature set,
each expressed through the UDS six-op/three-op interface."""

from repro.core.schedulers.base import CentralQueueSchedule, SixOpBase, as_three_op
from repro.core.schedulers.classic import (
    FixedSizeChunking,
    GuidedSS,
    RandSS,
    SelfScheduling,
    StaticBlock,
    StaticChunk,
    StaticCyclic,
    StaticStealing,
    Taper,
    TrapezoidFactoring,
    TrapezoidSS,
)
from repro.core.schedulers.factoring import AF, AWF, FAC, FAC2, WeightedFactoring

from typing import Any, Callable, Dict

__all__ = [
    "SixOpBase", "CentralQueueSchedule", "as_three_op",
    "StaticChunk", "StaticBlock", "StaticCyclic", "SelfScheduling",
    "GuidedSS", "TrapezoidSS", "TrapezoidFactoring", "Taper", "RandSS",
    "FixedSizeChunking", "StaticStealing", "FAC", "FAC2",
    "WeightedFactoring", "AWF", "AF",
    "SCHEDULER_FACTORIES", "make_scheduler",
]

# Factory registry: the framework-facing way to choose a strategy by name
# (what a config file's ``scheduler: fac2`` resolves through).
SCHEDULER_FACTORIES: Dict[str, Callable[..., Any]] = {
    "static": StaticChunk,
    "static_block": StaticBlock,
    "static_cyclic": StaticCyclic,
    "dynamic": SelfScheduling,
    "ss": SelfScheduling,
    "guided": GuidedSS,
    "gss": GuidedSS,
    "tss": TrapezoidSS,
    "tfss": TrapezoidFactoring,
    "taper": Taper,
    "rand": RandSS,
    "fsc": FixedSizeChunking,
    "static_steal": StaticStealing,
    "fac": FAC,
    "fac2": FAC2,
    "wf2": WeightedFactoring,
    "awf": AWF,
    "awf_b": lambda **kw: AWF(variant="B", **kw),
    "awf_c": lambda **kw: AWF(variant="C", **kw),
    "awf_d": lambda **kw: AWF(variant="D", **kw),
    "awf_e": lambda **kw: AWF(variant="E", **kw),
    "af": AF,
}


def make_scheduler(name: str, **params: Any):
    if name not in SCHEDULER_FACTORIES:
        raise KeyError(f"unknown scheduler {name!r}; "
                       f"known: {sorted(SCHEDULER_FACTORIES)}")
    return SCHEDULER_FACTORIES[name](**params)
