"""The scheduler library — every strategy from the paper's literature set,
each expressed through the UDS six-op/three-op interface."""

from repro.core.schedulers.base import CentralQueueSchedule, SixOpBase, as_three_op
from repro.core.schedulers.classic import (
    FixedSizeChunking,
    GuidedSS,
    RandSS,
    SelfScheduling,
    StaticBlock,
    StaticChunk,
    StaticCyclic,
    StaticStealing,
    Taper,
    TrapezoidFactoring,
    TrapezoidSS,
)
from repro.core.schedulers.factoring import AF, AWF, FAC, FAC2, WeightedFactoring

from typing import Any, Callable, Dict

__all__ = [
    "SixOpBase", "CentralQueueSchedule", "as_three_op",
    "StaticChunk", "StaticBlock", "StaticCyclic", "SelfScheduling",
    "GuidedSS", "TrapezoidSS", "TrapezoidFactoring", "Taper", "RandSS",
    "FixedSizeChunking", "StaticStealing", "FAC", "FAC2",
    "WeightedFactoring", "AWF", "AF",
    "SCHEDULER_FACTORIES", "make_scheduler",
]

# Builtin factory table.  This is ABSORBED into the unified ScheduleSpec
# registry (``repro.core.spec``) at import time; it is kept as a module
# attribute only for backward compatibility — new code selects strategies
# through ``repro.core.spec.resolve`` / ``@register_schedule``.
SCHEDULER_FACTORIES: Dict[str, Callable[..., Any]] = {
    "static": StaticChunk,
    "static_block": StaticBlock,
    "static_cyclic": StaticCyclic,
    "dynamic": SelfScheduling,
    "ss": SelfScheduling,
    "guided": GuidedSS,
    "gss": GuidedSS,
    "tss": TrapezoidSS,
    "tfss": TrapezoidFactoring,
    "taper": Taper,
    "rand": RandSS,
    "fsc": FixedSizeChunking,
    "static_steal": StaticStealing,
    "fac": FAC,
    "fac2": FAC2,
    "wf2": WeightedFactoring,
    "awf": AWF,
    "awf_b": lambda **kw: AWF(variant="B", **kw),
    "awf_c": lambda **kw: AWF(variant="C", **kw),
    "awf_d": lambda **kw: AWF(variant="D", **kw),
    "awf_e": lambda **kw: AWF(variant="E", **kw),
    "af": AF,
}


def make_scheduler(name: str, **params: Any):
    """DEPRECATED shim — use ``repro.core.spec.resolve`` instead.

    Delegates to the unified ScheduleSpec registry: unknown names raise a
    ``KeyError`` listing every registered schedule (builtins AND
    declare-/lambda-style UDS registrations), and clause-expressible
    parameters gain a spec identity so their plans share the engine cache
    with clause-string selections (``make_scheduler("guided", chunk=4)``
    and ``resolve("guided,4")`` hit the same cached plan).  Spec
    validation applies on that path (e.g. ``chunk`` must be >= 1);
    parameters the clause cannot express (arbitrary objects) construct
    directly with no spec identity.
    """
    from repro.core import spec as _spec
    entry = _spec.lookup(name)          # rich unknown-name error

    def clause_expressible(v: Any) -> bool:
        if isinstance(v, (dict, list, tuple)):
            return all(isinstance(x, (int, float)) for x in
                       (v.values() if isinstance(v, dict) else v))
        return v is None or isinstance(v, (bool, int, float, str))

    if all(clause_expressible(v) for v in params.values()):
        return _spec.resolve(_spec.ScheduleSpec.make(name, **params))
    return entry.factory(**params)
