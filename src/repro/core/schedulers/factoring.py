"""Factoring-family strategies: FAC, FAC2, WF/WF2, AWF (+B/C/D/E), AF.

These are the probabilistically-derived and adaptive strategies the paper
identifies as *impossible to support* in current OpenMP without UDS:

* FAC  [Flynn Hummel, Schonberg & Flynn 1992] — batches sized from the
  mean/std of iteration times.
* FAC2 — the practical variant: each batch assigns half the remaining
  iterations split evenly over P workers.
* WF/WF2 [Flynn Hummel, Schmidt, Uma & Wein 1996] — factoring with fixed
  per-worker capability weights (heterogeneous hardware).
* AWF [Banicescu, Velusamy & Devaprasad 2003] — weights adapted across
  loop *invocations* (timesteps) via the history object.
* AWF-B/C/D/E [Ciorba et al. taxonomy] — weights adapted *within* an
  invocation at batch (B, D) or chunk (C, E) boundaries; D/E include
  scheduling overhead in the measured rate.
* AF  [Banicescu & Liu 2000] — fully adaptive: per-worker mean μ_i and
  variance σ_i² of iteration time drive per-worker chunk sizes.

Type-(3) strategies consume measurements ONLY through the paper's
begin/end hooks + history object — no side channels.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

from repro.core.interface import SchedulerContext, ceil_div
from repro.core.schedulers.base import CentralQueueSchedule

__all__ = ["FAC", "FAC2", "WeightedFactoring", "AWF", "AF"]


class FAC2(CentralQueueSchedule):
    """FAC2: batch j hands out P chunks of ceil(R_j / (2P)); i.e. each batch
    schedules half of what remained when the batch opened."""

    name = "fac2"
    spec_chunk_param = None

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        state.scratch.update(batch_left=0, batch_chunk=1)
        return state

    def _open_batch(self, state: Any) -> None:
        p = state.ctx.loop.num_workers
        state.scratch["batch_chunk"] = max(1, ceil_div(state.remaining, 2 * p))
        state.scratch["batch_left"] = p

    def chunk_size(self, state: Any, worker: int) -> int:
        if state.scratch["batch_left"] <= 0:
            self._open_batch(state)
        state.scratch["batch_left"] -= 1
        return self._worker_chunk(state, worker)

    def _worker_chunk(self, state: Any, worker: int) -> int:
        return state.scratch["batch_chunk"]


class FAC(FAC2):
    """Probabilistic factoring (FAC): with per-iteration mean μ and std σ,
    batch factor x = 1 + b² + b·sqrt(b² + 2), b = (P / (2·sqrt(R))) · (σ/μ);
    batch chunk = ceil(R / (x · P)).  Degenerates to FAC2 (x = 2) when
    σ/μ → 0 is *not* the case — FAC2 fixes x = 2 by construction."""

    name = "fac"

    def __init__(self, mu: float = 1.0, sigma: float = 0.0):
        self.mu = mu
        self.sigma = sigma

    def _open_batch(self, state: Any) -> None:
        p = state.ctx.loop.num_workers
        r = max(state.remaining, 1)
        if self.sigma <= 0 or self.mu <= 0:
            x = 2.0
        else:
            b = (p / (2.0 * math.sqrt(r))) * (self.sigma / self.mu)
            x = 1.0 + b * b + b * math.sqrt(b * b + 2.0)
        state.scratch["batch_chunk"] = max(1, ceil_div(r, max(1, round(x * p))))
        state.scratch["batch_left"] = p


class WeightedFactoring(FAC2):
    """WF2: FAC2 batches with per-worker weights w_i (sum ≈ P):
    chunk_i = round(w_i · batch_chunk).  Weights come from the scheduler
    argument or, if absent, from ``ctx.weights`` (e.g. hardware capability
    of a heterogeneous mesh)."""

    name = "wf2"

    def __init__(self, weights: Optional[Dict[int, float]] = None):
        self.weights = weights

    def _weight(self, state: Any, worker: int) -> float:
        if self.weights is not None:
            return float(self.weights.get(worker, 1.0))
        w = state.ctx.weights
        if w is not None and worker < len(w):
            return float(w[worker])
        return 1.0

    def _worker_chunk(self, state: Any, worker: int) -> int:
        base = state.scratch["batch_chunk"]
        return max(1, int(round(self._weight(state, worker) * base)))


class AWF(WeightedFactoring):
    """Adaptive weighted factoring.

    variant="timestep" (classic AWF): weights are recomputed once per loop
    *invocation* from the history object (ratio of measured per-worker
    speeds over previous invocations) — the paper's flagship example of why
    cross-invocation history must be part of the interface.

    variant="B"/"C"/"D"/"E": weights adapt *within* the invocation from the
    measurements delivered via the end-loop-body hook:
      B — recompute at batch boundaries, rate = compute time / iterations
      C — recompute at every chunk,     rate = compute time / iterations
      D — as B but rate includes per-chunk scheduling overhead ``h``
      E — as C but rate includes ``h``
    """

    adaptive = True

    name = "awf"

    def __init__(self, variant: str = "timestep", overhead: float = 0.0):
        super().__init__(weights=None)
        variant = variant.upper() if variant != "timestep" else variant
        if variant not in ("timestep", "B", "C", "D", "E"):
            raise ValueError(f"unknown AWF variant: {variant}")
        self.variant = variant
        self.h = overhead
        self.name = "awf" if variant == "timestep" else f"awf_{variant.lower()}"

    # ------------------------------------------------------------------
    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        p = ctx.loop.num_workers
        if self.variant == "timestep" and ctx.history is not None:
            w = ctx.history.awf_weights(ctx.loop.loop_id, p)
        else:
            w = [1.0] * p
        if ctx.weights is not None and max(abs(x - 1.0) for x in w) < 1e-12:
            # cold start (no usable history): seed from the caller's
            # capability weights, exactly as WF2 would — measurements
            # take over from the first recorded invocation onward
            w = [float(ctx.weights[i]) if i < len(ctx.weights) else 1.0
                 for i in range(p)]
        state.scratch.update(
            aw=list(w),                     # current weights (sum ~= P)
            time=[0.0] * p,                 # cumulative measured time
            iters=[0] * p,                  # cumulative measured iterations
            nchunks=[0] * p,                # chunks completed (for overhead)
        )
        return state

    def observe(self, state: Any, worker: int, chunk, elapsed: float) -> None:
        s = state.scratch
        s["time"][worker] += elapsed + (self.h if self.variant in ("D", "E") else 0.0)
        s["iters"][worker] += chunk.size
        s["nchunks"][worker] += 1
        if self.variant in ("C", "E"):
            self._recompute_weights(state)

    def _open_batch(self, state: Any) -> None:
        if self.variant in ("B", "D"):
            self._recompute_weights(state)
        super()._open_batch(state)

    def _recompute_weights(self, state: Any) -> None:
        s = state.scratch
        p = state.ctx.loop.num_workers
        rates = []
        for w in range(p):
            if s["iters"][w] > 0 and s["time"][w] > 0:
                rates.append(s["time"][w] / s["iters"][w])   # sec/iter
            else:
                rates.append(None)
        known = [r for r in rates if r]
        if not known:
            return
        mean_rate = sum(known) / len(known)
        speeds = [1.0 / (r if r else mean_rate) for r in rates]
        total = sum(speeds)
        s["aw"] = [p * sp / total for sp in speeds]

    def _weight(self, state: Any, worker: int) -> float:
        return state.scratch["aw"][worker]


class AF(CentralQueueSchedule):
    """Adaptive factoring [Banicescu & Liu 2000].

    Maintains running per-worker mean μ_i and variance σ_i² of the
    *per-iteration* execution time (Welford), fed exclusively by the
    end-loop-body hook.  Chunk for worker i with R iterations remaining:

        D = Σ_j σ_j² / μ_j          (total variance-to-mean, seconds)
        E = Σ_j 1 / μ_j             (aggregate speed, iterations/second)
        T = R / (2·E)               (factoring half-horizon, seconds)
        chunk_i = (D + 2T − sqrt(D² + 4·D·T)) / (2 μ_i)

    As σ → 0 this converges to T/μ_i — each worker's *half* proportional
    share, i.e. FAC2 weighted by measured speed (hence adaptive
    *factoring*) — and finite variance hands out smaller, variance-hedged
    chunks (the σ-dependent discount).  Until a worker has ≥ ``warmup``
    measured chunks it falls back to FAC2-sized chunks.

    NOTE: the host paper cites AF [5] without formulas; this is the standard
    formulation used by DLS/LB4OMP-style libraries, documented here because
    the exact constant conventions differ across presentations.
    """

    adaptive = True

    name = "af"
    spec_chunk_param = None

    def __init__(self, warmup: int = 1):
        self.warmup = warmup

    def init(self, ctx: SchedulerContext) -> Any:
        state = super().init(ctx)
        p = ctx.loop.num_workers
        state.scratch.update(
            count=[0] * p,    # Welford per-worker
            mean=[0.0] * p,
            m2=[0.0] * p,
            measured=[0] * p,  # chunks measured
        )
        return state

    def observe(self, state: Any, worker: int, chunk, elapsed: float) -> None:
        if chunk.size <= 0:
            return
        rate = elapsed / chunk.size
        s = state.scratch
        s["measured"][worker] += 1
        s["count"][worker] += 1
        d = rate - s["mean"][worker]
        s["mean"][worker] += d / s["count"][worker]
        s["m2"][worker] += d * (rate - s["mean"][worker])

    def chunk_size(self, state: Any, worker: int) -> int:
        s = state.scratch
        p = state.ctx.loop.num_workers
        ready = [w for w in range(p)
                 if s["measured"][w] >= self.warmup and s["mean"][w] > 0]
        if worker not in ready or len(ready) < max(1, p // 2):
            # insufficient statistics -> FAC2-style fallback
            return max(1, ceil_div(state.remaining, 2 * p))
        D = sum((s["m2"][w] / max(s["count"][w], 1)) / s["mean"][w]
                for w in ready)
        E = sum(1.0 / s["mean"][w] for w in ready)
        if E <= 0:
            return max(1, ceil_div(state.remaining, 2 * p))
        T = 0.5 * state.remaining / E        # factoring half-horizon
        mu_i = s["mean"][worker]
        size = (D + 2.0 * T - math.sqrt(D * D + 4.0 * D * T)) / (2.0 * mu_i)
        return max(1, int(size))
