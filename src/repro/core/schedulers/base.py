"""Scheduler base machinery.

Every scheduler in this library is written in the paper's **six-operation**
form (init / enqueue / dequeue / finalize / begin-loop-body / end-loop-body)
and exposed through the reduced **three-operation** interface via the
``three_op_from_six`` merge — the code path itself demonstrates the paper's
reduction claim.

The conceptual todo list is "typically implemented as a set of shared or
thread-private loop counters" (paper §4); ``CentralQueueSchedule`` is the
shared-counter form (self-scheduling family), while static and stealing
schedulers use thread-private counters (paper Fig. 2 style).
"""

from __future__ import annotations

from types import SimpleNamespace
from typing import Any, Optional

from repro.core.interface import Chunk, SchedulerContext, three_op_from_six
from repro.core.history import ChunkRecord

__all__ = ["SixOpBase", "CentralQueueSchedule", "as_three_op"]


class SixOpBase:
    """Common six-op plumbing: measurement hooks write ChunkRecords into the
    context's history object (paper §3: the begin/end operations exist to feed
    the history mechanism).

    ``adaptive`` marks type-(3) strategies whose ``start`` consults the
    cross-invocation history: the plan engine includes the history epoch in
    their cache key so new measurements invalidate cached plans.  Custom
    history-reading schedulers must set it, or their plans may be served
    stale from the cache.

    ``spec_chunk_param`` names the constructor keyword a schedule-clause
    chunksize (``"name,N"``) maps to — the knowledge lives with the class
    so the unified registry never guesses.  ``None`` means the strategy
    takes no chunksize and the clause form is rejected.
    """

    name: str = "uds"
    adaptive: bool = False
    spec_chunk_param: Optional[str] = "chunk"

    # -- operations subclasses typically override -------------------------
    def init(self, ctx: SchedulerContext) -> Any:
        raise NotImplementedError

    def enqueue(self, state: Any) -> None:
        # Iteration space is fixed before execution (OpenMP), so the todo
        # list is "conceptually completely filled" here; counter-based
        # schedulers have nothing to materialize.
        return None

    def dequeue(self, state: Any, worker: int) -> Optional[Chunk]:
        raise NotImplementedError

    # -- measurement hooks (type-(3) adaptive support) ---------------------
    def begin_loop_body(self, state: Any, worker: int, chunk: Chunk) -> Any:
        return None

    def end_loop_body(self, state: Any, worker: int, chunk: Chunk,
                      token: Any, elapsed: Optional[float]) -> None:
        if elapsed is not None:
            self.observe(state, worker, chunk, elapsed)
        tel = getattr(state.ctx, "telemetry", None)
        if tel is not None:
            # telemetry buffers and flushes at invocation end — one history
            # epoch bump per invocation instead of per chunk
            tel.observe_chunk(worker, chunk, elapsed)
            return
        hist = state.ctx.history
        if hist is not None:
            hist.record(
                state.ctx.loop.loop_id,
                ChunkRecord(worker=worker, start=chunk.start, stop=chunk.stop,
                            elapsed=elapsed),
            )

    def observe(self, state: Any, worker: int, chunk: Chunk,
                elapsed: float) -> None:
        """Adaptive schedulers override to ingest a measurement."""
        return None

    def finalize(self, state: Any) -> None:
        return None

    # -- reduced three-op interface (paper's merge) ------------------------
    # Provided so callers can use any scheduler directly as a
    # UserDefinedSchedule without wrapping at every call site.
    def start(self, ctx: SchedulerContext) -> Any:
        self._adapter = three_op_from_six(self)
        return self._adapter.start(ctx)

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        return self._adapter.next(state, worker, elapsed)

    def finish(self, state: Any) -> None:
        self._adapter.finish(state)


class CentralQueueSchedule(SixOpBase):
    """Shared-counter self-scheduling base: each ``dequeue`` grabs the next
    ``chunk_size(...)`` logical iterations from a central counter
    (receiver-initiated load balancing, paper §2).

    Subclasses implement ``chunk_size(state, worker) -> int``.
    """

    def init(self, ctx: SchedulerContext) -> Any:
        n = ctx.loop.trip_count
        return SimpleNamespace(
            ctx=ctx,
            n=n,
            next_index=0,          # the shared loop counter (todo list head)
            remaining=n,
            dequeues=0,            # total dequeue count (for TSS et al.)
            per_worker=SimpleNamespace(),  # scratch for adaptive subclasses
            scratch={},
        )

    def chunk_size(self, state: Any, worker: int) -> int:
        raise NotImplementedError

    def dequeue(self, state: Any, worker: int) -> Optional[Chunk]:
        if state.remaining <= 0:
            return None
        size = int(self.chunk_size(state, worker))
        size = max(1, min(size, state.remaining))
        chunk = Chunk(state.next_index, state.next_index + size, worker)
        state.next_index += size
        state.remaining -= size
        state.dequeues += 1
        return chunk


def as_three_op(sched: SixOpBase):
    """Explicit reduction of a six-op scheduler (used by tests to prove the
    adapter and the built-in ``start/next/finish`` agree)."""
    return three_op_from_six(sched)
