"""Cross-invocation measurement history (paper §3).

"UDS must provide a mechanism to store and access the history of loop timings
or other statistics across multiple loop iterations and/or invocations in an
application program, e.g., across simulation time-steps of a numerical
simulation."

``LoopHistory`` is that mechanism: a per-loop-id store of per-invocation,
per-worker measurements.  It is plain data (serializable) so it can ride in a
training checkpoint — adaptive schedulers survive a restart with their learned
state intact (fault-tolerance requirement).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Tuple

__all__ = ["ChunkRecord", "InvocationRecord", "LoopHistory",
           "awf_weights_from_rates"]


def awf_weights_from_rates(rates: Dict[int, float],
                           num_workers: int) -> List[float]:
    """AWF (Banicescu et al.) capability weights from per-worker rates
    (seconds/iteration): weight_i ∝ 1/rate_i, normalized to sum
    ``num_workers``; workers without a usable rate get the mean speed;
    degenerate inputs (no rates, zeros, non-finite totals) fall back to
    exact uniform ones.  The ONE home of the formula — both the history's
    token-weighted rates and the straggler mitigator's step-mean rates
    feed through here."""
    if not rates:
        return [1.0] * num_workers
    speeds = {w: 1.0 / r for w, r in rates.items()
              if r > 0 and math.isfinite(r)}
    if not speeds:
        return [1.0] * num_workers
    mean_speed = sum(speeds.values()) / len(speeds)
    raw = [speeds.get(w, mean_speed) for w in range(num_workers)]
    total = sum(raw)
    if not (total > 0 and math.isfinite(total)):
        return [1.0] * num_workers
    return [num_workers * s / total for s in raw]


@dataclasses.dataclass
class ChunkRecord:
    """One chunk's measurement: worker, [start, stop) range, and the
    elapsed wall seconds (``None`` until measured)."""

    worker: int
    start: int
    stop: int
    elapsed: Optional[float] = None  # seconds; None if not measured

    @property
    def size(self) -> int:
        return self.stop - self.start

    @property
    def rate(self) -> Optional[float]:
        """Seconds per iteration — the adaptive strategies' basic statistic."""
        if self.elapsed is None or self.size == 0:
            return None
        return self.elapsed / self.size


@dataclasses.dataclass
class InvocationRecord:
    """One loop invocation's chunks (a serve run, a train step), plus the
    clause-string provenance ``schedule(auto)`` scores candidates by."""

    chunks: List[ChunkRecord] = dataclasses.field(default_factory=list)
    measured: bool = False    # any chunk recorded with a real elapsed time
    # clause-string provenance: which schedule produced this invocation
    # (written by the engine; the auto selector scores candidates by it)
    scheduler: Optional[str] = None

    def worker_time(self, worker: int) -> float:
        return sum(c.elapsed or 0.0 for c in self.chunks if c.worker == worker)

    def worker_iters(self, worker: int) -> int:
        return sum(c.size for c in self.chunks if c.worker == worker)

    def makespan(self, num_workers: int) -> float:
        return max((self.worker_time(w) for w in range(num_workers)), default=0.0)

    def total_time(self) -> float:
        return sum(c.elapsed or 0.0 for c in self.chunks)

    def imbalance(self, num_workers: int) -> float:
        """(max - mean)/max over worker finish times; 0 = perfectly balanced."""
        times = [self.worker_time(w) for w in range(num_workers)]
        mx = max(times, default=0.0)
        if mx <= 0:
            return 0.0
        return (mx - sum(times) / len(times)) / mx


class LoopHistory:
    """Measurement store keyed by loop id.

    Adaptive schedulers read:
      * ``worker_rates(loop_id)``  — per-worker mean seconds/iteration,
      * ``worker_rate_stats(loop_id)`` — (mean, std) per worker,
      * ``awf_weights(loop_id, P)`` — normalized AWF capability weights.
    The executor writes via ``record``.
    """

    _instances = 0

    def __init__(self) -> None:
        self._data: Dict[str, List[InvocationRecord]] = {}
        self._measured: Dict[str, int] = {}
        # per-instance identity token: two histories with equal epoch
        # counts must never share plan-cache entries (and id() can be
        # recycled by the allocator)
        LoopHistory._instances += 1
        self.token = LoopHistory._instances

    # ------------------------------------------------------------- writing
    def open_invocation(self, loop_id: str,
                        scheduler: Optional[str] = None) -> InvocationRecord:
        """Open a fresh invocation boundary; ``scheduler`` is the producing
        schedule's clause string (provenance for ``schedule(auto)``)."""
        inv = InvocationRecord(scheduler=scheduler)
        self._data.setdefault(loop_id, []).append(inv)
        return inv

    def record(self, loop_id: str, rec: ChunkRecord) -> None:
        if loop_id not in self._data or not self._data[loop_id]:
            self.open_invocation(loop_id)
        inv = self._data[loop_id][-1]
        inv.chunks.append(rec)
        if rec.elapsed is not None and not inv.measured:
            inv.measured = True
            self._measured[loop_id] = self._measured.get(loop_id, 0) + 1

    # ------------------------------------------------------------- reading
    def invocations(self, loop_id: str) -> List[InvocationRecord]:
        return self._data.get(loop_id, [])

    def num_invocations(self, loop_id: str) -> int:
        return len(self._data.get(loop_id, []))

    def measured_invocations(self, loop_id: str) -> int:
        """Invocations carrying at least one *measured* chunk — the epoch
        the plan engine keys adaptive caches on (planning-time records have
        elapsed=None and must not self-invalidate the cache).  O(1): the
        counter is maintained by ``record`` — measurements must flow
        through it, not by mutating ``InvocationRecord.chunks`` directly."""
        return self._measured.get(loop_id, 0)

    def worker_rates(self, loop_id: str, last_k: Optional[int] = None
                     ) -> Dict[int, float]:
        """Mean seconds/iteration per worker over the last_k invocations."""
        invs = self.invocations(loop_id)
        if last_k is not None:
            invs = invs[-last_k:]
        total_t: Dict[int, float] = {}
        total_i: Dict[int, int] = {}
        for inv in invs:
            for c in inv.chunks:
                if c.elapsed is None or c.size == 0:
                    continue
                total_t[c.worker] = total_t.get(c.worker, 0.0) + c.elapsed
                total_i[c.worker] = total_i.get(c.worker, 0) + c.size
        return {w: total_t[w] / total_i[w] for w in total_t if total_i.get(w)}

    def worker_rate_stats(self, loop_id: str) -> Dict[int, Tuple[float, float]]:
        """(mean, std) of per-chunk iteration rates, per worker (for AF)."""
        per: Dict[int, List[float]] = {}
        for inv in self.invocations(loop_id):
            for c in inv.chunks:
                r = c.rate
                if r is not None:
                    per.setdefault(c.worker, []).append(r)
        out: Dict[int, Tuple[float, float]] = {}
        for w, rates in per.items():
            mu = sum(rates) / len(rates)
            var = sum((r - mu) ** 2 for r in rates) / len(rates)
            out[w] = (mu, math.sqrt(var))
        return out

    def awf_weights(self, loop_id: str, num_workers: int) -> List[float]:
        """AWF capability weights over this history's token-weighted rates
        (see ``awf_weights_from_rates`` for the formula)."""
        return awf_weights_from_rates(self.worker_rates(loop_id),
                                      num_workers)

    # ------------------------------------------------------ serialization
    def to_json(self) -> str:
        payload = {
            lid: [{"scheduler": inv.scheduler,
                   "chunks": [dataclasses.asdict(c) for c in inv.chunks]}
                  for inv in invs]
            for lid, invs in self._data.items()
        }
        return json.dumps(payload)

    @classmethod
    def from_json(cls, text: str) -> "LoopHistory":
        h = cls()
        payload = json.loads(text)
        for lid, invs in payload.items():
            for entry in invs:
                if isinstance(entry, dict):       # current format
                    chunks = entry["chunks"]
                    tag = entry.get("scheduler")
                else:                             # pre-provenance format
                    chunks, tag = entry, None
                inv = h.open_invocation(lid, scheduler=tag)
                inv.chunks.extend(ChunkRecord(**c) for c in chunks)
                if any(c.elapsed is not None for c in inv.chunks):
                    inv.measured = True
                    h._measured[lid] = h._measured.get(lid, 0) + 1
        return h
