"""``schedule(hier)`` — hierarchical composition of schedule clauses.

The paper's interface assumes one flat team, but this framework schedules
across nested levels: hosts of a pod, devices (or microbatch slots) of a
host, kernel tile lanes of a device.  Following "An Efficient OpenMP
Runtime System for Hierarchical Architectures" (arxiv 0706.2073, bubble
scheduling — a scheduler tree whose levels own contiguous work blocks)
and "OpenMP Loop Scheduling Revisited" (arxiv 1809.03188 — reuse the
existing clauses rather than inventing per-level ones), a ``hier`` clause
names one *registered* clause per mesh level::

    hier(host=awf, device=guided,4, tile=static)
    hier(host=wf2(weights=2:1:1), device=dynamic, workers=3:2)

Compilation lives in ``PlanEngine._plan_hier``: the outermost level plans
the parent loop as-is (so a single-level ``hier(host=X)`` is
chunk-for-chunk identical to flat ``X``), its per-worker iteration totals
become contiguous row blocks ``[bounds[h], bounds[h+1])``, and every
remaining level re-plans each block recursively.  The result is a
:class:`~repro.core.plan.ComposedPlan`: host-level arrays on the outside
(``worker_iters`` still feeds the batch splitter and membership requeue
provenance), per-block child plans inside (``tile_order`` feeds the
Pallas front-ends a host-block-major leaf order).

``workers=a:b`` pins per-level team sizes; an unpinned level inherits its
parent's worker count (the top level inherits the planned LoopSpec's).

This module must stay importable without JAX (the docs gate imports the
registry under a numpy-only interpreter) and imports the engine lazily —
it is imported from the bottom of ``core/spec.py``, mirroring ``auto``.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple, Union

from repro.core.interface import Chunk, SchedulerContext
from repro.core.spec import (HIER_LEVELS, ScheduleSpec,
                             _normalize_level_workers, parse,
                             register_schedule, resolve)

__all__ = ["HierSchedule"]


def _as_level_spec(name: str, val: Union[str, ScheduleSpec]) -> ScheduleSpec:
    spec = val if isinstance(val, ScheduleSpec) else parse(str(val))
    if spec.kind == "hier":
        raise ValueError(
            "hier levels cannot nest another hier (name the levels "
            "host/device/tile in one clause instead)")
    if spec.is_runtime:
        raise ValueError(
            "hier levels must name a concrete schedule ('runtime' "
            "late-binds a whole clause, not one level)")
    return spec


class HierSchedule:
    """Composition of per-level clauses implementing the three-op interface.

    The engine recognizes this scheduler by its ``hier_levels`` attribute
    and compiles it with ``_plan_hier`` instead of a flat backend.  The
    three-op fallback (streams: packing, microbatch LPT, admission)
    delegates to the *outermost* level — the level that owns the
    substrate's workers — so a stream over ``hier(host=awf, ...)``
    behaves exactly like a stream over ``awf``.
    """

    name = "hier"

    def __init__(self, host: Union[None, str, ScheduleSpec] = None,
                 device: Union[None, str, ScheduleSpec] = None,
                 tile: Union[None, str, ScheduleSpec] = None,
                 workers: Union[None, int, str, Tuple[int, ...]] = None):
        by_name = {"host": host, "device": device, "tile": tile}
        levels = tuple((n, _as_level_spec(n, by_name[n]))
                       for n in HIER_LEVELS if by_name[n] is not None)
        if not levels:
            raise ValueError(
                "hier needs at least one level (host=, device=, tile=)")
        # canonical spec = plan-cache identity (resolve() will overwrite
        # _spec with an equal value; direct construction stays cacheable)
        kwargs = dict(levels)
        if workers is not None:
            kwargs["workers"] = _normalize_level_workers(workers)
        self.spec = ScheduleSpec(kind="hier",
                                 kwargs=tuple(sorted(kwargs.items())))
        self._spec = self.spec       # provenance tag for direct construction
        self.hier_levels: Tuple[Tuple[str, ScheduleSpec], ...] = \
            self.spec.levels
        self.hier_level_workers: Tuple[Optional[int], ...] = \
            self.spec.level_workers
        # adaptive iff any level is (AWF/AF/auto/...): the engine then
        # keys the composed plan on the measured history epoch
        self.adaptive = any(getattr(resolve(s), "adaptive", False)
                            for _, s in self.hier_levels)

    # ------------------------------------------------------------ identity
    def plan_key(self) -> tuple:
        """Composed plans cache on the full nested spec (each level's
        block plans are additionally cached on their own flat keys)."""
        return ("hier", self.spec)

    @property
    def level_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.hier_levels)

    def level(self, name: str) -> Optional[ScheduleSpec]:
        """The named level's nested spec, or None if the clause omits it."""
        return dict(self.hier_levels).get(name)

    # ------------------------------------------------------------ three-op
    def start(self, ctx: SchedulerContext) -> Any:
        inner = resolve(self.hier_levels[0][1])
        return (inner, inner.start(ctx))

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        inner, inner_state = state
        return inner.next(inner_state, worker, elapsed)

    def finish(self, state: Any) -> None:
        inner, inner_state = state
        inner.finish(inner_state)

    def __repr__(self) -> str:
        return f"HierSchedule({str(self.spec)!r})"


register_schedule(
    "hier", source="builtin", chunk_param=None,
    doc="hierarchical composition: one registered clause per mesh level "
        "(host/device/tile), compiled to a ComposedPlan of contiguous "
        "blocks",
)(HierSchedule)
