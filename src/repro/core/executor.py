"""Host-side loop executor — the *execute* stage of plan/execute/measure.

All scheduling decisions flow through ``core.engine.PlanEngine``; this
module never drives the three-op state machine itself.  Two execution
modes:

* **streaming** (``run_loop`` / ``simulate_loop``): a ``ScheduleStream``
  from the engine dequeues chunk-at-a-time under a **virtual clock**
  (deterministic discrete-event simulation — the idle-most worker dequeues
  next, the receiver-initiated semantics of a real OpenMP team), feeding
  measured or modelled chunk times back as the merged
  end-body/dequeue/begin-body ``elapsed``.  This is the mode adaptive
  strategies need: the schedule unfolds *with* the measurements.
* **plan replay** (``execute_plan``): a materialized (possibly cached)
  :class:`~repro.core.plan.SchedulePlan` is executed with vectorized
  NumPy accounting — no Python dequeue at all.  This is the fast path for
  non-adaptive schedules whose assignment is fixed ahead of time, and the
  host-side mirror of what the SPMD substrates do with the same plan.

Chunk costs come either from real measured wall time (``body`` mode) or
from a cost model (``costs`` mode — used by the makespan benchmarks to
reproduce the qualitative literature results the paper cites); the
*measure* stage writes per-chunk timings into the ``LoopHistory``, which
is what invalidates cached adaptive plans in the engine.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.engine import PlanEngine, get_engine
from repro.core.history import LoopHistory
from repro.core.interface import (
    Chunk,
    LoopSpec,
    SchedulerContext,
    UserDefinedSchedule,
    chunks_cover,
)
from repro.core.plan import SchedulePlan

__all__ = ["LoopResult", "run_loop", "simulate_loop", "execute_plan"]


@dataclasses.dataclass
class LoopResult:
    """Outcome of one virtual loop execution (``run_loop`` /
    ``execute_plan``): the dequeued chunks plus per-worker virtual busy
    and finish times the load-balance metrics derive from."""

    loop: LoopSpec
    chunks: List[Chunk]
    worker_time: List[float]       # virtual busy time per worker
    worker_finish: List[float]     # virtual finish time per worker
    dequeues: int
    overhead_time: float           # total scheduling overhead charged
    wave_times: Optional[List[float]] = None  # per-wave makespan (replay)

    @property
    def makespan(self) -> float:
        return max(self.worker_finish, default=0.0)

    @property
    def total_work(self) -> float:
        return sum(self.worker_time)

    @property
    def imbalance(self) -> float:
        """Percent load imbalance: (max/mean - 1)."""
        if not self.worker_time or max(self.worker_time) == 0:
            return 0.0
        mean = sum(self.worker_time) / len(self.worker_time)
        if mean == 0:
            return 0.0
        return max(self.worker_time) / mean - 1.0

    @property
    def cov(self) -> float:
        """Coefficient of variation of worker finish times."""
        t = np.asarray(self.worker_finish)
        if t.size == 0 or t.mean() == 0:
            return 0.0
        return float(t.std() / t.mean())

    def per_worker_chunks(self) -> Dict[int, List[Chunk]]:
        out: Dict[int, List[Chunk]] = {}
        for c in self.chunks:
            out.setdefault(c.worker, []).append(c)
        return out


def _drive(sched: UserDefinedSchedule,
           ctx: SchedulerContext,
           chunk_cost: Callable[[Chunk, int], float],
           overhead: float,
           speeds: Optional[Sequence[float]],
           check_coverage: bool,
           engine: Optional[PlanEngine] = None,
           telemetry: Any = None) -> LoopResult:
    loop = ctx.loop
    p = loop.num_workers
    speeds = list(speeds) if speeds is not None else [1.0] * p
    if len(speeds) != p:
        raise ValueError("speeds must have one entry per worker")

    eng = engine if engine is not None else get_engine()
    stream = eng.open_stream(sched, ctx, telemetry=telemetry)

    # discrete-event simulation: (available_time, worker)
    pq: List = [(0.0, w) for w in range(p)]
    heapq.heapify(pq)
    last_elapsed: Dict[int, Optional[float]] = {w: None for w in range(p)}
    busy = [0.0] * p
    finish = [0.0] * p
    chunks: List[Chunk] = []
    dequeues = 0
    ovh_total = 0.0

    while pq:
        now, w = heapq.heappop(pq)
        chunk = stream.next(w, last_elapsed[w])
        dequeues += 1
        ovh_total += overhead
        if chunk is None:
            finish[w] = max(finish[w], now)
            continue
        dt = chunk_cost(chunk, w) / max(speeds[w], 1e-12)
        last_elapsed[w] = dt
        busy[w] += dt
        end = now + overhead + dt
        finish[w] = end
        chunks.append(chunk)
        heapq.heappush(pq, (end, w))

    stream.close()

    if check_coverage and not chunks_cover(loop, chunks):
        raise AssertionError(
            f"scheduler {getattr(sched, 'name', sched)!r} violated the todo-"
            f"list invariant: chunks do not exactly tile [0, {loop.trip_count})")

    return LoopResult(loop=loop, chunks=chunks, worker_time=busy,
                      worker_finish=finish, dequeues=dequeues,
                      overhead_time=ovh_total)


def run_loop(sched: UserDefinedSchedule,
             loop: Union[LoopSpec, range, int],
             body: Callable[[int], Any],
             *,
             num_workers: Optional[int] = None,
             history: Optional[LoopHistory] = None,
             user_data: Any = None,
             weights: Optional[Sequence[float]] = None,
             telemetry: Any = None,
             check_coverage: bool = True) -> LoopResult:
    """Execute ``body(i)`` for every iteration under the given schedule,
    measuring real wall time per chunk (feeds adaptive schedulers)."""
    loop = _as_loop(loop, num_workers)
    ctx = SchedulerContext(loop=loop, history=history, user_data=user_data,
                           weights=weights)

    def cost(chunk: Chunk, worker: int) -> float:
        t0 = time.perf_counter()
        for i in chunk.indices(loop):
            body(i)
        return time.perf_counter() - t0

    return _drive(sched, ctx, cost, overhead=0.0, speeds=None,
                  check_coverage=check_coverage, telemetry=telemetry)


def simulate_loop(sched: UserDefinedSchedule,
                  loop: Union[LoopSpec, range, int],
                  costs: Union[Sequence[float], Callable[[int], float]],
                  *,
                  num_workers: Optional[int] = None,
                  speeds: Optional[Sequence[float]] = None,
                  overhead: float = 0.0,
                  history: Optional[LoopHistory] = None,
                  user_data: Any = None,
                  weights: Optional[Sequence[float]] = None,
                  telemetry: Any = None,
                  check_coverage: bool = True) -> LoopResult:
    """Deterministic virtual-time execution with per-iteration ``costs``,
    per-worker ``speeds`` (heterogeneity / stragglers) and per-dequeue
    ``overhead`` (the h of FSC).  This is the benchmark engine."""
    loop = _as_loop(loop, num_workers)
    ctx = SchedulerContext(loop=loop, history=history, user_data=user_data,
                           weights=weights)
    if callable(costs):
        cost_of = costs
    else:
        arr = np.asarray(costs, dtype=np.float64)
        if arr.shape[0] != loop.trip_count:
            raise ValueError(
                f"costs has {arr.shape[0]} entries, loop has {loop.trip_count}")
        prefix = np.concatenate([[0.0], np.cumsum(arr)])

        def cost_of(i: int) -> float:  # noqa: unused - replaced below
            return float(arr[i])

    def chunk_cost(chunk: Chunk, worker: int) -> float:
        if callable(costs):
            return sum(cost_of(i) for i in range(chunk.start, chunk.stop))
        return float(prefix[chunk.stop] - prefix[chunk.start])

    return _drive(sched, ctx, chunk_cost, overhead=overhead, speeds=speeds,
                  check_coverage=check_coverage, telemetry=telemetry)


def execute_plan(plan: SchedulePlan,
                 costs: Union[Sequence[float], Callable[[int], float]],
                 *,
                 speeds: Optional[Sequence[float]] = None,
                 overhead: float = 0.0,
                 history: Optional[LoopHistory] = None,
                 telemetry: Any = None) -> LoopResult:
    """Replay a materialized (possibly cached) plan under virtual time.

    Unlike ``simulate_loop`` — where the assignment of chunks to workers
    unfolds dynamically with the simulated clock — the plan's assignment is
    **fixed**, so the whole accounting vectorizes: no per-chunk Python.
    This is the host-side fast path for non-adaptive schedules and the
    mirror of how the SPMD substrates execute the very same plan arrays.

    ``telemetry`` (or a bare ``history``) closes the measurement loop for
    replays: every replayed chunk's modelled elapsed time is recorded and
    flushed, bumping the history's measured epoch so cached adaptive plans
    are invalidated and the next ``PlanEngine.plan()`` replans from this
    replay's data.  The per-wave makespans are returned in
    ``LoopResult.wave_times`` (the SPMD cadence timings).
    """
    loop = plan.loop
    p = loop.num_workers
    n = loop.trip_count
    if callable(costs):
        per_iter = np.asarray([costs(i) for i in range(n)], np.float64)
    else:
        per_iter = np.asarray(costs, dtype=np.float64)
        if per_iter.shape[0] != n:
            raise ValueError(
                f"costs has {per_iter.shape[0]} entries, loop has {n}")
    prefix = np.concatenate([[0.0], np.cumsum(per_iter)])
    chunk_costs = prefix[plan.stops] - prefix[plan.starts]

    sp = np.asarray(speeds if speeds is not None else np.ones(p), np.float64)
    if sp.shape[0] != p:
        raise ValueError("speeds must have one entry per worker")
    chunk_elapsed = chunk_costs / np.maximum(sp[plan.workers], 1e-12)
    busy = np.bincount(plan.workers, weights=chunk_elapsed, minlength=p)
    counts = plan.worker_chunk_counts()
    finish = busy + overhead * counts
    # per-wave makespan: the SPMD cadence — each wave ends when its slowest
    # worker finishes its chunk of the wave
    wave_times: List[float] = []
    if plan.num_chunks:
        nw = plan.num_waves
        per_wave_worker = np.zeros((nw, p), np.float64)
        np.add.at(per_wave_worker, (plan.wave_ids, plan.workers),
                  chunk_elapsed)
        wave_times = per_wave_worker.max(axis=1).tolist()

    if telemetry is None and history is not None:
        from repro.core.telemetry import LoopTelemetry
        telemetry = LoopTelemetry(history, loop_id=loop.loop_id,
                                  num_workers=p)
    if telemetry is not None:
        if telemetry.history is None:
            telemetry.history = history
        if telemetry.loop_id is None:
            telemetry.loop_id = loop.loop_id
        if telemetry.num_workers is None:
            telemetry.num_workers = p
        # bulk-record from plain lists — one zip pass, no per-chunk
        # ndarray scalar indexing on the replay fast path
        telemetry.record_chunks(plan.workers.tolist(), plan.starts.tolist(),
                                plan.stops.tolist(), chunk_elapsed.tolist())
        telemetry.flush()

    # each worker also pays one terminal None-dequeue, as in the stream path
    dequeues = plan.num_chunks + p
    return LoopResult(loop=loop, chunks=plan.chunks,
                      worker_time=busy.tolist(),
                      worker_finish=finish.tolist(),
                      dequeues=dequeues,
                      overhead_time=overhead * dequeues,
                      wave_times=wave_times)


def _as_loop(loop: Union[LoopSpec, range, int],
             num_workers: Optional[int]) -> LoopSpec:
    if isinstance(loop, LoopSpec):
        if num_workers is not None and num_workers != loop.num_workers:
            loop = dataclasses.replace(loop, num_workers=num_workers)
        return loop
    if isinstance(loop, int):
        loop = range(loop)
    return LoopSpec(lb=loop.start, ub=loop.stop, incr=loop.step,
                    num_workers=num_workers or 1)
