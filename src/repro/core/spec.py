"""One schedule clause: the unified ``ScheduleSpec`` selection surface.

The paper argues that a *standard interface* for picking a scheduling
strategy matters as much as the strategy machinery itself: OpenMP's
``schedule`` clause is the one place a user names a strategy, and the
proposal extends that single clause — not a new API per strategy family —
to user-defined schedules.  This module is that clause for this framework.
Every substrate (serve admission, train packing, microbatching, MoE
capacity, straggler mitigation, Pallas tile orders) selects its strategy
through one value — a :class:`ScheduleSpec` — resolved by one function —
:func:`resolve` — against one registry — :func:`register_schedule`.

Clause grammar, mapped to the OpenMP syntax each form mirrors::

    spec string                  OpenMP form it mirrors
    -----------------------      -----------------------------------------
    "guided,4"                   schedule(guided, 4)
    "static"                     schedule(static)
    "fac2"                       schedule(<literature strategy>)   [paper §2]
    "taper(mu=1.0,sigma=0.5)"    strategy parameters beyond chunksize,
                                 impossible in today's clause    [paper §1]
    "wf2(weights=2:1:1)"         WF2 capability weights (the user-specified
                                 workload balancing of [Hummel et al. 96])
    "uds:mystatic(2,3)"          schedule(mystatic(2,3)) — a declare-style
                                 UDS (paper §4.2, Fig. 2 right)
    "uds:mytemplate,16"          schedule(UDS:16, template(mytemplate)) —
                                 a lambda-style template (paper §4.1)
    "runtime"                    schedule(runtime) + OMP_SCHEDULE: the kind
                                 is late-bound from the REPRO_SCHEDULE
                                 environment variable at resolve time
    "auto" /
    "auto(candidates=a:b:c),4"   schedule(auto): the kind is selected
                                 ONLINE from LoopHistory telemetry by the
                                 portfolio selector in core/auto.py
    "hier(host=awf,
          device=guided,4,
          tile=static)"          hierarchical composition: one clause per
                                 mesh level (outer -> inner), each level
                                 any registered clause; the spec NESTS —
                                 level values are themselves ScheduleSpecs
                                 (core/hier.py, compiled to a ComposedPlan)

Resolution accepts a spec, a clause string, an already-built scheduler
instance, or a zero-argument factory callable; it returns a scheduler
implementing the reduced three-op interface.  Schedulers built from a spec
carry the (frozen, hashable) spec as their plan-cache identity, so two
structurally-equal specs built independently share a cached
:class:`~repro.core.plan.SchedulePlan` in the engine.

Late registration: ``REPRO_UDS_MODULES`` (comma-separated module names) is
imported before the first failed lookup, so user schedules shipped as
plain modules are reachable by name from any CLI entry point —
``REPRO_UDS_MODULES=examples.uds_blocks train --scheduler uds:blocks``.

The user guide — full clause grammar (EBNF), the table of every
registered schedule, the UDS registration paths and the telemetry →
replan lifecycle — lives in ``docs/SCHEDULING.md``.
"""

from __future__ import annotations

import dataclasses
import importlib
import inspect
import os
import re
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

__all__ = [
    "ScheduleSpec",
    "SpecLike",
    "parse",
    "resolve",
    "register_schedule",
    "registered_names",
    "lookup",
    "describe",
    "HIER_LEVELS",
    "RUNTIME_ENV_VAR",
    "UDS_MODULES_ENV_VAR",
    "DEFAULT_RUNTIME_SCHEDULE",
]

RUNTIME_ENV_VAR = "REPRO_SCHEDULE"
UDS_MODULES_ENV_VAR = "REPRO_UDS_MODULES"
DEFAULT_RUNTIME_SCHEDULE = "dynamic"

# the "uds:" namespace restricts lookup to user-defined registrations
# (declare-style, lambda-style templates, @register_schedule users)
_UDS_SOURCES = ("declare", "template", "user")

# hierarchical composition: the mesh levels a "hier(...)" clause may name,
# in OUTER -> INNER order (the order the composed plan partitions in)
HIER_LEVELS = ("host", "device", "tile")

_Scalar = Union[None, bool, int, float, str]

# string parameter values must render/re-parse losslessly in a clause
# (":" joins list-valued tokens: wf2 weights, auto candidate names)
_SAFE_TOKEN_RE = re.compile(r"^[\w.+\-:]+$")


# =========================================================================
# The spec
# =========================================================================
@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Frozen, hashable identity of one schedule-clause instance.

    Fields mirror the information the OpenMP clause (and the paper's
    extension of it) can carry:

    * ``kind``    — the strategy name (``guided``, ``fac2``, ``runtime``,
      ``uds:mystatic`` ...).  The ``uds:`` prefix namespaces user-defined
      registrations, mirroring ``schedule(UDS, ...)``.
    * ``chunk``   — the clause's optional chunksize parameter.
    * ``params``  — positional strategy arguments (a declare-style UDS's
      ``omp_argN`` values; ``schedule(mystatic(2,3))``).
    * ``kwargs``  — named strategy parameters, stored as a sorted tuple of
      ``(name, value)`` pairs so the spec stays hashable.
    * ``weights`` — the per-worker capability-weights policy (WF2/AWF
      family), normalized to a tuple of floats.

    Use :meth:`make` to build one with plain dicts/lists; the dataclass
    constructor expects the canonical (hashable) field types.
    """

    kind: str
    chunk: Optional[int] = None
    params: Tuple[Any, ...] = ()
    kwargs: Tuple[Tuple[str, Any], ...] = ()
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self) -> None:
        if not isinstance(self.kind, str) or not self.kind:
            raise ValueError("schedule kind must be a non-empty string")
        if self.kind == "hier":
            # level values normalize to nested ScheduleSpecs before the
            # clause-safe token check below (their clause strings may
            # carry commas/parens that only the hier grammar accepts)
            self._normalize_hier()
        # string parameter values must be clause-safe tokens, or the
        # documented parse(str(spec)) round-trip would break
        for v in self.params + tuple(v for _, v in self.kwargs):
            if isinstance(v, str) and not _SAFE_TOKEN_RE.match(v):
                raise ValueError(
                    f"string parameter {v!r} is not a clause-safe token "
                    f"(allowed: letters, digits, '_', '.', '+', '-', ':')")
        if self.chunk is not None:
            if not isinstance(self.chunk, int) or isinstance(self.chunk, bool):
                raise ValueError(
                    f"chunk must be an int, got {type(self.chunk).__name__}")
            if self.chunk < 1:
                raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.weights is not None:
            if not self.weights:
                raise ValueError("weights must be non-empty when given")
            if any(w <= 0 for w in self.weights):
                raise ValueError(f"weights must be positive: {self.weights}")
        if self.is_runtime and (self.chunk is not None or self.params
                                or self.kwargs or self.weights is not None):
            raise ValueError(
                "schedule 'runtime' takes no parameters (the late-bound "
                f"clause comes whole from ${RUNTIME_ENV_VAR})")

    # ------------------------------------------------------------- builders
    @classmethod
    def make(cls, kind: Union[str, "ScheduleSpec"],
             chunk: Optional[int] = None,
             params: Sequence[Any] = (),
             weights: Optional[Union[Sequence[float],
                                     Mapping[int, float]]] = None,
             **kwargs: Any) -> "ScheduleSpec":
        """Build a spec from convenient Python values.

        ``kind`` may itself be a clause string (parsed first) or a spec
        (used as the base); explicit arguments override the parsed parts.
        ``weights`` accepts a sequence or a worker->weight mapping.
        """
        base = (kind if isinstance(kind, ScheduleSpec)
                else parse(kind) if ("," in kind or "(" in kind)
                else cls(kind=kind))
        if isinstance(weights, Mapping):
            n = max(weights) + 1 if weights else 0
            weights = tuple(float(weights.get(i, 1.0)) for i in range(n))
        elif weights is not None:
            weights = tuple(float(w) for w in weights)
        merged = dict(base.kwargs)
        merged.update(kwargs)
        return cls(
            kind=base.kind,
            chunk=chunk if chunk is not None else base.chunk,
            params=tuple(params) if params else base.params,
            kwargs=tuple(sorted(merged.items())),
            weights=weights if weights is not None else base.weights,
        )

    # ----------------------------------------------------------- hier nesting
    def _normalize_hier(self) -> None:
        """Validate + canonicalize a ``hier`` spec: every level value
        becomes a nested :class:`ScheduleSpec` (clause strings are parsed
        recursively), ``workers`` becomes a canonical ``":"``-joined count
        string, and the kwargs tuple is re-sorted — so two hier specs
        built from equivalent inputs compare (and hash) equal."""
        if self.params:
            raise ValueError(
                "hier takes only named levels (host=, device=, tile=)")
        if self.chunk is not None:
            raise ValueError(
                "hier itself takes no chunksize (set it on a level clause: "
                "hier(device=guided,4))")
        if self.weights is not None:
            raise ValueError(
                "hier itself takes no weights (set them on a level clause: "
                "hier(host=wf2(weights=2:1:1)))")
        levels: Dict[str, "ScheduleSpec"] = {}
        workers: Optional[str] = None
        for k, v in self.kwargs:
            if k == "workers":
                workers = _normalize_level_workers(v)
                continue
            if k not in HIER_LEVELS:
                raise ValueError(
                    f"unknown hier level {k!r} (levels: "
                    f"{', '.join(HIER_LEVELS)}; plus 'workers')")
            if k in levels:
                raise ValueError(f"duplicate hier level {k!r}")
            if isinstance(v, ScheduleSpec):
                sub = v
            elif isinstance(v, str):
                sub = parse(v)
            else:
                raise ValueError(
                    f"hier level {k!r} must be a clause string or "
                    f"ScheduleSpec, got {type(v).__name__}")
            if sub.kind == "hier":
                raise ValueError(
                    "hier levels cannot nest another hier (name the "
                    "levels host/device/tile in one clause instead)")
            if sub.is_runtime:
                raise ValueError(
                    "hier levels must name a concrete schedule ('runtime' "
                    "late-binds a whole clause, not one level)")
            levels[k] = sub
        if not levels:
            raise ValueError(
                "hier needs at least one level (host=, device=, tile=)")
        if workers is not None \
                and len(workers.split(":")) != len(levels):
            raise ValueError(
                f"hier workers={workers!r} must give one count per level "
                f"({len(levels)} level(s) named)")
        merged: Dict[str, Any] = dict(levels)
        if workers is not None:
            merged["workers"] = workers
        object.__setattr__(self, "kwargs", tuple(sorted(merged.items())))

    @property
    def is_hier(self) -> bool:
        return self.kind == "hier"

    @property
    def levels(self) -> Tuple[Tuple[str, "ScheduleSpec"], ...]:
        """A hier spec's ``(name, nested spec)`` pairs in outer -> inner
        order (``HIER_LEVELS`` order); ``()`` for flat specs."""
        if not self.is_hier:
            return ()
        d = dict(self.kwargs)
        return tuple((n, d[n]) for n in HIER_LEVELS if n in d)

    @property
    def level_workers(self) -> Tuple[Optional[int], ...]:
        """Per-level worker counts from the ``workers=a:b`` kwarg, aligned
        with :attr:`levels`; all ``None`` (inherit from the planned
        LoopSpec) when the clause doesn't pin them."""
        lv = self.levels
        w = dict(self.kwargs).get("workers")
        if w is None:
            return (None,) * len(lv)
        return tuple(int(x) for x in str(w).split(":"))

    # ------------------------------------------------------------ accessors
    @property
    def is_runtime(self) -> bool:
        return self.kind == "runtime"

    @property
    def is_uds(self) -> bool:
        return self.kind.startswith("uds:")

    @property
    def name(self) -> str:
        """Registry lookup name (the kind without the ``uds:`` namespace)."""
        return self.kind[4:] if self.is_uds else self.kind

    def kwargs_dict(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    # ------------------------------------------------------------ rendering
    def __str__(self) -> str:
        """Canonical clause string; ``parse(str(spec)) == spec``."""
        if self.is_hier:
            # levels render outer -> inner (parse re-sorts the kwargs
            # tuple, so the cosmetic order round-trips losslessly)
            inner = [f"{n}={s}" for n, s in self.levels]
            w = dict(self.kwargs).get("workers")
            if w is not None:
                inner.append(f"workers={w}")
            return "hier(" + ", ".join(inner) + ")"
        inner = [_render_value(v) for v in self.params]
        inner += [f"{k}={_render_value(v)}" for k, v in self.kwargs]
        if self.weights is not None:
            inner.append("weights=" + ":".join(_render_number(w)
                                               for w in self.weights))
        s = self.kind
        if inner:
            s += "(" + ",".join(inner) + ")"
        if self.chunk is not None:
            s += f",{self.chunk}"
        return s

    def __repr__(self) -> str:
        return f"ScheduleSpec({str(self)!r})"


SpecLike = Union[ScheduleSpec, str, Any]


def _render_number(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _render_value(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return repr(v)
    return str(v)


def _normalize_level_workers(v: Any) -> str:
    """Canonicalize a hier ``workers`` value (int, ``"4:2"`` string, or a
    sequence of ints) to the ``":"``-joined clause form."""
    if isinstance(v, bool):
        raise ValueError(f"hier workers must be positive ints, got {v!r}")
    if isinstance(v, int):
        counts: Sequence[Any] = (v,)
    elif isinstance(v, str):
        counts = [x for x in v.split(":") if x.strip()]
    elif isinstance(v, (list, tuple)):
        counts = v
    else:
        raise ValueError(
            f"hier workers must be an int, 'a:b' string, or int sequence, "
            f"got {type(v).__name__}")
    out: List[int] = []
    for c in counts:
        try:
            i = int(c)
        except (TypeError, ValueError):
            i = 0
        if i < 1 or isinstance(c, bool):
            raise ValueError(f"hier workers must be positive ints, got {v!r}")
        out.append(i)
    if not out:
        raise ValueError("hier workers must be non-empty when given")
    return ":".join(str(i) for i in out)


# =========================================================================
# The parser
# =========================================================================
_CLAUSE_RE = re.compile(
    r"""^\s*
        (?P<kind>(?:uds:)?[A-Za-z_][\w.\-]*)      # name, optional namespace
        \s*
        (?:\((?P<args>.*)\))?                     # optional (arg, ...)
        \s*
        (?:,\s*(?P<chunk>\S+)\s*)?                # optional , chunksize
        $""",
    re.VERBOSE,
)

_NUM_RE = re.compile(r"^[+-]?(\d+\.?\d*|\.\d+)([eE][+-]?\d+)?$")


def _parse_scalar(tok: str) -> _Scalar:
    tok = tok.strip()
    if tok.lower() in ("true", "false"):
        return tok.lower() == "true"
    if tok.lower() == "none":
        return None
    if _NUM_RE.match(tok):
        if re.match(r"^[+-]?\d+$", tok):
            return int(tok)
        return float(tok)
    return tok


def _split_args(args: str) -> List[str]:
    """Split a paren arg list on top-level commas (no nesting in the
    grammar, so this is a plain split with whitespace hygiene)."""
    return [a for a in (p.strip() for p in args.split(",")) if a]


# hier is the one nesting point of the grammar: "hier(" starts a level
# list; everything else (including a stray "hier,4" / bare "hier") is
# rejected with the hier-specific message
_HIER_HEAD_RE = re.compile(r"^\s*hier\s*($|[(,])")
_HIER_BODY_RE = re.compile(r"^\s*hier\s*\((?P<args>.*)\)\s*$", re.DOTALL)
_HIER_SEG_RE = re.compile(r"\s*[A-Za-z_]\w*\s*=")


def _split_hier_args(args: str) -> List[str]:
    """Split a hier level list at depth-0 commas that start a new
    ``name=`` segment.  Level clauses keep their own commas and parens
    (``device=guided,4``, ``host=taper(mu=1.0,sigma=0.5)``): a comma only
    separates levels when what follows looks like the next assignment."""
    segs: List[str] = []
    cur: List[str] = []
    depth = 0
    i, n = 0, len(args)
    while i < n:
        ch = args[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise ValueError("unbalanced ')' in hier level list")
        elif ch == "," and depth == 0 \
                and _HIER_SEG_RE.match(args, i + 1):
            segs.append("".join(cur).strip())
            cur = []
            i += 1
            continue
        cur.append(ch)
        i += 1
    if depth != 0:
        raise ValueError("unbalanced '(' in hier level list")
    tail = "".join(cur).strip()
    if tail:
        segs.append(tail)
    return segs


def _parse_hier(clause: str) -> ScheduleSpec:
    """Parse one ``hier(level=<clause>, ...)`` composition clause; level
    values are full sub-clauses, parsed recursively by
    ``ScheduleSpec.__post_init__``."""
    m = _HIER_BODY_RE.match(clause)
    if m is None:
        raise ValueError(
            f"malformed hier clause {clause!r} (expected "
            f"'hier(host=<clause>, device=<clause>, tile=<clause>)'; "
            f"hier itself takes no chunksize)")
    kwargs: Dict[str, Any] = {}
    try:
        segs = _split_hier_args(m.group("args"))
    except ValueError as e:
        raise ValueError(f"hier clause {clause!r}: {e}") from None
    for seg in segs:
        key, eq, val = seg.partition("=")
        key, val = key.strip(), val.strip()
        if not eq or not key.isidentifier() or not val:
            raise ValueError(
                f"hier clause {clause!r}: expected 'level=<clause>' "
                f"segments, got {seg!r}")
        if key in kwargs:
            raise ValueError(
                f"hier clause {clause!r}: duplicate level {key!r}")
        kwargs[key] = val
    try:
        return ScheduleSpec(kind="hier",
                            kwargs=tuple(sorted(kwargs.items())))
    except ValueError as e:
        raise ValueError(f"hier clause {clause!r}: {e}") from None


def parse(clause: str) -> ScheduleSpec:
    """Parse one OpenMP-style schedule clause string into a spec.

    Raises ``ValueError`` with the offending clause on any malformed
    input — an unbalanced paren, a non-integer or non-positive chunk, a
    malformed weights list.
    """
    if not isinstance(clause, str):
        raise TypeError(f"expected a clause string, got "
                        f"{type(clause).__name__}")
    if _HIER_HEAD_RE.match(clause):
        return _parse_hier(clause)
    m = _CLAUSE_RE.match(clause)
    if (m is None or clause.count("(") != clause.count(")")
            # the grammar has no nesting: parens inside the arg list mean
            # a malformed clause, not string-valued params
            or (m.group("args") is not None
                and ("(" in m.group("args") or ")" in m.group("args")))):
        raise ValueError(
            f"malformed schedule clause {clause!r} (expected "
            f"'kind', 'kind,chunk', 'kind(arg,...)[,chunk]', or "
            f"'uds:name(arg,...)[,chunk]')")
    kind = m.group("kind")
    chunk: Optional[int] = None
    if m.group("chunk") is not None:
        tok = _parse_scalar(m.group("chunk"))
        if not isinstance(tok, int) or isinstance(tok, bool):
            raise ValueError(
                f"schedule clause {clause!r}: chunksize must be an "
                f"integer, got {m.group('chunk')!r}")
        chunk = tok          # range-checked by ScheduleSpec.__post_init__
    params: List[Any] = []
    kwargs: Dict[str, Any] = {}
    weights: Optional[Tuple[float, ...]] = None
    if m.group("args") is not None:
        for tok in _split_args(m.group("args")):
            if "=" in tok:
                key, _, val = tok.partition("=")
                key = key.strip()
                if not key.isidentifier():
                    raise ValueError(
                        f"schedule clause {clause!r}: bad parameter "
                        f"name {key!r}")
                if key == "weights":
                    if weights is not None:
                        raise ValueError(
                            f"schedule clause {clause!r}: duplicate "
                            f"parameter 'weights'")
                    try:
                        weights = tuple(float(w)
                                        for w in val.split(":") if w.strip())
                    except ValueError:
                        raise ValueError(
                            f"schedule clause {clause!r}: weights must be "
                            f"a ':'-separated number list, got {val!r}")
                    if not weights:
                        raise ValueError(
                            f"schedule clause {clause!r}: empty weights")
                else:
                    if key in kwargs:
                        raise ValueError(
                            f"schedule clause {clause!r}: duplicate "
                            f"parameter {key!r}")
                    kwargs[key] = _parse_scalar(val)
            else:
                if kwargs or weights is not None:
                    raise ValueError(
                        f"schedule clause {clause!r}: positional argument "
                        f"{tok!r} after a named parameter")
                params.append(_parse_scalar(tok))
    try:
        return ScheduleSpec(kind=kind, chunk=chunk, params=tuple(params),
                            kwargs=tuple(sorted(kwargs.items())),
                            weights=weights)
    except ValueError as e:
        raise ValueError(f"schedule clause {clause!r}: {e}") from None


# =========================================================================
# The unified registry
# =========================================================================
@dataclasses.dataclass(frozen=True)
class RegisteredSchedule:
    """One registry entry: how to build a scheduler from a spec."""

    name: str
    factory: Callable[..., Any]
    source: str = "user"            # builtin | declare | template | user
    chunk_param: Optional[str] = "chunk"   # ctor kwarg the chunksize maps to
    doc: str = ""


_REGISTRY: Dict[str, RegisteredSchedule] = {}
_uds_modules_state = "unloaded"      # -> "loading" -> "loaded"


def register_schedule(name: Optional[str] = None, *,
                      source: str = "user",
                      chunk_param: Optional[str] = "chunk",
                      replace: bool = False,
                      doc: str = "") -> Callable:
    """Register a scheduler factory under ``name`` in the unified registry.

    Usable as a decorator (``@register_schedule("myname")``) or called
    directly (``register_schedule("myname")(factory)``).  The factory is
    invoked with the spec's positional ``params`` and named ``kwargs``;
    a spec chunksize is passed as the ``chunk_param`` keyword (set
    ``chunk_param=None`` for strategies that take no chunksize).

    ``replace=True`` may only replace a registration of the *same*
    source: no registration path can shadow a builtin or silently
    clobber another style's entry of the same name.
    """

    def deco(factory: Callable[..., Any]) -> Callable[..., Any]:
        key = name or getattr(factory, "name", None) or factory.__name__
        prev = _REGISTRY.get(key)
        if prev is not None and (not replace or prev.source != source):
            raise ValueError(
                f"schedule name {key!r} already registered "
                f"(source: {prev.source})"
                + ("; replace=True may only replace a registration of "
                   "the same source" if replace else ""))
        _REGISTRY[key] = RegisteredSchedule(
            name=key, factory=factory, source=source,
            chunk_param=chunk_param,
            doc=doc or (inspect.getdoc(factory) or "").split("\n")[0])
        return factory

    return deco


def unregister_schedule(name: str) -> None:
    """Remove a registration (tests and template redefinition)."""
    _REGISTRY.pop(name, None)


def registered_names(source: Optional[str] = None) -> List[str]:
    """All registered schedule names, optionally filtered by source."""
    _load_uds_modules()
    return sorted(n for n, e in _REGISTRY.items()
                  if source is None or e.source == source)


def _load_uds_modules() -> None:
    """Import ``REPRO_UDS_MODULES`` once — the late registration point
    that makes user schedules reachable by name from CLI entry points.

    The loaded flag is only committed after every import succeeds, so an
    ImportError propagates to the caller AND the load is retried on the
    next lookup (a long-lived process is not silently stuck with a
    half-configured registry).  Reentrant lookups during loading (a UDS
    module that itself resolves a schedule at import time) fall through
    to the registry as-is.
    """
    global _uds_modules_state
    if _uds_modules_state != "unloaded":
        return
    _uds_modules_state = "loading"
    try:
        for mod in os.environ.get(UDS_MODULES_ENV_VAR, "").split(","):
            mod = mod.strip()
            if mod:
                importlib.import_module(mod)
    except BaseException:
        _uds_modules_state = "unloaded"
        raise
    _uds_modules_state = "loaded"


def _unknown_name_error(name: str, uds_only: bool) -> KeyError:
    by_source: Dict[str, List[str]] = {}
    for n, e in sorted(_REGISTRY.items()):
        by_source.setdefault(e.source, []).append(n)
    parts = []
    order = ("builtin", "declare", "template", "user")
    for src in order:
        if src in by_source and not (uds_only and src == "builtin"):
            parts.append(f"{src}: {by_source[src]}")
    scope = "UDS " if uds_only else ""
    return KeyError(
        f"unknown {scope}schedule {name!r}; registered schedules — "
        + "; ".join(parts))


def lookup(name: str, *, uds_only: bool = False) -> RegisteredSchedule:
    """Find a registry entry by name; ``uds_only`` restricts to the
    user-defined sources (the ``uds:`` namespace).  Raises a ``KeyError``
    that lists every registered name, grouped by source."""
    _load_uds_modules()
    entry = _REGISTRY.get(name)
    if entry is not None and uds_only and entry.source not in _UDS_SOURCES:
        entry = None
    if entry is None:
        raise _unknown_name_error(name, uds_only)
    return entry


# =========================================================================
# Resolution
# =========================================================================
def _is_scheduler(obj: Any) -> bool:
    # a scheduler *instance*: classes (whose attributes also match) are
    # treated as factory callables and instantiated by resolve()
    return (not isinstance(obj, type)
            and hasattr(obj, "start") and hasattr(obj, "next")
            and hasattr(obj, "finish"))


def _runtime_spec() -> ScheduleSpec:
    clause = os.environ.get(RUNTIME_ENV_VAR, "").strip() \
        or DEFAULT_RUNTIME_SCHEDULE
    spec = parse(clause)
    if spec.is_runtime:
        raise ValueError(
            f"${RUNTIME_ENV_VAR}={clause!r} resolves to 'runtime' — "
            f"the late-bound clause must name a concrete schedule")
    return spec


def _instantiate(spec: ScheduleSpec) -> Any:
    entry = lookup(spec.name, uds_only=spec.is_uds)
    kwargs = spec.kwargs_dict()
    if spec.weights is not None and "weights" not in kwargs:
        # WF2-family constructors take a worker->weight mapping
        kwargs["weights"] = {i: w for i, w in enumerate(spec.weights)}
    if spec.chunk is not None:
        if entry.chunk_param is None:
            raise ValueError(
                f"schedule {spec.kind!r} does not take a chunksize "
                f"(got {spec})")
        kwargs[entry.chunk_param] = spec.chunk
    try:
        sched = entry.factory(*spec.params, **kwargs)
    except TypeError as e:
        raise ValueError(
            f"schedule {spec.kind!r} rejected parameters of {spec}: {e}"
        ) from None
    if not _is_scheduler(sched):
        raise TypeError(
            f"factory for schedule {spec.kind!r} returned "
            f"{type(sched).__name__}, not a three-op scheduler")
    return sched


def resolve(spec_like: SpecLike, /, **overrides: Any) -> Any:
    """The one call path from "how the user names a schedule" to a
    scheduler implementing the reduced three-op interface.

    Accepts:

    * a :class:`ScheduleSpec`,
    * a clause string (``"guided,4"``, ``"uds:mystatic(2,3)"``,
      ``"runtime"`` — see the module docstring for the grammar),
    * an already-built scheduler instance (returned as-is; no overrides
      allowed), or
    * a zero-argument factory callable returning a scheduler.

    ``overrides`` merge into the spec before instantiation
    (``resolve("guided", chunk=4)`` == ``resolve("guided,4")``).

    The returned scheduler carries the normalized spec as ``_spec``: the
    engine keys its plan cache on it, so equal specs share cached plans.
    """
    if _is_scheduler(spec_like):
        if overrides:
            raise TypeError(
                "cannot apply spec overrides to an already-built "
                f"scheduler instance ({getattr(spec_like, 'name', spec_like)!r})")
        return spec_like
    if isinstance(spec_like, ScheduleSpec):
        spec = spec_like
    elif isinstance(spec_like, str):
        spec = parse(spec_like)
    elif callable(spec_like):
        if overrides:
            raise TypeError(
                "cannot apply spec overrides to a schedule factory "
                "callable (build the spec explicitly instead)")
        sched = spec_like()
        if not _is_scheduler(sched):
            raise TypeError(
                f"schedule factory {spec_like!r} returned "
                f"{type(sched).__name__}, not a three-op scheduler")
        return sched
    else:
        raise TypeError(
            f"cannot resolve a schedule from {type(spec_like).__name__!r} "
            f"(expected ScheduleSpec, clause string, scheduler instance, "
            f"or factory callable)")
    if overrides:
        spec = ScheduleSpec.make(spec, **overrides)
    if spec.is_runtime:
        spec = _runtime_spec()
    sched = _instantiate(spec)
    try:
        sched._spec = spec       # plan-cache identity (see engine.py)
    except (AttributeError, TypeError):   # __slots__ etc.: still usable
        pass
    return sched


def describe(spec_like: SpecLike) -> str:
    """Human-readable name of a spec-like (for logs and CLI echo)."""
    if isinstance(spec_like, (ScheduleSpec, str)):
        try:
            spec = (spec_like if isinstance(spec_like, ScheduleSpec)
                    else parse(spec_like))
            return str(spec)
        except ValueError:
            return str(spec_like)
    return str(getattr(spec_like, "name", spec_like))


# =========================================================================
# Builtin absorption: SCHEDULER_FACTORIES -> unified registry
# =========================================================================
def _register_builtins() -> None:
    from repro.core.schedulers import SCHEDULER_FACTORIES

    # each scheduler class declares its own clause-chunksize mapping
    # (``spec_chunk_param``); non-class factories (the awf_* variant
    # lambdas) default to None — rejecting ``name,N`` beats mis-mapping it
    for name, factory in SCHEDULER_FACTORIES.items():
        register_schedule(
            name, source="builtin",
            chunk_param=getattr(factory, "spec_chunk_param", None),
            replace=True,
        )(factory)


_register_builtins()

# the auto selector registers itself on import; it lives in its own
# module (it depends on the engine/executor, which depend on this one)
import repro.core.auto  # noqa: F401,E402  (registers "auto")

# hierarchical composition registers itself the same way (it resolves
# its level clauses through this module and plans through the engine)
import repro.core.hier  # noqa: F401,E402  (registers "hier")

# declare-style and lambda-style registrations mirror themselves in at
# declaration time (declare_schedule / schedule_template import this
# module before touching their own registries), so no pre-existing
# entries can be missed here.
