"""``schedule(auto)`` — online schedule selection, the *reselect* stage.

The rest of the spine is plan → execute → measure → replan; this module
closes the remaining human loop: *which clause to plan with*.  OpenMP's
``auto`` kind delegates the choice to the runtime — here the runtime's
evidence is :class:`~repro.core.history.LoopHistory`, so the selector is
an online portfolio over registered schedules (following "A Comparative
Study of OpenMP Scheduling Algorithm Selection Strategies",
arxiv 2507.20312):

* every measured invocation carries the clause string that produced it
  (``InvocationRecord.scheduler``, written by the engine), so the
  **incumbent**'s score is real measured wall time — ``makespan * P /
  iterations``, a per-iteration cost at full parallelism;
* **cold** candidates are scored by cost-model replay: the engine compiles
  their plan (a ~µs cache hit in steady state) and
  :func:`~repro.core.executor.execute_plan` replays it against per-worker
  speeds and per-iteration costs derived from the same history — the sum
  of the modelled wave times is the SPMD-cadence makespan estimate;
* a UCB-style bonus discounts rarely-tried candidates so the selector
  keeps exploring, and a **hysteresis band** keeps the incumbent unless a
  challenger is decisively better, so near-equal schedules don't thrash
  the plan cache.

Selection is a *pure function of the history* (no hidden selector state),
so a fresh ``resolve("auto")`` per invocation — what the serve and train
loops do — continues exactly where the last one left off, and the learned
state rides in checkpoints with the history itself.

See ``docs/SCHEDULING.md`` ("The auto schedule") for usage, the candidate
grammar (``auto(candidates=guided:fac2:awf),chunk``) and convergence
caveats.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.history import ChunkRecord, LoopHistory
from repro.core.interface import Chunk, LoopSpec, SchedulerContext
from repro.core.spec import ScheduleSpec, lookup, parse, register_schedule, resolve

__all__ = ["AutoScheduler", "DEFAULT_CANDIDATES"]

#: Default portfolio: the OpenMP quartet's members that exist here
#: (static / dynamic / guided), the paper's factoring workhorse (fac2)
#: and the adaptive weighted family's representative (awf).
DEFAULT_CANDIDATES: Tuple[str, ...] = ("static", "dynamic", "guided",
                                       "fac2", "awf")


def _as_candidate(cand: Union[str, ScheduleSpec]) -> ScheduleSpec:
    """Normalize one candidate (name, clause string, or spec) to a spec."""
    spec = cand if isinstance(cand, ScheduleSpec) else parse(cand)
    if spec.name == "auto":
        raise ValueError("'auto' cannot be its own candidate")
    if spec.is_runtime:
        raise ValueError("'runtime' cannot be an auto candidate (late-bind "
                         "the whole clause via $REPRO_SCHEDULE instead)")
    lookup(spec.name, uds_only=spec.is_uds)   # fail early on unknown names
    return spec


@dataclasses.dataclass
class _AutoState:
    """One invocation's delegation record: the chosen inner scheduler."""

    inner: Any
    inner_state: Any


class AutoScheduler:
    """Online schedule selector implementing the three-op interface.

    ``candidates`` is the portfolio: a ``":"``-separated clause-name string
    (the ``auto(candidates=guided:fac2:awf)`` form), or a sequence of
    names / clause strings / specs.  ``chunk`` is applied to every
    candidate that accepts a chunksize and doesn't fix its own.
    ``window`` bounds how many recent measured invocations per candidate
    feed its score; ``explore`` scales the UCB bonus; ``hysteresis`` is
    the relative margin a challenger must win by to unseat the incumbent.
    """

    name = "auto"
    adaptive = True          # selection reads history at start: the plan
    # cache must key on the measured epoch (see engine._cache_key)

    def __init__(self, candidates: Union[None, str,
                                         Sequence[Union[str, ScheduleSpec]]]
                 = None,
                 chunk: Optional[int] = None,
                 window: int = 8,
                 explore: float = 0.25,
                 hysteresis: float = 0.1):
        if candidates is None:
            cands: Sequence[Union[str, ScheduleSpec]] = DEFAULT_CANDIDATES
        elif isinstance(candidates, str):
            cands = [c for c in candidates.split(":") if c.strip()]
        else:
            cands = list(candidates)
        if not cands:
            raise ValueError("auto needs at least one candidate schedule")
        self.candidates = tuple(_as_candidate(c) for c in cands)
        if len({str(c) for c in self.candidates}) != len(self.candidates):
            raise ValueError(
                f"duplicate auto candidates: {self.candidates}")
        if chunk is not None and (not isinstance(chunk, int) or chunk < 1):
            raise ValueError(f"chunk must be a positive int, got {chunk!r}")
        if not 0.0 <= hysteresis < 1.0:
            raise ValueError(f"hysteresis must be in [0, 1), got {hysteresis}")
        if explore < 0.0:
            raise ValueError(f"explore must be >= 0, got {explore}")
        self.chunk = chunk
        self.window = int(window)
        self.explore = float(explore)
        self.hysteresis = float(hysteresis)
        self._selected: Optional[ScheduleSpec] = None

    # -------------------------------------------------------- identities
    def full_candidates(self) -> List[ScheduleSpec]:
        """Candidate specs with the clause chunksize applied where the
        candidate accepts one and doesn't already fix its own."""
        out: List[ScheduleSpec] = []
        for c in self.candidates:
            if (self.chunk is not None and c.chunk is None
                    and lookup(c.name,
                               uds_only=c.is_uds).chunk_param is not None):
                c = dataclasses.replace(c, chunk=self.chunk)
            out.append(c)
        return out

    @property
    def selected(self) -> Optional[ScheduleSpec]:
        """The candidate the last :meth:`select` call settled on."""
        return self._selected

    @property
    def history_tag(self) -> str:
        """Provenance tag for invocations this selector drives: the
        *selected candidate's* clause string, so measured records
        attribute to the candidate (and fixed runs of the same clause
        feed the same statistics)."""
        return str(self._selected) if self._selected is not None else "auto"

    def plan_key(self) -> tuple:
        """Plan-cache identity: the selector configuration *plus the
        currently-selected candidate* — a selection bump re-keys the
        plan, riding the measured-epoch invalidation the engine already
        applies to adaptive schedulers."""
        return ("auto", self.candidates, self.chunk, self.window,
                self.explore, self.hysteresis, self._selected)

    # ----------------------------------------------------------- scoring
    @staticmethod
    def _measured_score(inv: Any, num_workers: int) -> Optional[float]:
        """Per-iteration cost at full parallelism: ``makespan * P /
        iterations`` — comparable across invocations of different sizes
        and with the modelled replay score."""
        iters = sum(c.size for c in inv.chunks)
        if iters <= 0:
            return None
        return inv.makespan(num_workers) * num_workers / iters

    @staticmethod
    def _telemetry_loop_id(history: LoopHistory, loop_id: str) -> str:
        """The loop id selection reads: the loop's own, or — when it has
        no measurements — the nearest ``"/"``-ancestor that does (the
        straggler mitigator plans ``train_step/token_shares`` from
        ``train_step`` step telemetry)."""
        lid = loop_id
        while True:
            if history.measured_invocations(lid) > 0:
                return lid
            if "/" not in lid:
                return loop_id
            lid = lid.rsplit("/", 1)[0]

    def _speeds_and_rate(self, history: LoopHistory, lid: str,
                         num_workers: int,
                         weights: Optional[Sequence[float]]
                         ) -> Tuple[List[float], float]:
        """Cost model for replay: per-worker relative speeds and the mean
        per-iteration cost, from measured rates when the history has
        them, else from the caller's capability weights."""
        rates = history.worker_rates(lid)
        rates = {w: r for w, r in rates.items()
                 if r > 0 and math.isfinite(r)}
        if rates:
            mean_rate = sum(rates.values()) / len(rates)
            speeds = [mean_rate / rates.get(w, mean_rate)
                      for w in range(num_workers)]
            return speeds, mean_rate
        if weights is not None and len(weights) == num_workers \
                and all(w > 0 for w in weights):
            mean_w = sum(weights) / len(weights)
            return [w / mean_w for w in weights], 1.0
        return [1.0] * num_workers, 1.0

    @staticmethod
    def _model_history(loop_id: str, speeds: Sequence[float],
                       rate: float) -> LoopHistory:
        """A throwaway history primed with the measured per-worker rates,
        so *adaptive* cold candidates (AWF/AF/user schedules that read
        history) are modelled at their informed steady state — without
        writing anything into the real history."""
        h = LoopHistory()
        h.open_invocation(loop_id)
        k = 64
        for w, s in enumerate(speeds):
            h.record(loop_id, ChunkRecord(
                worker=w, start=w * k, stop=(w + 1) * k,
                elapsed=rate / max(s, 1e-9) * k))
        return h

    def _modelled_score(self, cand: ScheduleSpec, loop: LoopSpec,
                        speeds: Sequence[float], rate: float,
                        weights: Optional[Sequence[float]],
                        model_hist: LoopHistory) -> float:
        """Cost-model replay of a cold candidate: compile its plan
        through the engine (cached) and replay it with
        :func:`execute_plan`; the sum of the modelled wave times is the
        SPMD-cadence makespan, normalized like the measured score."""
        from repro.core.engine import get_engine
        from repro.core.executor import execute_plan

        n = loop.trip_count
        if n <= 0:
            return 0.0
        w = list(weights) if weights is not None else list(speeds)
        plan = get_engine().plan(resolve(cand), loop, weights=w,
                                 history=model_hist)
        result = execute_plan(plan, costs=np.full(n, rate), speeds=speeds)
        makespan = (sum(result.wave_times) if result.wave_times
                    else result.makespan)
        return makespan * loop.num_workers / n

    # --------------------------------------------------------- selection
    def select(self, history: Optional[LoopHistory], loop: LoopSpec,
               weights: Optional[Sequence[float]] = None) -> ScheduleSpec:
        """Run one selection round and return (and remember) the winner.

        Deterministic in the history contents: with no measurements at
        all this is the cold-start default (the first candidate); with
        measurements, each candidate gets a score — measured where its
        tagged invocations exist, modelled replay otherwise — a UCB bonus
        for under-tried candidates, and the incumbent (the candidate of
        the most recent measured invocation) survives unless a challenger
        beats it by the hysteresis margin.
        """
        cands = self.full_candidates()
        tags = {str(c): c for c in cands}
        order = {str(c): i for i, c in enumerate(cands)}
        if history is None:
            self._selected = cands[0]
            return self._selected
        lid = self._telemetry_loop_id(history, loop.loop_id)
        p = loop.num_workers
        measured: Dict[str, List[float]] = {}
        incumbent: Optional[str] = None
        for inv in history.invocations(lid):
            if not inv.measured:
                continue
            tag = getattr(inv, "scheduler", None)
            if tag not in tags:
                continue
            s = self._measured_score(inv, p)
            if s is None:
                continue
            measured.setdefault(tag, []).append(s)
            incumbent = tag
        if not measured and history.measured_invocations(lid) == 0:
            # true cold start: nothing to model against either
            self._selected = cands[0]
            return self._selected

        speeds, rate = self._speeds_and_rate(history, lid, p, weights)
        model_hist: Optional[LoopHistory] = None
        scores: Dict[str, float] = {}
        counts: Dict[str, int] = {}
        for tag, cand in tags.items():
            obs = measured.get(tag, [])[-self.window:]
            counts[tag] = len(obs)
            if obs:
                scores[tag] = sum(obs) / len(obs)
            else:
                if model_hist is None:
                    model_hist = self._model_history(loop.loop_id, speeds,
                                                     rate)
                scores[tag] = self._modelled_score(cand, loop, speeds,
                                                   rate, weights,
                                                   model_hist)
        total = sum(counts.values())
        ucb: Dict[str, float] = {}
        for tag, m in scores.items():
            bonus = self.explore * math.sqrt(
                math.log(total + 1.0) / (counts[tag] + 1.0))
            ucb[tag] = m * (1.0 - min(bonus, 0.95))
        best = min(ucb, key=lambda t: (ucb[t], order[t]))
        if (incumbent is not None and best != incumbent
                and ucb[best] > ucb[incumbent] * (1.0 - self.hysteresis)):
            best = incumbent             # inside the hysteresis band: stay
        self._selected = tags[best]
        return self._selected

    # ---------------------------------------------------------- three-op
    def start(self, ctx: SchedulerContext) -> _AutoState:
        """Select a candidate from the context's history and delegate.

        A history-less context does NOT reset an existing selection: a
        caller that scores against an out-of-band history (the straggler
        mitigator runs ``select`` explicitly, then plans without one)
        must get the candidate it selected, not the cold-start default.
        """
        if ctx.history is not None or self._selected is None:
            self.select(ctx.history, ctx.loop, weights=ctx.weights)
        inner = resolve(self._selected)
        return _AutoState(inner=inner, inner_state=inner.start(ctx))

    def next(self, state: _AutoState, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        """Dequeue from the selected candidate's state machine."""
        return state.inner.next(state.inner_state, worker, elapsed)

    def finish(self, state: _AutoState) -> None:
        """Close the selected candidate's state machine."""
        state.inner.finish(state.inner_state)

    def __repr__(self) -> str:
        return (f"AutoScheduler(candidates="
                f"{':'.join(str(c) for c in self.candidates)}, "
                f"selected={self._selected})")


register_schedule(
    "auto", source="builtin", chunk_param="chunk",
    doc="online schedule selection from LoopHistory telemetry "
        "(UCB portfolio over registered candidates)",
)(AutoScheduler)
