"""SchedulePlan — the materialized schedule IR every substrate consumes.

A ``SchedulePlan`` is the todo list *after* all dequeues: flat NumPy arrays
(one entry per chunk, in dequeue order) plus provenance metadata recording
how the plan was produced.  It is the single currency between the paper's
three-op scheduling interface and every execution substrate in this
framework:

  * the host executor replays plans under virtual time (``execute_plan``),
  * the SPMD wave planner is a *view* of the same arrays (``waves``,
    ``padded_worker_table``),
  * Pallas kernels scalar-prefetch the flattened tables (``table``,
    ``sched_matmul``/``flash_attention`` tile orders),
  * the launch layer splits batches by ``worker_iters``.

Plans are produced exclusively by ``core.engine.PlanEngine`` — either by
vectorized closed-form compilation (non-adaptive families) or by the
generic three-op state-machine driver — and may be **cached** across loop
invocations, so the arrays are frozen (read-only) after construction.

Array layout (all 1-D, length = number of chunks, dequeue order):
  ``starts[i]``   logical start of chunk i (0-based, inclusive)
  ``sizes[i]``    iterations in chunk i
  ``workers[i]``  worker (thread / shard / expert / kernel lane) id
  ``wave_ids[i]`` batched-dequeue round the chunk belongs to (SPMD cadence)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.interface import Chunk, LoopSpec

__all__ = ["PlanProvenance", "SchedulePlan", "ComposedPlan"]


@dataclasses.dataclass(frozen=True)
class PlanProvenance:
    """How a plan came to be (for benchmarks, debugging, and cache audits)."""

    scheduler: str = "uds"
    source: str = "generic"          # "vectorized" | "generic"
    cache_key: Optional[tuple] = None  # None = plan was not cacheable
    plan_time_s: float = 0.0


def _freeze_array(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a, dtype=np.int64)
    a.setflags(write=False)
    return a


@dataclasses.dataclass(eq=False)
class SchedulePlan:
    """A fully-materialized schedule: the todo list after all dequeues."""

    loop: LoopSpec
    starts: np.ndarray
    sizes: np.ndarray
    workers: np.ndarray
    wave_ids: np.ndarray
    provenance: PlanProvenance = dataclasses.field(default_factory=PlanProvenance)

    def __post_init__(self) -> None:
        self.starts = _freeze_array(self.starts)
        self.sizes = _freeze_array(self.sizes)
        self.workers = _freeze_array(self.workers)
        self.wave_ids = _freeze_array(self.wave_ids)
        m = self.starts.shape[0]
        if not (self.sizes.shape[0] == self.workers.shape[0]
                == self.wave_ids.shape[0] == m):
            raise ValueError("plan arrays must have equal length")

    # ------------------------------------------------------------ construct
    @classmethod
    def from_waves(cls, loop: LoopSpec, waves: Sequence[Sequence[Chunk]],
                   provenance: Optional[PlanProvenance] = None
                   ) -> "SchedulePlan":
        """Build from the batched-dequeue (SPMD wave) representation."""
        starts, sizes, workers, wave_ids = [], [], [], []
        for r, wave in enumerate(waves):
            for c in wave:
                starts.append(c.start)
                sizes.append(c.stop - c.start)
                workers.append(c.worker)
                wave_ids.append(r)
        return cls(loop=loop,
                   starts=np.asarray(starts, np.int64),
                   sizes=np.asarray(sizes, np.int64),
                   workers=np.asarray(workers, np.int64),
                   wave_ids=np.asarray(wave_ids, np.int64),
                   provenance=provenance or PlanProvenance())

    @classmethod
    def from_chunks(cls, loop: LoopSpec, chunks: Sequence[Chunk],
                    provenance: Optional[PlanProvenance] = None
                    ) -> "SchedulePlan":
        """Build from a flat dequeue-order chunk list (one chunk per wave
        slot, wave = chunk index // num_workers)."""
        m = len(chunks)
        idx = np.arange(m, dtype=np.int64)
        return cls(loop=loop,
                   starts=np.asarray([c.start for c in chunks], np.int64),
                   sizes=np.asarray([c.stop - c.start for c in chunks],
                                    np.int64),
                   workers=np.asarray([c.worker for c in chunks], np.int64),
                   wave_ids=idx // max(loop.num_workers, 1),
                   provenance=provenance or PlanProvenance())

    # -------------------------------------------------------------- queries
    @property
    def num_chunks(self) -> int:
        return int(self.starts.shape[0])

    @property
    def stops(self) -> np.ndarray:
        return self.starts + self.sizes

    @property
    def chunks(self) -> List[Chunk]:
        """Materialize ``Chunk`` tuples in dequeue order (compat view; hot
        paths should consume the flat arrays instead)."""
        return [Chunk(int(s), int(s + z), int(w))
                for s, z, w in zip(self.starts, self.sizes, self.workers)]

    @property
    def waves(self) -> List[List[Chunk]]:
        """Chunks grouped by batched-dequeue round (the SPMD cadence)."""
        out: List[List[Chunk]] = [[] for _ in range(self.num_waves)]
        for s, z, w, r in zip(self.starts, self.sizes, self.workers,
                              self.wave_ids):
            out[int(r)].append(Chunk(int(s), int(s + z), int(w)))
        return out

    @property
    def num_waves(self) -> int:
        return int(self.wave_ids.max()) + 1 if self.num_chunks else 0

    def identical(self, other: "SchedulePlan") -> bool:
        """Chunk-for-chunk equality (the vectorized-vs-generic invariant)."""
        return (self.loop == other.loop
                and np.array_equal(self.starts, other.starts)
                and np.array_equal(self.sizes, other.sizes)
                and np.array_equal(self.workers, other.workers))

    def coverage_ok(self) -> bool:
        """Vectorized todo-list invariant: chunks exactly tile [0, N)."""
        n = self.loop.trip_count
        if self.num_chunks == 0:
            return n == 0
        order = np.argsort(self.starts, kind="stable")
        s = self.starts[order]
        z = self.sizes[order]
        return bool(s[0] == 0 and np.all(z >= 0)
                    and np.all(s[1:] == s[:-1] + z[:-1])
                    and s[-1] + z[-1] == n)

    # --------------------------------------------------------------- tables
    def table(self) -> Dict[str, np.ndarray]:
        """(starts, sizes, workers) int32 arrays in dequeue order — the form
        XLA / Pallas scalar prefetch consumes."""
        return {
            "starts": self.starts.astype(np.int32),
            "sizes": self.sizes.astype(np.int32),
            "workers": self.workers.astype(np.int32),
        }

    def device_table(self) -> Dict:
        """:meth:`table` uploaded as int32 device arrays, cached on the
        plan.  Plans are engine-cached across invocations and frozen, so
        steady-state consumers of on-device plan execution (a fused step's
        in-program chunk table, Pallas scalar prefetch) reuse ONE device
        buffer per plan instead of re-uploading host arrays per dispatch.
        JAX is imported lazily — the plan IR itself stays host-only."""
        tab = getattr(self, "_device_table", None)
        if tab is None:
            import jax.numpy as jnp
            tab = {k: jnp.asarray(v) for k, v in self.table().items()}
            object.__setattr__(self, "_device_table", tab)
        return tab

    def device_tile_order(self, n_tiles: Optional[int] = None,
                          order: str = "dequeue"):
        """:meth:`tile_order` uploaded as an int32 device array, cached on
        the plan per ``(n_tiles, order)`` — the prefetched form the fused
        execution paths and Pallas kernels feed to scalar prefetch (one
        upload per plan, amortized over every dispatch that reuses the
        cached plan)."""
        cache = getattr(self, "_device_orders", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_device_orders", cache)
        key = (n_tiles, order)
        if key not in cache:
            import jax.numpy as jnp
            cache[key] = jnp.asarray(self.tile_order(n_tiles, order=order))
        return cache[key]

    def per_worker(self) -> Dict[int, List[Chunk]]:
        out: Dict[int, List[Chunk]] = {w: [] for w in
                                       range(self.loop.num_workers)}
        for s, z, w in zip(self.starts, self.sizes, self.workers):
            out[int(w)].append(Chunk(int(s), int(s + z), int(w)))
        return out

    def worker_iters(self) -> np.ndarray:
        """Iterations assigned per worker — the shard sizes the distributed
        layer consumes (e.g. per-host batch split)."""
        return np.bincount(self.workers, weights=self.sizes,
                           minlength=self.loop.num_workers).astype(np.int64)

    def worker_chunk_counts(self) -> np.ndarray:
        return np.bincount(self.workers,
                           minlength=self.loop.num_workers).astype(np.int64)

    # ------------------------------------------------- membership requeue
    def owned_chunk_ids(self, workers: Sequence[int]) -> np.ndarray:
        """Dequeue-order chunk indices owned by ``workers`` — the plan's
        chunk→worker provenance, queryable (what a membership-loss
        requeue starts from)."""
        lost = np.asarray(sorted({int(w) for w in workers}), np.int64)
        return np.flatnonzero(np.isin(self.workers, lost)).astype(np.int64)

    def unfinished_iters(self, lost_workers: Sequence[int],
                         completed_chunks: Sequence[int] = ()
                         ) -> np.ndarray:
        """Original iteration indices stranded by a membership loss:
        every iteration of a chunk owned by ``lost_workers`` whose chunk
        index is NOT in ``completed_chunks`` — sorted ascending.  This is
        the requeue payload: chunk→worker ownership is plan provenance,
        so the dead workers' unfinished work is recoverable without any
        cooperation from the workers themselves."""
        ids = self.owned_chunk_ids(lost_workers)
        if len(ids) and len(completed_chunks):
            done = np.asarray(sorted({int(i) for i in completed_chunks}),
                              np.int64)
            ids = ids[~np.isin(ids, done)]
        if not len(ids):
            return np.empty(0, np.int64)
        starts = self.starts[ids]
        sizes = self.sizes[ids]
        offsets = np.cumsum(sizes) - sizes
        total = int(sizes.sum())
        out = (np.repeat(starts, sizes)
               + np.arange(total) - np.repeat(offsets, sizes))
        return np.sort(out).astype(np.int64)

    def unfinished_ranges(self, lost_workers: Sequence[int],
                          completed_chunks: Sequence[int] = ()
                          ) -> List[tuple]:
        """:meth:`unfinished_iters` merged into maximal contiguous
        ``(start, stop)`` ranges — the human-auditable form a supervisor
        report carries ("host 3 died owning [512, 768))")."""
        its = self.unfinished_iters(lost_workers, completed_chunks)
        if not len(its):
            return []
        breaks = np.flatnonzero(np.diff(its) != 1)
        starts = np.concatenate([[0], breaks + 1])
        stops = np.concatenate([breaks, [len(its) - 1]])
        return [(int(its[a]), int(its[b]) + 1)
                for a, b in zip(starts, stops)]

    def padded_worker_table(self, pad_chunks: Optional[int] = None
                            ) -> Dict[str, np.ndarray]:
        """Dense (P, max_chunks) tables padded with size-0 chunks — the SPMD
        form (every program instance indexes the same-shaped table).  This is
        what the Pallas ``sched_matmul`` kernel scalar-prefetches."""
        p = self.loop.num_workers
        counts = self.worker_chunk_counts()
        width = int(counts.max()) if self.num_chunks else 0
        if pad_chunks is not None:
            if pad_chunks < width:
                raise ValueError(f"pad_chunks={pad_chunks} < max chunks "
                                 f"{width}")
            width = pad_chunks
        starts = np.zeros((p, width), dtype=np.int32)
        sizes = np.zeros((p, width), dtype=np.int32)
        if self.num_chunks:
            order = np.argsort(self.workers, kind="stable")
            w_sorted = self.workers[order]
            offsets = np.cumsum(counts) - counts
            col = (np.arange(self.num_chunks)
                   - np.repeat(offsets, counts)).astype(np.int64)
            starts[w_sorted, col] = self.starts[order]
            sizes[w_sorted, col] = self.sizes[order]
        return {"starts": starts, "sizes": sizes}

    def tile_order(self, n_tiles: Optional[int] = None,
                   order: str = "dequeue") -> np.ndarray:
        """Expand chunks to their member iterations — the tile-visit
        permutation Pallas kernels scalar-prefetch.

        ``order="dequeue"``: chunks in dequeue order.  For the sequential
        central-queue schedules this is the identity permutation (starts
        ascend), so it only reorders stealing/custom plans.
        ``order="worker"``: worker-major — each worker's chunks contiguous,
        workers in id order.  This is the form a multi-kernel / megacore
        split consumes: lane *w* walks exactly the tile run the UDS
        assigned to worker *w*, so a P-lane split inherits the schedule's
        load balance.
        """
        n = self.loop.trip_count if n_tiles is None else n_tiles
        if order == "worker":
            perm = np.argsort(self.workers, kind="stable")
            starts, sizes = self.starts[perm], self.sizes[perm]
        elif order == "dequeue":
            starts, sizes = self.starts, self.sizes
        else:
            raise ValueError(f"unknown tile order {order!r}")
        total = int(sizes.sum())
        offsets = np.cumsum(sizes) - sizes
        out = (np.repeat(starts, sizes)
               + np.arange(total) - np.repeat(offsets, sizes))
        out = out[out < n]
        return out.astype(np.int32)


# =========================================================================
# Hierarchical composition
# =========================================================================
@dataclasses.dataclass(eq=False)
class ComposedPlan(SchedulePlan):
    """A plan tree: one level's plan outside, per-block child plans inside.

    Compiled by ``PlanEngine._plan_hier`` from a ``hier(...)`` clause.
    The *base* arrays (``starts``/``sizes``/``workers``/``wave_ids``) are
    the OUTERMOST level's plan over the parent loop — verbatim for a
    single-level composition (``identical()`` against the flat clause
    holds), BLOCKIFIED to one contiguous span per worker when children
    exist (composition semantics: worker h owns block h, whatever the
    flat family's dequeue-order chunk layout was).  Every flat-plan
    consumer keeps working unchanged: ``worker_iters()`` is the host
    batch-share vector, and membership requeue recovers a dead host's
    whole contiguous block from the base chunk→worker provenance.

    ``block_bounds[h] : block_bounds[h+1]`` is worker *h*'s contiguous
    iteration block (the outer level's per-worker totals, cumulated in
    worker-id order); ``children[h]`` is the next level's plan over that
    block in LOCAL coordinates ``[0, block size)`` — itself a
    ``ComposedPlan`` when more than one level remains.  ``level_names``
    are the level names from this node down (``("host", "device",
    "tile")`` at the root, ``("device", "tile")`` inside its children).
    A single-level composition has no children and behaves exactly like
    the flat plan.
    """

    level_names: tuple = ()
    block_bounds: Optional[np.ndarray] = None
    children: tuple = ()

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.block_bounds is None:
            totals = np.bincount(self.workers, weights=self.sizes,
                                 minlength=self.loop.num_workers)
            self.block_bounds = np.concatenate(
                [[0], np.cumsum(totals)]).astype(np.int64)
        self.block_bounds = _freeze_array(self.block_bounds)
        if self.children and len(self.children) != \
                self.block_bounds.shape[0] - 1:
            raise ValueError(
                f"composed plan has {len(self.children)} children for "
                f"{self.block_bounds.shape[0] - 1} blocks")
        for h, child in enumerate(self.children):
            n_h = int(self.block_bounds[h + 1] - self.block_bounds[h])
            if child.loop.trip_count != n_h:
                raise ValueError(
                    f"child plan {h} covers {child.loop.trip_count} "
                    f"iterations, block is {n_h}")

    # ------------------------------------------------------------- queries
    @property
    def num_levels(self) -> int:
        child = self.children[0] if self.children else None
        return 1 + (child.num_levels if isinstance(child, ComposedPlan)
                    else 1 if child is not None else 0)

    def host_block(self, worker: int) -> tuple:
        """Outer worker ``worker``'s contiguous block as global
        ``(start, stop)`` iteration indices (``loop.lb``-based, like the
        flat plan's chunk starts)."""
        lb = int(self.loop.lb)
        return (lb + int(self.block_bounds[worker]),
                lb + int(self.block_bounds[worker + 1]))

    def leaf_chunks(self) -> List[dict]:
        """Leaf-level chunks in GLOBAL coordinates, each carrying its
        full per-level ownership path — the provenance the conformance
        suite (and a pod debugger) walks: ``{"start", "size", "owners":
        {"host": h, "device": d, ...}}`` in block-major order."""
        if not self.children:
            lvl = self.level_names[0] if self.level_names else "worker"
            lb = int(self.loop.lb)
            return [{"start": lb + int(s), "size": int(z),
                     "owners": {lvl: int(w)}}
                    for s, z, w in zip(self.starts, self.sizes,
                                       self.workers)]
        lvl = self.level_names[0] if self.level_names else "worker"
        out: List[dict] = []
        for h, child in enumerate(self.children):
            # children are planned over LOCAL [0, block) loops; lift their
            # leaves into this loop's (lb-based) iteration coordinates
            off = int(self.loop.lb) + int(self.block_bounds[h])
            if isinstance(child, ComposedPlan):
                leaves = child.leaf_chunks()
            else:
                nxt = (self.level_names[1]
                       if len(self.level_names) > 1 else "worker")
                leaves = [{"start": int(s), "size": int(z),
                           "owners": {nxt: int(w)}}
                          for s, z, w in zip(child.starts, child.sizes,
                                             child.workers)]
            for leaf in leaves:
                out.append({"start": leaf["start"] + off,
                            "size": leaf["size"],
                            "owners": {lvl: h, **leaf["owners"]}})
        return out

    def tile_order(self, n_tiles: Optional[int] = None,
                   order: str = "dequeue") -> np.ndarray:
        """Leaf tile-visit order, host-block-major: outer workers in id
        order, each block visited in its OWN child plan's ``order`` —
        the per-host-block leaf orders the Pallas front-ends consume.
        Without children this is exactly the flat plan's order."""
        if not self.children:
            return super().tile_order(n_tiles, order=order)
        n = self.loop.trip_count if n_tiles is None else n_tiles
        parts = []
        for h, child in enumerate(self.children):
            sub = child.tile_order(child.loop.trip_count, order=order)
            parts.append(sub.astype(np.int64) + int(self.block_bounds[h]))
        out = (np.concatenate(parts) if parts
               else np.empty(0, np.int64))
        out = out[out < n]
        return out.astype(np.int32)
