"""Lambda-style UDS specification (paper §4.1).

Mirrors the proposed OpenMP syntax::

    #pragma omp declare schedule_template (mystatic) \
        init(@@INIT_LAMBDA@@) dequeue(@@DEQUEUE_LAMBDA@@) \
        finalize(@@FINISH_LAMBDA@@) uds_data(void*)

    #pragma omp parallel for schedule(UDS, template(mystatic))

in Python::

    schedule_template("mystatic", init=..., dequeue=..., finalize=...)
    sched = UDS(template="mystatic", chunk=16, uds_data=my_state)

The lambdas take **no arguments** (exactly as in the paper's Fig. 2 left):
they interact with the loop through the compiler-provided getter/setter
functions below, which this module supplies as module-level functions
reading an implicit per-worker context:

    getters:  OMP_UDS_loop_start()  OMP_UDS_loop_end()  OMP_UDS_loop_step()
              OMP_UDS_chunksize()   OMP_UDS_user_ptr()  OMP_UDS_num_workers()
    setters:  OMP_UDS_loop_chunk_start(i)  OMP_UDS_loop_chunk_end(i)
              OMP_UDS_loop_chunk_step(s)   OMP_UDS_loop_dequeue_done()

A dequeue lambda signals completion either by calling
``OMP_UDS_loop_dequeue_done()`` or by returning a falsy value without
setting a chunk (the paper's ``return 0``).

Templates may be partially overridden at the use site ("overwrite specific
elements of an existing UDS template for a specific loop" — paper §4.1):
``UDS(template="mystatic", dequeue=other_fn)``.
"""

from __future__ import annotations

import copy
import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.core.interface import Chunk, LoopSpec, SchedulerContext
from repro.core.declare import omp_get_thread_num, _set_thread_num

__all__ = [
    "schedule_template", "UDS", "registered_templates",
    "OMP_UDS_loop_start", "OMP_UDS_loop_end", "OMP_UDS_loop_step",
    "OMP_UDS_chunksize", "OMP_UDS_user_ptr", "OMP_UDS_num_workers",
    "OMP_UDS_loop_chunk_start", "OMP_UDS_loop_chunk_end",
    "OMP_UDS_loop_chunk_step", "OMP_UDS_loop_dequeue_done",
    "omp_get_thread_num",
]


@dataclasses.dataclass
class _ActiveLoop:
    loop: LoopSpec
    user_ptr: Any
    # per-dequeue scratch
    chunk_start: Optional[int] = None
    chunk_end: Optional[int] = None
    chunk_step: Optional[int] = None
    done: bool = False


_tls = threading.local()


def _active() -> _ActiveLoop:
    ctx = getattr(_tls, "uds_ctx", None)
    if ctx is None:
        raise RuntimeError(
            "OMP_UDS_* getters/setters may only be called from inside a UDS "
            "lambda during loop execution")
    return ctx


# ------------------------------ getters (compiler-generated in the paper)
def OMP_UDS_loop_start() -> int:
    return _active().loop.lb


def OMP_UDS_loop_end() -> int:
    return _active().loop.ub


def OMP_UDS_loop_step() -> int:
    return _active().loop.incr


def OMP_UDS_chunksize() -> int:
    c = _active().loop.chunk
    return c if c is not None else 1


def OMP_UDS_num_workers() -> int:
    return _active().loop.num_workers


def OMP_UDS_user_ptr() -> Any:
    return _active().user_ptr


# ------------------------------ setters
def OMP_UDS_loop_chunk_start(start_iteration: int) -> None:
    _active().chunk_start = int(start_iteration)


def OMP_UDS_loop_chunk_end(end_iteration: int) -> None:
    _active().chunk_end = int(end_iteration)


def OMP_UDS_loop_chunk_step(step_size: int) -> None:
    _active().chunk_step = int(step_size)


def OMP_UDS_loop_dequeue_done() -> None:
    _active().done = True


# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Template:
    name: str
    init: Optional[Callable[[], Any]]
    dequeue: Callable[[], Any]
    finalize: Optional[Callable[[], Any]]
    uds_data: Any = None


_TEMPLATES: Dict[str, _Template] = {}


def schedule_template(name: str, *, init: Optional[Callable] = None,
                      dequeue: Callable = None,
                      finalize: Optional[Callable] = None,
                      uds_data: Any = None,
                      replace: bool = False) -> _Template:
    """``#pragma omp declare schedule_template(name) ...``"""
    if dequeue is None:
        raise ValueError("a UDS template must define dequeue()")
    if name in _TEMPLATES and not replace:
        raise ValueError(f"template {name!r} already declared")
    tmpl = _Template(name, init, dequeue, finalize, uds_data)
    # mirror first: it validates the name against the unified registry
    # (builtin shadowing), and must not leave a half-registered template
    _mirror_into_spec_registry(tmpl)
    _TEMPLATES[name] = tmpl
    return tmpl


def _mirror_into_spec_registry(tmpl: _Template) -> None:
    """Absorb a template into the unified ScheduleSpec registry so it is
    reachable by name (``resolve("uds:<name>[,chunk]")``) everywhere."""
    from repro.core import spec as _spec

    def factory(*, chunk=None, **overrides):
        # chunk is keyword-only: it must arrive through the spec's
        # validated chunksize (positional clause params are rejected for
        # templates).  A clause denotes a *fresh* schedule instance, so
        # the template's uds_data seed is copied per resolution — state
        # must not leak between independent loops selected by name.
        if "uds_data" not in overrides and tmpl.uds_data is not None:
            overrides["uds_data"] = copy.deepcopy(tmpl.uds_data)
        return UDS(template=tmpl.name, chunk=chunk, **overrides)

    # replace=True only replaces same-source entries: the registry itself
    # rejects shadowing a builtin / user / declare name, atomically
    # (this runs before the template enters the template registry)
    _spec.register_schedule(tmpl.name, source="template",
                            chunk_param="chunk", replace=True)(factory)


def registered_templates() -> List[str]:
    return sorted(_TEMPLATES)


class UDS:
    """``schedule(UDS[:chunkSize][, monotonic|non-monotonic], ...)``.

    Either references a template (``template="name"``) with optional
    per-use overrides, or is fully inline (``init=..., dequeue=...``) —
    the paper's "localized single use loop scheduling strategies".
    """

    def __init__(self, template: Optional[str] = None,
                 chunk: Optional[int] = None,
                 monotonic: bool = True,
                 init: Optional[Callable] = None,
                 dequeue: Optional[Callable] = None,
                 finalize: Optional[Callable] = None,
                 uds_data: Any = None):
        if template is not None:
            if template not in _TEMPLATES:
                raise KeyError(f"no schedule_template {template!r}; "
                               f"known: {registered_templates()}")
            t = _TEMPLATES[template]
            self._init = init or t.init
            self._dequeue = dequeue or t.dequeue
            self._finalize = finalize or t.finalize
            self._uds_data = uds_data if uds_data is not None else t.uds_data
            self.name = f"UDS:{template}"
        else:
            if dequeue is None:
                raise ValueError("inline UDS requires dequeue=")
            self._init, self._dequeue, self._finalize = init, dequeue, finalize
            self._uds_data = uds_data
            self.name = "UDS:<inline>"
        self.chunk = chunk
        self.monotonic = monotonic

    def plan_key(self) -> None:
        # user-supplied closures + mutable uds_data: never plan-cacheable
        return None

    # -- three-op interface --------------------------------------------------
    def start(self, ctx: SchedulerContext) -> Any:
        loop = ctx.loop
        if self.chunk is not None:
            loop = dataclasses.replace(loop, chunk=self.chunk)
        user_ptr = self._uds_data if self._uds_data is not None else ctx.user_data
        active = _ActiveLoop(loop=loop, user_ptr=user_ptr)
        if self._init is not None:
            self._enter(active, 0)
            try:
                self._init()
            finally:
                self._exit()
        return {"active": active, "last_stop_src": {}}

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        active: _ActiveLoop = state["active"]
        active.chunk_start = active.chunk_end = None
        active.chunk_step = None
        active.done = False
        self._enter(active, worker)
        try:
            ret = self._dequeue()
        finally:
            self._exit()
        if active.done:
            return None
        if active.chunk_start is None:
            if not ret:
                return None     # the paper's "return 0" path
            raise RuntimeError(
                f"UDS {self.name}: dequeue returned truthy but never called "
                "OMP_UDS_loop_chunk_start()")
        loop = active.loop
        lo_src = active.chunk_start
        hi_src = active.chunk_end if active.chunk_end is not None else lo_src
        lo = (lo_src - loop.lb) // loop.incr
        hi = (hi_src - loop.lb) // loop.incr
        if self.monotonic:
            # monotonic modifier (OpenMP 5 semantics): each *thread's*
            # successive chunks must be non-decreasing in iteration space.
            prev = state["last_stop_src"].get(worker)
            if prev is not None and lo_src < prev:
                raise RuntimeError(
                    f"UDS {self.name}: monotonic schedule dequeued a chunk "
                    f"starting at {lo_src} before worker {worker}'s previous "
                    f"chunk end {prev}")
            state["last_stop_src"][worker] = hi_src
        return Chunk(lo, hi, worker)

    def finish(self, state: Any) -> None:
        if self._finalize is not None:
            self._enter(state["active"], 0)
            try:
                self._finalize()
            finally:
                self._exit()

    # -- context plumbing -----------------------------------------------------
    @staticmethod
    def _enter(active: _ActiveLoop, worker: int) -> None:
        _tls.uds_ctx = active
        _set_thread_num(worker)

    @staticmethod
    def _exit() -> None:
        _tls.uds_ctx = None
