"""PlanEngine — the single driver of the reduced three-op interface.

Every substrate in this framework (host executor, SPMD wave planner, data
packing, microbatching, MoE capacity, serving admission, Pallas chunk
tables) used to re-drive the UDS state machine with its own Python-level
``next()`` loop.  This module centralizes that: the engine is now the ONLY
place ``sched.next`` is called, in two forms:

* ``PlanEngine.plan(sched, loop)`` — materialize the whole schedule as a
  :class:`~repro.core.plan.SchedulePlan`.  Two backends:

  - **vectorized closed-form compilation** for the non-adaptive scheduler
    families (static block/cyclic/chunk, dynamic/SS, GSS, TSS, TFSS, FSC,
    taper, FAC, FAC2, WF2, RAND): the full chunk table is emitted with
    NumPy arithmetic (batch- or table-level operations) instead of one
    Python ``next()`` round-trip per chunk.  An invariant — enforced by
    ``validate=True`` and by the property tests — guarantees the compiled
    table is chunk-for-chunk identical to the generic driver's.

  - the **generic three-op driver** (the paper's state machine, batched
    into SPMD waves) for adaptive strategies (AWF variants, AF) and
    arbitrary user-defined schedules (lambda-style / declare-style).

  Plans are **cached** keyed on (scheduler identity, LoopSpec, history
  epoch, capability weights): repeated invocations of the same loop — the
  common case in training steps and serving — skip Python dequeue
  entirely and return the frozen plan object.

* ``PlanEngine.open_stream(sched, ctx)`` — a :class:`ScheduleStream` for
  consumers that need chunk-at-a-time dequeue with measurement feedback
  (the executor's discrete-event simulation, packing/microbatch load
  feedback, serving admission).  The stream owns start/next/finish; no
  consumer touches the scheduler state machine directly.

Cache-correctness notes:

* Adaptive schedulers (``sched.adaptive``) consult the cross-invocation
  history at ``start`` time, so their cache key includes the **measured
  history epoch** (``LoopHistory.measured_invocations`` for the loop id):
  recording an invocation of real measurements invalidates the cached
  plan, while planning's own ``elapsed=None`` records do not — repeated
  planning without new measurements hits the cache.
* Non-adaptive schedulers cannot read history, so their keys omit the
  epoch and hit across invocations.  Every ``plan()`` call with a history
  opens an ``InvocationRecord`` regardless of how the plan was produced
  (generic, vectorized, or cache hit), so the measure stage's records
  keep per-step boundaries.
* Schedules carrying unhashable state (closures, user pointers) and calls
  with a ``cost_model`` are never cached.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.history import LoopHistory
from repro.core.interface import (Chunk, LoopSpec, SchedulerContext,
                                  UserDefinedSchedule, ceil_div)
from repro.core.plan import PlanProvenance, SchedulePlan
from repro.core.spec import ScheduleSpec, SpecLike, resolve

__all__ = [
    "PlanEngine",
    "ScheduleStream",
    "CacheStats",
    "get_engine",
    "set_engine",
    "register_compiler",
    "scheduler_plan_key",
    "schedule_tag",
    "plan_worker_order",
]


def schedule_tag(sched: Any) -> Optional[str]:
    """Clause-string provenance of a scheduler, written onto every
    invocation the engine opens (``InvocationRecord.scheduler``).

    Priority: an explicit ``history_tag`` (the auto selector reports the
    *selected candidate's* clause, not its own), then the resolved spec's
    canonical clause string, then the scheduler's name — so a fixed run
    of ``"guided,4"`` and the auto selector delegating to ``"guided,4"``
    tag identically and feed the same per-candidate statistics."""
    tag = getattr(sched, "history_tag", None)
    if tag is not None:
        return str(tag)
    spec = getattr(sched, "_spec", None)
    if isinstance(spec, ScheduleSpec):
        return str(spec)
    name = getattr(sched, "name", None)
    return str(name) if name is not None else type(sched).__name__


# =========================================================================
# Streaming: the one home of the three-op control flow
# =========================================================================
class ScheduleStream:
    """Owns one start/next*/finish lifecycle of a UDS.

    This class is (with the engine's generic driver, which uses it) the only
    code that invokes the reduced interface's ``next`` operation — consumers
    dequeue through it and feed back measured ``elapsed`` times, exactly the
    paper's merged end-body/dequeue/begin-body operation.

    A :class:`~repro.core.telemetry.LoopTelemetry` attached to the context
    becomes the measurement sink for the stream's lifecycle: the scheduler
    hook buffers chunk records into it, and ``close()`` flushes the buffer
    into the history — bumping the measured epoch that invalidates cached
    adaptive plans exactly once per invocation.
    """

    def __init__(self, sched: UserDefinedSchedule, ctx: SchedulerContext):
        self._sched = sched
        self.ctx = ctx
        self.telemetry = ctx.telemetry
        self._state = sched.start(ctx)
        if ctx.history is not None:
            # tagged AFTER start: an auto selector has picked its
            # candidate by now, so provenance names the real schedule
            ctx.history.open_invocation(ctx.loop.loop_id,
                                        scheduler=schedule_tag(sched))
        self.dequeues = 0
        self._closed = False

    def next(self, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        chunk = self._sched.next(self._state, worker, elapsed)
        self.dequeues += 1
        return chunk

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._sched.finish(self._state)
            if self.telemetry is not None:
                self.telemetry.flush()

    def __enter__(self) -> "ScheduleStream":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# =========================================================================
# Scheduler identity (cache keys)
# =========================================================================
class _Unfreezable(Exception):
    pass


def _freeze(v: Any) -> Any:
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return v
    if isinstance(v, (np.integer, np.floating, np.bool_)):
        return v.item()
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, np.ndarray):
        return ("ndarray", v.shape, str(v.dtype), v.tobytes())
    raise _Unfreezable(type(v).__name__)


def scheduler_plan_key(sched: Any) -> Optional[tuple]:
    """Hashable identity of a scheduler *configuration* (not instance).

    Priority order:

    1. an explicit ``plan_key()`` override (lambda-/declare-style UDS
       return None here: user closures are never plan-cacheable);
    2. the :class:`~repro.core.spec.ScheduleSpec` the scheduler was
       resolved from (``sched._spec``) — the schedule-clause identity, so
       two structurally-equal specs built independently share cache
       entries regardless of instance identity.  The frozen *live* public
       parameters stay part of the key, so mutating a resolved scheduler
       after the fact cannot silently hit the stale spec's plan;
    3. otherwise the class + frozen public constructor parameters
       (schedulers are deterministic state machines over their parameters
       + context).

    Returns None for schedulers carrying unhashable state (e.g.
    lambda-style closures) — such schedules are planned fresh every time.
    """
    fn = getattr(sched, "plan_key", None)
    if callable(fn):
        return fn()
    try:
        params = tuple(sorted(
            (k, _freeze(v)) for k, v in vars(sched).items()
            if not k.startswith("_")))
    except _Unfreezable:
        return None
    spec = getattr(sched, "_spec", None)
    if spec is not None and isinstance(spec, ScheduleSpec):
        try:
            hash(spec)
            return ("spec", spec, params)
        except TypeError:
            pass        # non-scalar spec params: fall through
    return (type(sched).__module__, type(sched).__qualname__, params)


# =========================================================================
# Vectorized closed-form compilers
# =========================================================================
# A compiler maps (sched, ctx) -> chunk-size array in dequeue order (all
# registered families are central-queue / sequential-start schedules, so
# starts = cumsum(sizes) and chunk i belongs to worker i mod P — the exact
# wave-order semantics of the generic driver).  Registered by EXACT type:
# subclasses (e.g. AWF extending WF2 with adaptivity) must opt in
# explicitly.
_COMPILERS: Dict[type, Callable[[Any, SchedulerContext],
                                Optional[np.ndarray]]] = {}


def register_compiler(*types: type):
    """Register a vectorized closed-form compiler for scheduler types."""
    def deco(fn):
        for t in types:
            _COMPILERS[t] = fn
        return fn
    return deco


def has_compiler(sched: Any) -> bool:
    return type(sched) in _COMPILERS


def _fixed_sizes(n: int, c: int) -> np.ndarray:
    """Chunk-size table for a fixed chunk c: c, c, ..., remainder."""
    if n <= 0:
        return np.zeros(0, np.int64)
    c = max(1, int(c))
    m = ceil_div(n, c)
    sizes = np.full(m, c, np.int64)
    sizes[-1] = n - (m - 1) * c
    return sizes


def _clip_to_trip(des: np.ndarray, n: int) -> np.ndarray:
    """Truncate a desired-size sequence at trip count n (the central
    counter's per-dequeue ``min(size, remaining)`` clamp, vectorized)."""
    cum = np.cumsum(des)
    cut = int(np.searchsorted(cum, n, side="left"))
    sizes = des[:cut + 1].copy()
    sizes[cut] = n - (int(cum[cut - 1]) if cut else 0)
    return sizes


def _register_builtin_compilers() -> None:
    from repro.core.schedulers.classic import (FixedSizeChunking, GuidedSS,
                                               RandSS, SelfScheduling,
                                               StaticBlock, StaticChunk,
                                               StaticCyclic, Taper,
                                               TrapezoidFactoring,
                                               TrapezoidSS)
    from repro.core.schedulers.factoring import (FAC, FAC2,
                                                 WeightedFactoring)

    @register_compiler(StaticChunk, StaticBlock, StaticCyclic)
    def _static(sched, ctx):
        # schedule(static, c) under wave order IS the fixed-chunk table with
        # round-robin workers: chunk i = [i*c, (i+1)*c) on worker i mod P.
        loop = ctx.loop
        n, p = loop.trip_count, loop.num_workers
        c = sched.chunk or loop.chunk or ceil_div(max(n, 1), p)
        return _fixed_sizes(n, c)

    @register_compiler(SelfScheduling)
    def _dynamic(sched, ctx):
        c = sched.chunk or ctx.loop.chunk or 1
        return _fixed_sizes(ctx.loop.trip_count, c)

    @register_compiler(FixedSizeChunking)
    def _fsc(sched, ctx):
        n = ctx.loop.trip_count
        if n <= 0:
            return np.zeros(0, np.int64)
        state = sched.init(ctx)          # reuse the Kruskal-Weiss formula
        return _fixed_sizes(n, state.scratch["chunk"])

    @register_compiler(GuidedSS)
    def _guided(sched, ctx):
        # GSS: size_j = max(m, ceil(R_j / P)).  The decaying head is an
        # integer recurrence (no closed form under ceil), emitted by a tight
        # scalar loop; the fixed tail (size == min_chunk) is emitted as one
        # NumPy fill.
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        mc = sched.min_chunk
        head: List[int] = []
        push = head.append
        r = n
        while r > 0:
            s = -(-r // p)                   # ceil(r / p), inlined
            if s <= mc:
                break
            push(s)
            r -= s
        sizes = np.asarray(head, np.int64)
        if r > 0:
            k, rem = divmod(r, mc)
            tail = np.full(k + (1 if rem else 0), mc, np.int64)
            if rem:
                tail[-1] = rem
            sizes = np.concatenate([sizes, tail])
        return sizes

    @register_compiler(TrapezoidSS)
    def _tss(sched, ctx):
        # TSS: size_k = max(round(first - k*delta), last) — a pure function
        # of the dequeue index, emitted as one vectorized table.
        n = ctx.loop.trip_count
        if n <= 0:
            return np.zeros(0, np.int64)
        state = sched.init(ctx)          # reuse first/last/delta derivation
        first = state.scratch["first"]
        last = state.scratch["last"]
        delta = state.scratch["delta"]
        k = max(ceil_div(2 * n, first + last), 1) + 4
        while True:
            ks = np.arange(k, dtype=np.float64)
            des = np.maximum(
                np.floor(first - ks * delta + 0.5).astype(np.int64), last)
            if int(des.sum()) >= n:
                break
            k *= 2
        return _clip_to_trip(des, n)

    @register_compiler(TrapezoidFactoring)
    def _tfss(sched, ctx):
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        if n <= 0:
            return np.zeros(0, np.int64)
        state = sched.init(ctx)
        f = state.scratch["first"]
        last = state.scratch["last"]
        delta = state.scratch["delta"]
        parts: List[np.ndarray] = []
        r = n
        while r > 0:
            b = max(int(math.floor(f + 0.5)), last)
            f = max(f - delta, float(last))
            full = min(p, r // b)
            batch = np.full(full, b, np.int64)
            rem = r - full * b
            if full < p and rem > 0:
                batch = np.append(batch, rem)
            parts.append(batch)
            r -= int(batch.sum())
        return np.concatenate(parts)

    @register_compiler(FAC, FAC2)
    def _fac(sched, ctx):
        # Factoring: batches of P equal chunks; the batch size comes from
        # the scheduler's own _open_batch (FAC2's R/2P or FAC's
        # probabilistic x-factor), driven once per batch instead of once
        # per chunk.
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        if n <= 0:
            return np.zeros(0, np.int64)
        state = sched.init(ctx)
        parts: List[np.ndarray] = []
        r = n
        while r > 0:
            state.remaining = r
            sched._open_batch(state)
            b = int(state.scratch["batch_chunk"])
            full = min(p, r // b)
            batch = np.full(full, b, np.int64)
            rem = r - full * b
            if full < p and rem > 0:
                batch = np.append(batch, rem)
            parts.append(batch)
            r -= int(batch.sum())
        return np.concatenate(parts)

    @register_compiler(WeightedFactoring)
    def _wf2(sched, ctx):
        # WF2: per-batch base chunk from FAC2, per-worker size
        # round(w_i * base); batches align with waves so chunk i of a batch
        # belongs to worker i.
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        if n <= 0:
            return np.zeros(0, np.int64)
        state = sched.init(ctx)
        wvec = np.asarray([sched._weight(state, i) for i in range(p)],
                          np.float64)
        parts: List[np.ndarray] = []
        r = n
        while r > 0:
            state.remaining = r
            sched._open_batch(state)
            b = int(state.scratch["batch_chunk"])
            des = np.maximum(1, np.round(wvec * b)).astype(np.int64)
            cum = np.cumsum(des)
            if int(cum[-1]) <= r:
                batch = des
            else:
                cut = int(np.searchsorted(cum, r, side="left"))
                prev = int(cum[cut - 1]) if cut else 0
                batch = np.append(des[:cut], r - prev)
            parts.append(batch)
            r -= int(batch.sum())
        return np.concatenate(parts)

    @register_compiler(RandSS)
    def _rand(sched, ctx):
        # RAND draws one uniform integer per dequeue; NumPy fills arrays
        # element-wise from the same PCG stream, so batch draws reproduce
        # the sequential sequence exactly.
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        if n <= 0:
            return np.zeros(0, np.int64)
        rng = np.random.default_rng(sched.seed)
        lo = sched.min_chunk
        hi = max(sched.max_chunk or ceil_div(max(n, 1), p), lo)
        draws: List[np.ndarray] = []
        total = 0
        while total < n:
            k = max(64, ceil_div(n - total, max((lo + hi) // 2, 1)) + 8)
            d = rng.integers(lo, hi + 1, size=k)
            draws.append(d.astype(np.int64))
            total += int(d.sum())
        return _clip_to_trip(np.concatenate(draws), n)

    @register_compiler(Taper)
    def _taper(sched, ctx):
        # Taper's size recurrence is sequential (size_k depends on R_k),
        # but its tail is not: x(t) = t + v²/2 − v·sqrt(2t + v²/4) ≤ t for
        # every v ≥ 0, so once R/P ≤ min_chunk the clamp max(mc, ceil(x))
        # pins ALL remaining sizes to mc.  Emit the decaying head with a
        # tight scalar loop (constants hoisted, ceil_div inlined) and the
        # fixed tail as one NumPy fill — the same head/tail split that puts
        # GSS past the 10× planning bar.
        n, p = ctx.loop.trip_count, ctx.loop.num_workers
        if n <= 0:
            return np.zeros(0, np.int64)
        mc, v = sched.min_chunk, sched.v
        head: List[int] = []
        push = head.append
        r = n
        if v <= 0:
            while r > mc * p:
                s = -(-r // p)                   # ceil(r / p), inlined
                push(s)
                r -= s
        else:
            half_v2 = 0.5 * v * v
            quarter_v2 = 0.25 * v * v
            sqrt, ceil = math.sqrt, math.ceil
            while r > mc * p:
                t = r / p
                s = int(ceil(t + half_v2 - v * sqrt(2.0 * t + quarter_v2)))
                if s < mc:
                    s = mc
                push(s)
                r -= s
        sizes = np.asarray(head, np.int64)
        if r > 0:
            k, rem = divmod(r, mc)
            tail = np.full(k + (1 if rem else 0), mc, np.int64)
            if rem:
                tail[-1] = rem
            sizes = np.concatenate([sizes, tail])
        return sizes


# =========================================================================
# The engine
# =========================================================================
@dataclasses.dataclass
class CacheStats:
    """Plan-cache counters (``PlanEngine.cache_info()``); ``uncacheable``
    counts plans whose scheduler declined a cache key."""

    hits: int = 0
    misses: int = 0
    uncacheable: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PlanEngine:
    """Compile, cache, and stream user-defined schedules.

    ``validate=True`` (or env ``REPRO_PLAN_VALIDATE=1``) cross-checks every
    vectorized plan against the generic driver — the executable form of the
    compilation invariant.
    """

    def __init__(self, cache_size: int = 256,
                 validate: Optional[bool] = None):
        self.cache_size = cache_size
        if validate is None:
            validate = os.environ.get("REPRO_PLAN_VALIDATE", "") not in ("", "0")
        self.validate = validate
        self._cache: "OrderedDict[tuple, SchedulePlan]" = OrderedDict()
        self.stats = CacheStats()

    # ------------------------------------------------------------- streams
    def open_stream(self, sched: UserDefinedSchedule,
                    ctx: Union[SchedulerContext, LoopSpec],
                    telemetry: Any = None,
                    **ctx_kw: Any) -> ScheduleStream:
        """Chunk-at-a-time dequeue with measurement feedback (executor,
        packing, microbatching, serving admission).

        ``telemetry``: a ``LoopTelemetry`` to attach as the stream's
        measurement sink (flushed into the history on ``close``).  A
        telemetry with no history of its own inherits the context's.
        """
        if isinstance(ctx, LoopSpec):
            ctx = SchedulerContext(loop=ctx, **ctx_kw)
        if telemetry is not None:
            if telemetry.history is None:
                telemetry.history = ctx.history
            if telemetry.loop_id is None:
                telemetry.loop_id = ctx.loop.loop_id
            if telemetry.num_workers is None:
                telemetry.num_workers = ctx.loop.num_workers
            ctx = dataclasses.replace(ctx, telemetry=telemetry)
        return ScheduleStream(sched, ctx)

    # ------------------------------------------------------------ planning
    def plan(self, sched: UserDefinedSchedule,
             loop: Union[LoopSpec, SchedulerContext],
             *,
             history: Optional[LoopHistory] = None,
             user_data: Any = None,
             weights: Optional[Sequence[float]] = None,
             cost_model: Optional[Callable[[Chunk, int], float]] = None,
             check_coverage: bool = True,
             mode: str = "auto") -> SchedulePlan:
        """Materialize the full schedule for one loop invocation.

        mode: "auto" (cache, then vectorized, then generic), "vectorized"
        (closed-form only; raises if the scheduler has no compiler), or
        "generic" (state-machine driver; bypasses the cache — used by the
        validation path and benchmarks).
        """
        if mode not in ("auto", "vectorized", "generic"):
            raise ValueError(f"unknown plan mode {mode!r}")
        if isinstance(loop, SchedulerContext):
            ctx = loop
        else:
            ctx = SchedulerContext(loop=loop, history=history,
                                   user_data=user_data, weights=weights)

        cacheable = mode == "auto" and cost_model is None
        key = self._cache_key(sched, ctx) if cacheable else None
        if cacheable and key is None:
            self.stats.uncacheable += 1
        if key is not None:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.stats.hits += 1
                if ctx.history is not None:
                    # every plan() marks an invocation boundary, however it
                    # was produced, so the measure stage's records land in
                    # this step's InvocationRecord
                    ctx.history.open_invocation(
                        ctx.loop.loop_id, scheduler=schedule_tag(sched))
                return hit
            self.stats.misses += 1

        t0 = time.perf_counter()
        plan: Optional[SchedulePlan] = None
        hier_levels = getattr(sched, "hier_levels", None)
        if hier_levels:
            # hierarchical composition (core/hier.py): plan the outer
            # level over this loop, then re-plan every contiguous block
            # with the next level — never the flat backends directly
            if cost_model is not None:
                raise ValueError(
                    "hier plans take no cost_model (model the level "
                    "clauses' plans individually instead)")
            plan = self._plan_hier(sched, ctx, mode, key, t0)
            if ctx.history is not None:
                ctx.history.open_invocation(
                    ctx.loop.loop_id, scheduler=schedule_tag(sched))
        elif mode in ("auto", "vectorized") and cost_model is None:
            compiler = _COMPILERS.get(type(sched))
            if compiler is not None:
                sizes = compiler(sched, ctx)
                plan = self._plan_from_sizes(sched, ctx, sizes, key, t0)
                if ctx.history is not None:
                    # invocation boundary (the generic path opens its own
                    # through ScheduleStream)
                    ctx.history.open_invocation(
                        ctx.loop.loop_id, scheduler=schedule_tag(sched))
                if self.validate:
                    ref = self._plan_generic(
                        sched, SchedulerContext(loop=ctx.loop,
                                                weights=ctx.weights,
                                                user_data=ctx.user_data),
                        None, None, t0)
                    if not plan.identical(ref):
                        raise AssertionError(
                            f"vectorized plan for "
                            f"{getattr(sched, 'name', sched)!r} diverges "
                            f"from the generic three-op driver")
            elif mode == "vectorized":
                raise ValueError(
                    f"no vectorized compiler registered for "
                    f"{type(sched).__name__}")
        if plan is None:
            plan = self._plan_generic(sched, ctx, cost_model, key, t0)

        if check_coverage and not plan.coverage_ok():
            raise AssertionError(
                f"scheduler {getattr(sched, 'name', sched)!r} violated the "
                f"todo-list invariant: chunks do not exactly tile "
                f"[0, {ctx.loop.trip_count})")
        if key is not None:
            # a scheduler whose identity shifts *during* planning — the
            # auto selector settles on its candidate at start() — is
            # re-keyed after the fact, so the cached entry is reachable
            # by the key the NEXT call computes
            key = self._cache_key(sched, ctx) or key
            self._cache[key] = plan
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
                self.stats.evictions += 1
        return plan

    # --------------------------------------------------- membership requeue
    def requeue_plan(self, plan: SchedulePlan, sched: SpecLike, *,
                     lost_workers: Sequence[int], num_workers: int,
                     completed_chunks: Sequence[int] = (),
                     history: Optional[LoopHistory] = None,
                     weights: Optional[Sequence[float]] = None,
                     loop_id: Optional[str] = None
                     ) -> tuple:
        """Replan a dead team member's unfinished work over the survivors.

        ``plan`` is the schedule that was executing when the membership
        loss landed; ``lost_workers`` are its (old-team) worker ids that
        left, ``completed_chunks`` the dequeue-order chunk indices already
        finished.  The stranded iterations are recovered from the plan's
        chunk→worker provenance (:meth:`SchedulePlan.unfinished_iters`)
        and planned as a fresh virtual loop ``[0, n_unfinished)`` over the
        ``num_workers``-strong surviving team under ``sched`` — the
        paper's contract, literally: re-run ``init`` + ``enqueue`` for the
        current team.

        Returns ``(new_plan, iter_map)`` where ``iter_map[v]`` is the
        ORIGINAL iteration index that virtual iteration ``v`` stands for
        (so ``new_plan``'s coverage invariant holds over the virtual
        range while callers still know exactly which real work moved
        where).  No iteration is silently lost:
        ``len(iter_map) == sum of the lost workers' unfinished sizes``.
        """
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        iters = plan.unfinished_iters(lost_workers, completed_chunks)
        lid = loop_id or f"{plan.loop.loop_id}/requeue"
        loop = LoopSpec(lb=0, ub=int(len(iters)), num_workers=num_workers,
                        loop_id=lid)
        if not len(iters):
            empty = np.empty(0, np.int64)
            return (SchedulePlan(
                loop=loop, starts=empty, sizes=empty, workers=empty,
                wave_ids=empty,
                provenance=PlanProvenance(scheduler="requeue",
                                          source="requeue")), iters)
        sched = resolve(sched)
        if hasattr(sched, "select"):
            # schedule(auto): reselect against the post-churn history so
            # the requeue plan uses the clause auto now favors
            sched.select(history if history is not None else LoopHistory(),
                         loop, weights=list(weights) if weights else None)
        new_plan = self.plan(sched, loop, history=history,
                             weights=list(weights) if weights else None)
        return new_plan, iters

    # -------------------------------------------------------------- cache
    def _cache_key(self, sched: Any,
                   ctx: SchedulerContext) -> Optional[tuple]:
        skey = scheduler_plan_key(sched)
        if skey is None:
            return None
        try:
            wkey = (_freeze(tuple(ctx.weights))
                    if ctx.weights is not None else None)
            ukey = (_freeze(ctx.user_data)
                    if ctx.user_data is not None else None)
        except _Unfreezable:
            return None
        if getattr(sched, "adaptive", False):
            # adaptive strategies read the history at start: key on the
            # history's identity token AND its *measured* epoch — distinct
            # histories with equal epoch counts must not share plans, and
            # new measurements invalidate while planning's own
            # elapsed=None records do not
            if ctx.history is not None:
                epoch = (getattr(ctx.history, "token", id(ctx.history)),
                         ctx.history.measured_invocations(ctx.loop.loop_id))
            else:
                epoch = -1
        else:
            epoch = None
        return (skey, ctx.loop, epoch, wkey, ukey)

    def cache_info(self) -> CacheStats:
        return self.stats

    def clear_cache(self) -> None:
        self._cache.clear()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._cache)

    # ------------------------------------------------------------ backends
    def _plan_from_sizes(self, sched: Any, ctx: SchedulerContext,
                         sizes: np.ndarray, key: Optional[tuple],
                         t0: float) -> SchedulePlan:
        sizes = np.asarray(sizes, np.int64)
        m = sizes.shape[0]
        starts = np.cumsum(sizes) - sizes
        idx = np.arange(m, dtype=np.int64)
        p = max(ctx.loop.num_workers, 1)
        prov = PlanProvenance(
            scheduler=getattr(sched, "name", type(sched).__name__),
            source="vectorized", cache_key=key,
            plan_time_s=time.perf_counter() - t0)
        return SchedulePlan(loop=ctx.loop, starts=starts, sizes=sizes,
                            workers=idx % p, wave_ids=idx // p,
                            provenance=prov)

    def _plan_generic(self, sched: Any, ctx: SchedulerContext,
                      cost_model: Optional[Callable[[Chunk, int], float]],
                      key: Optional[tuple], t0: float) -> SchedulePlan:
        """The paper's state machine, batched into SPMD waves: each wave
        hands one chunk to every still-active worker; ``cost_model`` chunk
        costs (if given) are fed back as the previous chunk's ``elapsed``
        so adaptive strategies can plan against a model."""
        loop = ctx.loop
        p = loop.num_workers
        starts: List[int] = []
        sizes: List[int] = []
        workers: List[int] = []
        wave_ids: List[int] = []
        with self.open_stream(sched, ctx) as stream:
            active = set(range(p))
            last: Dict[int, Optional[float]] = {w: None for w in range(p)}
            wave = 0
            guard = 0
            while active:
                got = 0
                for w in sorted(active):
                    chunk = stream.next(w, last[w])
                    if chunk is None:
                        active.discard(w)
                        continue
                    last[w] = cost_model(chunk, w) if cost_model else None
                    starts.append(chunk.start)
                    sizes.append(chunk.stop - chunk.start)
                    workers.append(chunk.worker)
                    wave_ids.append(wave)
                    got += 1
                if got:
                    wave += 1
                guard += 1
                if guard > 10 * max(loop.trip_count, 1) + 16:
                    raise RuntimeError(
                        f"scheduler {getattr(sched, 'name', sched)!r} failed"
                        f" to drain the todo list (livelock guard tripped)")
        prov = PlanProvenance(
            scheduler=getattr(sched, "name", type(sched).__name__),
            source="generic", cache_key=key,
            plan_time_s=time.perf_counter() - t0)
        return SchedulePlan(loop=loop,
                            starts=np.asarray(starts, np.int64),
                            sizes=np.asarray(sizes, np.int64),
                            workers=np.asarray(workers, np.int64),
                            wave_ids=np.asarray(wave_ids, np.int64),
                            provenance=prov)

    # ------------------------------------------------- hierarchical plans
    def _plan_hier(self, sched: Any, ctx: SchedulerContext, mode: str,
                   key: Optional[tuple], t0: float) -> SchedulePlan:
        """Compose a ``hier(...)`` scheduler into a ComposedPlan (see
        core/hier.py for the clause; core/plan.py for the IR)."""
        levels = tuple(sched.hier_levels)
        lw = tuple(getattr(sched, "hier_level_workers", ()) or ())
        if len(lw) < len(levels):
            lw = lw + (None,) * (len(levels) - len(lw))
        return self._compose_levels(ctx, levels, lw, mode, key, t0,
                                    schedule_tag(sched))

    def _compose_levels(self, ctx: SchedulerContext, levels: tuple,
                        lvl_workers: tuple, mode: str,
                        key: Optional[tuple], t0: float,
                        tag: Optional[str]) -> SchedulePlan:
        """One composition step: plan ``levels[0]`` over the context's
        loop (the SAME loop — a single-level hier is chunk-for-chunk the
        flat plan, sharing its cache entry), derive each outer worker's
        contiguous block from the per-worker iteration totals, and
        re-plan every block with the remaining levels over a virtual
        child loop ``[0, block)`` whose loop_id extends the parent's
        (``train_step/host2`` — telemetry and adaptive replanning
        attribute per block).  Block plans go through ``self.plan``, so
        each level rides the ordinary plan cache."""
        from repro.core.plan import ComposedPlan

        name0, spec0 = levels[0]
        loop0 = ctx.loop
        p0 = lvl_workers[0] or loop0.num_workers
        if p0 != loop0.num_workers:
            loop0 = dataclasses.replace(loop0, num_workers=p0)
        weights = (list(ctx.weights)
                   if ctx.weights is not None else None)
        base = self.plan(resolve(spec0), loop0, history=ctx.history,
                         user_data=ctx.user_data, weights=weights,
                         mode=mode)
        starts, sizes = base.starts, base.sizes
        workers, wave_ids = base.workers, base.wave_ids
        children: List[SchedulePlan] = []
        if len(levels) > 1:
            totals = base.worker_iters()
            # BLOCKIFY the outer level: composition semantics are "worker
            # h owns the contiguous block [bounds[h], bounds[h+1])" sized
            # by its planned total.  Central-queue chunk layouts (AWF/AF
            # dequeue order interleaves workers) keep their per-worker
            # TOTALS but are rearranged into one contiguous span per
            # worker, so membership requeue recovers exactly a dead
            # worker's block.  A single-level hier skips this and stays
            # chunk-for-chunk identical to the flat plan.
            bounds = np.concatenate([[0], np.cumsum(totals)]).astype(
                np.int64)
            live = np.flatnonzero(totals > 0)
            starts = bounds[live].astype(np.int64)   # 0-based trip offsets
            sizes = totals[live].astype(np.int64)
            workers = live.astype(np.int64)
            wave_ids = np.zeros(live.shape[0], np.int64)
            child_p = lvl_workers[1] or loop0.num_workers
            for h in range(loop0.num_workers):
                child_loop = LoopSpec(
                    lb=0, ub=int(totals[h]), num_workers=child_p,
                    loop_id=f"{loop0.loop_id}/{name0}{h}")
                child_ctx = SchedulerContext(loop=child_loop,
                                             history=ctx.history,
                                             user_data=ctx.user_data)
                if len(levels) == 2:
                    child = self.plan(resolve(levels[1][1]), child_ctx,
                                      mode=mode)
                else:
                    child = self._compose_levels(
                        child_ctx, levels[1:], lvl_workers[1:], mode,
                        None, t0, tag)
                children.append(child)
        prov = PlanProvenance(
            scheduler=tag or "hier", source="composed", cache_key=key,
            plan_time_s=time.perf_counter() - t0)
        return ComposedPlan(loop=loop0, starts=starts, sizes=sizes,
                            workers=workers, wave_ids=wave_ids,
                            provenance=prov,
                            level_names=tuple(n for n, _ in levels),
                            children=tuple(children))


_register_builtin_compilers()


def plan_worker_order(sched: SpecLike, n: int, *, num_workers: int = 2,
                      loop_id: str = "tiles",
                      engine: Optional["PlanEngine"] = None,
                      device: bool = False,
                      **sched_params: Any) -> np.ndarray:
    """Worker-major tile-visit order for ``sched`` (a ScheduleSpec, clause
    string like ``"guided,4"``, or scheduler instance) over [0, n) — the
    shared front-end of the Pallas kernel table plumbing
    (``sched_matmul.plan_tile_order`` / ``flash_attention
    .plan_q_block_order``).  Each of the ``num_workers`` kernel lanes
    (default 2 = TPU megacore) gets its worker's contiguous tile run, so
    the lanes inherit the schedule's load balance.  Plans are cached by
    the engine across launches, keyed on the spec.

    ``device=True`` returns the plan's cached int32 *device* array
    (``SchedulePlan.device_tile_order``) instead of a host array: a cache
    hit reuses the buffer already uploaded for a previous launch, so the
    steady-state kernel path ships NO plan bytes host→device."""
    sched = resolve(sched, **sched_params)
    eng = engine if engine is not None else get_engine()
    loop = LoopSpec(lb=0, ub=n, num_workers=num_workers, loop_id=loop_id)
    plan = eng.plan(sched, loop)
    order = plan.tile_order(n, order="worker")
    if not np.array_equal(np.sort(order), np.arange(n)):
        raise AssertionError(
            f"plan for {getattr(sched, 'name', sched)!r} does not tile "
            f"[0, {n}) exactly")
    if device:
        return plan.device_tile_order(n, order="worker")
    return order


_DEFAULT_ENGINE: Optional[PlanEngine] = None


def get_engine() -> PlanEngine:
    """The process-wide default engine (shared plan cache)."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = PlanEngine()
    return _DEFAULT_ENGINE


def set_engine(engine: PlanEngine) -> PlanEngine:
    global _DEFAULT_ENGINE
    _DEFAULT_ENGINE = engine
    return engine
