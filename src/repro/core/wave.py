"""SPMD wave planning — a thin view over the PlanEngine.

On an SPMD mesh there is no shared work queue: every chip executes one XLA
program, so dynamic scheduling becomes **plan–execute–measure**:

  1. *plan*    — ``core.engine.PlanEngine`` materializes the schedule as a
     :class:`~repro.core.plan.SchedulePlan`.  Non-adaptive strategies
     compile to their closed-form chunk tables with NumPy arithmetic;
     adaptive ones run the generic three-op driver, whose dequeues are
     batched into *waves* (one chunk per still-active worker per round —
     a worker is a data-parallel shard, an expert, or a kernel grid lane,
     depending on the consumer).  Repeated invocations of the same loop
     hit the engine's plan cache and skip Python dequeue entirely.
  2. *execute* — the static plan parameterizes the compiled step (batch
     shard sizes via ``worker_iters``, expert capacities, Pallas chunk
     tables via ``table``/``padded_worker_table``/``tile_order``).
  3. *measure* — per-worker timings flow back into the ``LoopHistory``;
     recording a new invocation bumps the history epoch, which invalidates
     cached plans of adaptive schedulers, so the next plan's ``next()``
     calls see real measurements (type-(3) adaptive scheduling at step
     granularity).

The chunk tables produced here are **identical** to the host executor's
state machine (same ``next`` semantics, enforced by the engine's
vectorized-vs-generic invariant); only the dequeue *cadence* changes —
mirroring the paper's own merge of ``enqueue`` into ``init`` when the
iteration space is fixed ahead of time.

This module keeps the historical entry points (``plan_waves``,
``plan_schedule``) and re-exports ``SchedulePlan``; new code should talk
to the engine directly (``repro.core.engine.get_engine()``).
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

from repro.core.engine import PlanEngine, get_engine
from repro.core.history import LoopHistory
from repro.core.interface import Chunk, LoopSpec, UserDefinedSchedule
from repro.core.plan import SchedulePlan

__all__ = ["SchedulePlan", "plan_waves", "plan_schedule"]


def plan_waves(sched: UserDefinedSchedule,
               loop: LoopSpec,
               *,
               history: Optional[LoopHistory] = None,
               user_data: Any = None,
               weights: Optional[Sequence[float]] = None,
               cost_model: Optional[Callable[[Chunk, int], float]] = None,
               check_coverage: bool = True,
               engine: Optional[PlanEngine] = None) -> SchedulePlan:
    """Materialize the schedule for one loop invocation via the engine.

    ``cost_model(chunk, worker)`` — if given, predicted chunk costs are fed
    to ``next()`` as the ``elapsed`` of the previous chunk, letting adaptive
    schedulers plan against a model (they still re-adapt against *real*
    measurements between steps via ``history``); such calls always run the
    generic driver and bypass the plan cache.
    """
    eng = engine if engine is not None else get_engine()
    return eng.plan(sched, loop, history=history, user_data=user_data,
                    weights=weights, cost_model=cost_model,
                    check_coverage=check_coverage)


def plan_schedule(sched: UserDefinedSchedule, n: int, num_workers: int,
                  **kw: Any) -> SchedulePlan:
    """Convenience: plan over logical iterations [0, n)."""
    loop = LoopSpec(lb=0, ub=n, num_workers=num_workers,
                    loop_id=kw.pop("loop_id", "plan"))
    return plan_waves(sched, loop, **kw)
