"""SPMD wave planner — the TPU adaptation of per-thread dequeue.

On an SPMD mesh there is no shared work queue: every chip executes one XLA
program.  Dynamic scheduling therefore becomes **plan–execute–measure**:

  1. *plan*   — host-side, the UDS runs exactly as in the executor, but
     dequeues are *batched into waves*: each wave assigns one chunk to every
     still-active worker (a worker == a data-parallel shard, an expert, or a
     kernel grid lane, depending on the consumer);
  2. *execute* — the resulting static ``SchedulePlan`` parameterizes the
     compiled step (batch shard sizes, expert capacities, Pallas chunk
     tables);
  3. *measure* — per-worker timings flow back into the ``LoopHistory``, so
     the next plan's ``next()`` calls see real measurements (type-(3)
     adaptive scheduling at step granularity).

The chunk-size sequences produced here are **identical** to the host
executor's (same ``next`` calls, same state machine); only the dequeue
*cadence* changes — mirroring the paper's own merge of ``enqueue`` into
``init`` when the iteration space is fixed ahead of time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.history import LoopHistory
from repro.core.interface import (
    Chunk,
    LoopSpec,
    SchedulerContext,
    UserDefinedSchedule,
    chunks_cover,
)

__all__ = ["SchedulePlan", "plan_waves", "plan_schedule"]


@dataclasses.dataclass
class SchedulePlan:
    """A fully-materialized schedule: the todo list after all dequeues.

    ``waves[r]`` is the list of chunks dequeued in round r (≤ one per
    worker).  ``table()`` flattens to arrays consumable by XLA / Pallas
    scalar prefetch.
    """

    loop: LoopSpec
    waves: List[List[Chunk]]

    @property
    def chunks(self) -> List[Chunk]:
        return [c for wave in self.waves for c in wave]

    @property
    def num_waves(self) -> int:
        return len(self.waves)

    def table(self) -> Dict[str, np.ndarray]:
        """(starts, sizes, workers) int32 arrays in dequeue order."""
        cs = self.chunks
        return {
            "starts": np.asarray([c.start for c in cs], dtype=np.int32),
            "sizes": np.asarray([c.size for c in cs], dtype=np.int32),
            "workers": np.asarray([c.worker for c in cs], dtype=np.int32),
        }

    def per_worker(self) -> Dict[int, List[Chunk]]:
        out: Dict[int, List[Chunk]] = {w: [] for w in range(self.loop.num_workers)}
        for c in self.chunks:
            out[c.worker].append(c)
        return out

    def worker_iters(self) -> np.ndarray:
        """Iterations assigned per worker — the shard sizes the distributed
        layer consumes (e.g. per-host batch split)."""
        out = np.zeros(self.loop.num_workers, dtype=np.int64)
        for c in self.chunks:
            out[c.worker] += c.size
        return out

    def padded_worker_table(self, pad_chunks: Optional[int] = None
                            ) -> Dict[str, np.ndarray]:
        """Dense (P, max_chunks) tables padded with size-0 chunks — the SPMD
        form (every program instance indexes the same-shaped table).  This is
        what the Pallas ``sched_matmul`` kernel scalar-prefetches."""
        per = self.per_worker()
        width = max((len(v) for v in per.values()), default=0)
        if pad_chunks is not None:
            if pad_chunks < width:
                raise ValueError(f"pad_chunks={pad_chunks} < max chunks {width}")
            width = pad_chunks
        p = self.loop.num_workers
        starts = np.zeros((p, width), dtype=np.int32)
        sizes = np.zeros((p, width), dtype=np.int32)
        for w, lst in per.items():
            for j, c in enumerate(lst):
                starts[w, j] = c.start
                sizes[w, j] = c.size
        return {"starts": starts, "sizes": sizes}


def plan_waves(sched: UserDefinedSchedule,
               loop: LoopSpec,
               *,
               history: Optional[LoopHistory] = None,
               user_data: Any = None,
               weights: Optional[Sequence[float]] = None,
               cost_model: Optional[Callable[[Chunk, int], float]] = None,
               check_coverage: bool = True) -> SchedulePlan:
    """Run the UDS to completion in batched (wave) order.

    ``cost_model(chunk, worker)`` — if given, predicted chunk costs are fed
    to ``next()`` as the ``elapsed`` of the previous chunk, letting adaptive
    schedulers plan against a model (they still re-adapt against *real*
    measurements between steps via ``history``).
    """
    ctx = SchedulerContext(loop=loop, history=history, user_data=user_data,
                           weights=weights)
    state = sched.start(ctx)
    if history is not None:
        history.open_invocation(loop.loop_id)

    p = loop.num_workers
    active = set(range(p))
    last: Dict[int, Optional[float]] = {w: None for w in range(p)}
    waves: List[List[Chunk]] = []
    guard = 0
    while active:
        wave: List[Chunk] = []
        for w in sorted(active):
            chunk = sched.next(state, w, last[w])
            if chunk is None:
                active.discard(w)
                continue
            last[w] = cost_model(chunk, w) if cost_model else None
            wave.append(chunk)
        if wave:
            waves.append(wave)
        guard += 1
        if guard > 10 * max(loop.trip_count, 1) + 16:
            raise RuntimeError(
                f"scheduler {getattr(sched, 'name', sched)!r} failed to drain "
                "the todo list (livelock guard tripped)")
    sched.finish(state)

    plan = SchedulePlan(loop=loop, waves=waves)
    if check_coverage and not chunks_cover(loop, plan.chunks):
        raise AssertionError(
            f"scheduler {getattr(sched, 'name', sched)!r} violated the todo-"
            f"list invariant under wave planning")
    return plan


def plan_schedule(sched: UserDefinedSchedule, n: int, num_workers: int,
                  **kw: Any) -> SchedulePlan:
    """Convenience: plan over logical iterations [0, n)."""
    loop = LoopSpec(lb=0, ub=n, num_workers=num_workers,
                    loop_id=kw.pop("loop_id", "plan"))
    return plan_waves(sched, loop, **kw)
