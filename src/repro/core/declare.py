"""Declare-style UDS specification (paper §4.2).

Mirrors the proposed OpenMP syntax::

    #pragma omp declare schedule(mystatic) arguments(2) \
        init(my_init(omp_lb, omp_ub, omp_inc, omp_arg0, omp_arg1)) \
        next(my_next(omp_lb_chunk, omp_ub_chunk, omp_arg0, omp_arg1)) \
        fini(my_fini(omp_arg1))

in Python::

    declare_schedule(
        "mystatic", arguments=2,
        init=call(my_init, OMP_LB, OMP_UB, OMP_INCR, OMP_CHUNKSZ, ARG(0), ARG(1)),
        next=call(my_next, OMP_LB_CHUNK, OMP_UB_CHUNK, OMP_CHUNK_INCR, ARG(0), ARG(1)),
        fini=call(my_fini, ARG(1)),
    )
    sched = use_schedule("mystatic", lr0, lr1)   # schedule(mystatic(&lr...))

The ``OMP_*`` sentinels are the paper's reserved positional markers: "the
reserved keywords omp_lb, omp_ub, omp_inc, omp_lb_chunk, and omp_ub_chunk
serve as markers for the compiler what information about the loop iteration
space to pass to the UDS".  ``OMP_LB_CHUNK``/``OMP_UB_CHUNK``/
``OMP_CHUNK_INCR`` are *out*-parameters (C ``int *``), modelled as ``Ref``
cells.  The user ``next`` function must return non-zero while chunks remain
and zero when the loop is complete — exactly the paper's contract.

``omp_get_thread_num()`` is provided so user functions can be written as in
the paper's Fig. 2 (thread identity comes from the runtime, not from an
argument).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.interface import Chunk, LoopSpec, SchedulerContext

__all__ = [
    "OMP_LB", "OMP_UB", "OMP_INCR", "OMP_CHUNKSZ", "OMP_NUM_WORKERS",
    "OMP_LB_CHUNK", "OMP_UB_CHUNK", "OMP_CHUNK_INCR", "ARG", "Ref",
    "call", "declare_schedule", "use_schedule", "omp_get_thread_num",
    "registered_schedules",
]


class _Marker:
    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:
        return self.name


# Reserved positional markers (paper §4.2).
OMP_LB = _Marker("omp_lb")
OMP_UB = _Marker("omp_ub")
OMP_INCR = _Marker("omp_inc")
OMP_CHUNKSZ = _Marker("omp_chunksz")
OMP_NUM_WORKERS = _Marker("omp_num_workers")
OMP_LB_CHUNK = _Marker("omp_lb_chunk")     # int* out
OMP_UB_CHUNK = _Marker("omp_ub_chunk")     # int* out
OMP_CHUNK_INCR = _Marker("omp_chunk_incr")  # int* out


class _UserArg(_Marker):
    def __init__(self, index: int):
        super().__init__(f"omp_arg{index}")
        self.index = index


def ARG(index: int) -> _UserArg:
    """The compiler-generated ``omp_argN`` user-argument markers."""
    return _UserArg(index)


class Ref:
    """Models a C out-parameter (``int *``)."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def set(self, value: int) -> None:
        self.value = int(value)


@dataclasses.dataclass
class _BoundCall:
    fn: Callable
    markers: Tuple[Any, ...]


def call(fn: Callable, *markers: Any) -> _BoundCall:
    """Bind a user function to positional markers (the 'declare' syntax)."""
    return _BoundCall(fn, markers)


# --------------------------------------------------------------------------
# Thread-identity shim: user scheduler code calls omp_get_thread_num() just
# like in the paper's Fig. 2.  The executor sets the current worker id
# around every scheduler operation.
_tls = threading.local()


def omp_get_thread_num() -> int:
    return getattr(_tls, "tid", 0)


def _set_thread_num(tid: int) -> None:
    _tls.tid = tid


# --------------------------------------------------------------------------
_REGISTRY: Dict[str, "DeclaredSchedule"] = {}


@dataclasses.dataclass
class DeclaredSchedule:
    """One ``declare_schedule`` registration: the bound init/next/fini
    calls plus the declared ``arguments`` arity (the paper's
    ``arguments(N)`` clause)."""

    name: str
    arguments: int
    init: Optional[_BoundCall]
    next: _BoundCall
    fini: Optional[_BoundCall]
    # Optional per-use argument factory: conjures fresh omp_argN values
    # (e.g. a new loop record) when the schedule is instantiated *by name*
    # through the unified ScheduleSpec registry ("uds:name") rather than
    # at a use site that passes the arguments itself.
    make_args: Optional[Callable[[], Sequence[Any]]] = None


def declare_schedule(name: str, *, arguments: int = 0,
                     init: Optional[_BoundCall] = None,
                     next: _BoundCall = None,
                     fini: Optional[_BoundCall] = None,
                     make_args: Optional[Callable[[], Sequence[Any]]] = None,
                     replace: bool = False) -> DeclaredSchedule:
    if next is None:
        raise ValueError("a UDS must define the next (dequeue) operation")
    if name in _REGISTRY and not replace:
        raise ValueError(f"schedule {name!r} already declared")
    decl = DeclaredSchedule(name, arguments, init, next, fini, make_args)
    # mirror first: it validates the name against the unified registry
    # (builtin shadowing), and must not leave a half-registered schedule
    _mirror_into_spec_registry(decl)
    _REGISTRY[name] = decl
    return decl


def _mirror_into_spec_registry(decl: DeclaredSchedule) -> None:
    """Absorb a declaration into the unified ScheduleSpec registry so it
    is reachable by name (``resolve("uds:<name>")``) from every substrate."""
    from repro.core import spec as _spec

    def factory(*user_args: Any, chunk: Optional[int] = None):
        if not user_args and decl.make_args is not None:
            user_args = tuple(decl.make_args())
        return _DeclaredAdapter(decl, user_args, chunk=chunk)

    # replace=True only replaces same-source entries: the registry itself
    # rejects shadowing a builtin / user / template name, atomically
    # (this runs before the declaration enters the declare registry)
    _spec.register_schedule(decl.name, source="declare",
                            chunk_param="chunk", replace=True)(factory)


def registered_schedules() -> List[str]:
    return sorted(_REGISTRY)


class _DeclaredAdapter:
    """Adapts a declared schedule to the internal three-op interface.

    This is the 'compiler' of the proposal: it resolves the positional
    markers against the actual loop descriptor and splices the user
    functions into the standard loop transformation pattern.
    """

    def __init__(self, decl: DeclaredSchedule, user_args: Sequence[Any],
                 chunk: Optional[int] = None):
        if len(user_args) != decl.arguments:
            raise TypeError(
                f"schedule {decl.name!r} declared with arguments"
                f"({decl.arguments}) but used with {len(user_args)}")
        self._decl = decl
        self._args = list(user_args)
        self.chunk = chunk      # spec chunksize, overrides loop.chunk
        self.name = decl.name

    def plan_key(self) -> None:
        # user functions + mutable loop records: never plan-cacheable
        return None

    # -- marker resolution -------------------------------------------------
    def _resolve(self, bound: _BoundCall, loop: LoopSpec,
                 refs: Dict[str, Ref]) -> List[Any]:
        out: List[Any] = []
        for m in bound.markers:
            if isinstance(m, _UserArg):
                out.append(self._args[m.index])
            elif m is OMP_LB:
                out.append(loop.lb)
            elif m is OMP_UB:
                out.append(loop.ub)
            elif m is OMP_INCR:
                out.append(loop.incr)
            elif m is OMP_CHUNKSZ:
                out.append(loop.chunk if loop.chunk is not None else 1)
            elif m is OMP_NUM_WORKERS:
                out.append(loop.num_workers)
            elif m in (OMP_LB_CHUNK, OMP_UB_CHUNK, OMP_CHUNK_INCR):
                out.append(refs[m.name])
            else:
                out.append(m)  # plain value captured in the declaration
        return out

    # -- three-op interface -------------------------------------------------
    def start(self, ctx: SchedulerContext) -> Any:
        loop = ctx.loop
        if self.chunk is not None:
            loop = dataclasses.replace(loop, chunk=self.chunk)
        if self._decl.init is not None:
            _set_thread_num(0)
            self._decl.init.fn(*self._resolve(self._decl.init, loop, {}))
        return {"loop": loop}

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        loop: LoopSpec = state["loop"]
        refs = {"omp_lb_chunk": Ref(), "omp_ub_chunk": Ref(),
                "omp_chunk_incr": Ref(loop.incr)}
        _set_thread_num(worker)
        has_work = self._decl.next.fn(
            *self._resolve(self._decl.next, loop, refs))
        if not has_work:
            return None
        # User code works in *source* index space (as in the paper's C
        # examples); convert [lb_chunk, ub_chunk) back to logical space.
        lo_src = refs["omp_lb_chunk"].value
        hi_src = refs["omp_ub_chunk"].value
        lo = (lo_src - loop.lb) // loop.incr
        hi = (hi_src - loop.lb) // loop.incr
        return Chunk(lo, hi, worker)

    def finish(self, state: Any) -> None:
        if self._decl.fini is not None:
            _set_thread_num(0)
            self._decl.fini.fn(
                *self._resolve(self._decl.fini, state["loop"], {}))


def use_schedule(name: str, *user_args: Any) -> _DeclaredAdapter:
    """``schedule(mystatic(&lr))`` — instantiate a declared schedule.

    When called with no arguments and the declaration supplied
    ``make_args``, fresh arguments are conjured from it (the by-name
    late-binding path the unified ScheduleSpec registry uses).
    """
    if name not in _REGISTRY:
        raise KeyError(f"no schedule declared under name {name!r}; "
                       f"known: {registered_schedules()}")
    decl = _REGISTRY[name]
    if not user_args and decl.make_args is not None and decl.arguments:
        user_args = tuple(decl.make_args())
    return _DeclaredAdapter(decl, user_args)
