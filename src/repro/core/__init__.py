"""repro.core — the paper's primary contribution.

A standard interface for user-defined scheduling (UDS), reproduced from
"Toward a Standard Interface for User-Defined Scheduling in OpenMP"
(Kale, Iwainsky, Klemm, Müller Korndörfer, Ciorba; 2019), adapted to a
JAX/TPU training & inference framework:

* ``interface``    — the six-op / reduced three-op UDS protocol
* ``declare``      — declare-style specification (paper §4.2)
* ``lambda_style`` — lambda-style specification (paper §4.1)
* ``history``      — cross-invocation measurement store (paper §3)
* ``executor``     — host-side OpenMP-semantics team executor
* ``wave``         — SPMD batched dequeue → static schedule plans
* ``schedulers``   — STATIC/SS/GSS/TSS/FAC/FAC2/WF2/AWF*/AF/RAND/FSC/steal
"""

from repro.core.interface import (
    Chunk,
    LoopSpec,
    SchedulerContext,
    SixOpSchedule,
    UserDefinedSchedule,
    chunks_cover,
    three_op_from_six,
)
from repro.core.history import ChunkRecord, InvocationRecord, LoopHistory
from repro.core.executor import LoopResult, run_loop, simulate_loop
from repro.core.wave import SchedulePlan, plan_schedule, plan_waves
from repro.core.schedulers import SCHEDULER_FACTORIES, make_scheduler

__all__ = [
    "Chunk", "LoopSpec", "SchedulerContext", "UserDefinedSchedule",
    "SixOpSchedule", "three_op_from_six", "chunks_cover",
    "ChunkRecord", "InvocationRecord", "LoopHistory",
    "LoopResult", "run_loop", "simulate_loop",
    "SchedulePlan", "plan_schedule", "plan_waves",
    "SCHEDULER_FACTORIES", "make_scheduler",
]
