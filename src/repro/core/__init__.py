"""repro.core — the paper's primary contribution.

A standard interface for user-defined scheduling (UDS), reproduced from
"Toward a Standard Interface for User-Defined Scheduling in OpenMP"
(Kale, Iwainsky, Klemm, Müller Korndörfer, Ciorba; 2019), adapted to a
JAX/TPU training & inference framework:

* ``interface``    — the six-op / reduced three-op UDS protocol
* ``spec``         — ScheduleSpec: the unified schedule clause (OpenMP-style
                     parsing, one registry, ``resolve``, ``runtime``
                     late-binding via $REPRO_SCHEDULE)
* ``declare``      — declare-style specification (paper §4.2)
* ``lambda_style`` — lambda-style specification (paper §4.1)
* ``history``      — cross-invocation measurement store (paper §3)
* ``telemetry``    — LoopTelemetry: the measure-stage recorder that flushes
                     chunk timings into the history, bumping the epoch that
                     invalidates cached adaptive plans
* ``plan``         — the materialized SchedulePlan IR (flat chunk tables)
* ``engine``       — PlanEngine: vectorized compilation + plan cache +
                     the single driver of the three-op state machine
* ``auto``         — schedule(auto): online portfolio selection over the
                     registry from LoopHistory telemetry (reselect stage)
* ``hier``         — schedule(hier): hierarchical composition — one clause
                     per mesh level, compiled to a ComposedPlan of
                     contiguous blocks
* ``executor``     — host-side OpenMP-semantics team executor / plan replay
* ``wave``         — SPMD wave views of engine plans
* ``schedulers``   — STATIC/SS/GSS/TSS/FAC/FAC2/WF2/AWF*/AF/RAND/FSC/steal
"""

from repro.core.interface import (
    Chunk,
    LoopSpec,
    SchedulerContext,
    SixOpSchedule,
    UserDefinedSchedule,
    chunks_cover,
    three_op_from_six,
)
from repro.core.history import ChunkRecord, InvocationRecord, LoopHistory
from repro.core.telemetry import (ChunkLedger, LoopTelemetry,
                                  MembershipEvent, ServeMeter)
from repro.core.plan import ComposedPlan, PlanProvenance, SchedulePlan
from repro.core.engine import (
    PlanEngine,
    ScheduleStream,
    get_engine,
    set_engine,
)
from repro.core.executor import LoopResult, execute_plan, run_loop, simulate_loop
from repro.core.wave import plan_schedule, plan_waves
from repro.core.schedulers import SCHEDULER_FACTORIES, make_scheduler
from repro.core.spec import (
    ScheduleSpec,
    SpecLike,
    describe,
    register_schedule,
    registered_names,
    resolve,
)
from repro.core.spec import parse as parse_schedule
from repro.core.auto import AutoScheduler
from repro.core.hier import HierSchedule

__all__ = [
    "Chunk", "LoopSpec", "SchedulerContext", "UserDefinedSchedule",
    "SixOpSchedule", "three_op_from_six", "chunks_cover",
    "ChunkRecord", "InvocationRecord", "LoopHistory",
    "ChunkLedger", "LoopTelemetry", "MembershipEvent", "ServeMeter",
    "ComposedPlan", "PlanProvenance", "SchedulePlan",
    "PlanEngine", "ScheduleStream", "get_engine", "set_engine",
    "LoopResult", "execute_plan", "run_loop", "simulate_loop",
    "plan_schedule", "plan_waves",
    "ScheduleSpec", "SpecLike", "parse_schedule", "resolve", "describe",
    "register_schedule", "registered_names", "AutoScheduler",
    "HierSchedule",
    "SCHEDULER_FACTORIES", "make_scheduler",
]
