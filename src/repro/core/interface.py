"""The paper's contribution: a standard interface for user-defined scheduling.

The paper (Kale et al., 2019) reduces *any* loop-scheduling strategy to six
operations over a conceptual todo-list of iteration chunks:

    init, enqueue, dequeue, finalize, begin-loop-body, end-loop-body

and shows that under OpenMP's loop constraints these merge into **three**
user-visible operations:

    start      = init + enqueue      (iteration space fixed before the loop)
    next       = end-body + dequeue + begin-body   (always back-to-back)
    finish     = finalize

This module defines those operations as a Python protocol.  Everything else in
this framework — the host-side executor, the SPMD wave planner, document
packing, MoE capacity, microbatch scheduling, Pallas chunk tables — consumes
schedulers ONLY through this interface, mirroring the paper's requirement that
a UDS be implementable "without having to alter the OpenMP runtime library".
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, Sequence

__all__ = [
    "LoopSpec",
    "Chunk",
    "SchedulerContext",
    "UserDefinedSchedule",
    "SixOpSchedule",
    "three_op_from_six",
    "normalize_loop",
]


@dataclasses.dataclass(frozen=True)
class LoopSpec:
    """The critical loop parameters a UDS must be able to access (paper §4).

    a) lower bound, b) upper bound, c) stride, d) custom data, e) chunk size.
    ``chunk`` here is the paper's "optimization parameter used to group
    multiple iterations into a single loop scheduling item", NOT necessarily
    the OpenMP schedule() chunksize.
    """

    lb: int                      # omp_lb    — first iteration (inclusive)
    ub: int                      # omp_ub    — end of iteration space (exclusive)
    incr: int = 1                # omp_inc   — loop stride
    chunk: Optional[int] = None  # grouping / minimum chunk parameter
    num_workers: int = 1         # team size P
    loop_id: str = "loop"        # identity for cross-invocation history

    def __post_init__(self) -> None:
        if self.incr == 0:
            raise ValueError("loop increment must be non-zero")
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if self.chunk is not None and self.chunk < 1:
            raise ValueError("chunk must be >= 1 when given")

    @property
    def trip_count(self) -> int:
        """Number of iterations N (OpenMP: known before loop execution)."""
        if self.incr > 0:
            span = self.ub - self.lb
        else:
            span = self.lb - self.ub
        if span <= 0:
            return 0
        return (span + abs(self.incr) - 1) // abs(self.incr)


class Chunk(NamedTuple):
    """A contiguous range of *logical* iterations [start, stop) dequeued by
    one worker.  Logical iteration k maps to source index lb + k*incr."""

    start: int   # logical start (0-based, inclusive)
    stop: int    # logical stop (exclusive)
    worker: int  # the worker (thread) that dequeued this chunk

    @property
    def size(self) -> int:
        return self.stop - self.start

    def indices(self, loop: LoopSpec) -> range:
        """Materialize the source-space indices of this chunk."""
        return range(
            loop.lb + self.start * loop.incr,
            loop.lb + self.stop * loop.incr,
            loop.incr,
        )


def normalize_loop(loop: LoopSpec) -> int:
    """Return trip count; schedulers operate on logical 0..N-1 space."""
    return loop.trip_count


@dataclasses.dataclass
class SchedulerContext:
    """Everything a scheduler may consult at ``start`` time.

    ``history`` is the paper's cross-invocation measurement store ("a
    mechanism to store and access the history of loop timings or other
    statistics across multiple loop iterations and/or invocations").
    ``user_data`` is the paper's custom-data pointer (``uds_data(void*)`` /
    ``omp_argN``).  ``telemetry`` (a ``core.telemetry.LoopTelemetry``), when
    attached, becomes the recording sink for the end-loop-body measurement
    hook: chunk records are buffered there and flushed into the history at
    invocation end (one epoch bump per invocation) instead of being written
    chunk-by-chunk.
    """

    loop: LoopSpec
    history: Any = None          # core.history.LoopHistory | None
    user_data: Any = None
    weights: Optional[Sequence[float]] = None  # per-worker capability weights
    telemetry: Any = None        # core.telemetry.LoopTelemetry | None


class UserDefinedSchedule(Protocol):
    """The reduced three-operation interface (paper §4, final form).

    Lifecycle (host-side, OpenMP semantics)::

        state = sched.start(ctx)
        while True:
            chunk = sched.next(state, worker, elapsed_of_previous_chunk)
            if chunk is None: break          # "return 0" in the paper
            ... execute chunk ...
        sched.finish(state)

    ``next`` receives the *measured execution time of the worker's previous
    chunk* (or None on first call / when measurement is disabled) — this is
    the merged end-body/dequeue/begin-body operation that adaptive strategies
    (paper type-(3)) require.  Non-adaptive strategies ignore it.
    """

    name: str

    def start(self, ctx: SchedulerContext) -> Any: ...

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]: ...

    def finish(self, state: Any) -> None: ...


class SixOpSchedule(Protocol):
    """The unreduced six-operation set (paper §3) — provided so the reduction
    claim is *demonstrated in code*: ``three_op_from_six`` adapts any six-op
    scheduler to the reduced interface, and tests assert the schedules are
    identical."""

    name: str

    def init(self, ctx: SchedulerContext) -> Any: ...
    def enqueue(self, state: Any) -> None: ...
    def dequeue(self, state: Any, worker: int) -> Optional[Chunk]: ...
    def begin_loop_body(self, state: Any, worker: int, chunk: Chunk) -> Any: ...
    def end_loop_body(self, state: Any, worker: int, chunk: Chunk,
                      token: Any, elapsed: Optional[float]) -> None: ...
    def finalize(self, state: Any) -> None: ...


class _SixOpAdapter:
    """Adapt a six-op scheduler to the reduced three-op interface.

    Implements exactly the merges the paper argues for:
      * ``start``  = init + enqueue  (iteration space fixed pre-loop),
      * ``next``   = end-loop-body(prev) + dequeue + begin-loop-body(new),
      * ``finish`` = finalize.
    """

    def __init__(self, six: SixOpSchedule):
        self._six = six
        self.name = six.name

    def start(self, ctx: SchedulerContext) -> Any:
        state = self._six.init(ctx)
        self._six.enqueue(state)
        # per-worker bookkeeping of the in-flight chunk for the merge
        return {"inner": state, "inflight": {}, "tokens": {}}

    def next(self, state: Any, worker: int,
             elapsed: Optional[float] = None) -> Optional[Chunk]:
        inner = state["inner"]
        prev = state["inflight"].pop(worker, None)
        if prev is not None:
            self._six.end_loop_body(inner, worker, prev,
                                    state["tokens"].pop(worker, None), elapsed)
        chunk = self._six.dequeue(inner, worker)
        if chunk is None:
            return None
        state["inflight"][worker] = chunk
        state["tokens"][worker] = self._six.begin_loop_body(inner, worker, chunk)
        return chunk

    def finish(self, state: Any) -> None:
        self._six.finalize(state["inner"])


def three_op_from_six(six: SixOpSchedule) -> UserDefinedSchedule:
    """The paper's reduction, as an executable adapter."""
    return _SixOpAdapter(six)


def chunks_cover(loop: LoopSpec, chunks: Sequence[Chunk]) -> bool:
    """Invariant checker: chunks exactly tile [0, N) with no overlap.

    This is the executable form of the paper's correctness requirement on a
    todo list: every iteration is enqueued once and dequeued exactly once.
    Used by tests and by the executor's debug mode.
    """
    n = loop.trip_count
    seen = sorted((c.start, c.stop) for c in chunks)
    pos = 0
    for start, stop in seen:
        if start != pos or stop < start:
            return False
        pos = stop
    return pos == n


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)
