"""LoopTelemetry — the *measure* stage of plan/execute/measure, closed.

The plan engine's history-epoch cache invalidation (PR 1) was wired but
starved: adaptive strategies key their cached plans on
``LoopHistory.measured_invocations``, yet nothing in the serving or
training steady state actually recorded measurements, so AWF*/AF plans
never replanned from real data.  This module is the missing recorder.

A :class:`LoopTelemetry` aggregates per-chunk / per-worker measured times
from any substrate —

* **serving**: per-chunk wall time (prefill + every decode step of the
  chunk's requests), accumulated across the interleaved slot loop via the
  stopwatch ledger API (``begin`` / ``add_time`` / ``end``),
* **training**: per-step wall times and token counts
  (``record_chunk`` once per step),
* **plan replay**: ``core.executor.execute_plan`` records each replayed
  chunk's modelled elapsed time,
* **straggler mitigation**: per-host step-time deltas,

— buffers them as :class:`~repro.core.history.ChunkRecord` entries, and
``flush()``-es them into a :class:`~repro.core.history.LoopHistory`.  The
flush is what bumps the history's *measured epoch*, which invalidates the
engine's cached adaptive plans: the next ``PlanEngine.plan()`` misses the
cache and replans against the new measurements.  That is the whole
telemetry → history → replan loop.

Recording discipline (no double counting):

* When a telemetry object is attached to a :class:`SchedulerContext`, the
  scheduler measurement hook (``SixOpBase.end_loop_body``) routes chunk
  records *through the telemetry buffer* instead of writing the history
  directly, and the engine's :class:`ScheduleStream` flushes on ``close``
  — one epoch bump per completed invocation.
* The ledger API buffers a chunk exactly once even when its elapsed time
  is also fed back through ``stream.next`` (the hook recognizes
  ledger-recorded chunks and skips them), so within-invocation adaptive
  strategies (AWF-B/C/D/E, AF) still see every measurement.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.history import ChunkRecord, LoopHistory
from repro.core.interface import Chunk

__all__ = ["ChunkLedger", "LoopTelemetry", "MembershipEvent", "ServeMeter"]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """A team-size change — worker loss or join — as a scheduling event.

    The paper's contract (start = init + enqueue for the *current* team)
    makes membership change just another replan trigger: the event is
    recorded into the loop's history as a measured sentinel invocation
    (:meth:`LoopTelemetry.record_membership`), which bumps the measured
    epoch that cached adaptive plans key on, so the next ``plan()`` for
    the loop re-runs ``init`` over the new team size.  ``lost`` /
    ``joined`` carry OLD-team worker ids; after a loss the surviving
    team is renumbered densely ``0..new_size-1``.
    """

    kind: str                       # "loss" | "join"
    old_size: int
    new_size: int
    lost: Tuple[int, ...] = ()      # old-team ids that left
    joined: Tuple[int, ...] = ()    # new-team ids that joined
    step: Optional[int] = None      # loop step/dispatch the event landed on

    def __post_init__(self):
        if self.kind not in ("loss", "join"):
            raise ValueError(f"kind must be 'loss' or 'join', "
                             f"got {self.kind!r}")
        if self.old_size < 1 or self.new_size < 1:
            raise ValueError(f"team sizes must be >= 1, got "
                             f"{self.old_size}->{self.new_size}")

    @property
    def tag(self) -> str:
        """Invocation provenance string.  Deliberately NOT a schedule
        clause: ``schedule(auto)`` scores only invocations tagged with
        candidate clauses, so membership sentinels never pollute its
        portfolio statistics."""
        return f"membership({self.old_size}->{self.new_size})"


def _percentile(xs: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile of a small host-side sample (no numpy —
    this module stays dependency-free)."""
    if not xs:
        return None
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[idx]


class ServeMeter:
    """Per-request serving observability: latency stamps + KV residency.

    The continuous-batching engine makes admission a *scheduling* decision
    (blocks free? chunk budget?), so the interesting latencies live
    between lifecycle edges the loop controls:

    * ``queue``      — arrival → admission (first blocks granted),
    * ``admission``  — admission → first generated token (chunked
      prefill time as the request experiences it),
    * ``e2e``        — arrival → finish.

    The loop calls :meth:`arrive` / :meth:`admit` / :meth:`first_token` /
    :meth:`finish` / :meth:`preempt` with its own clock value (pass
    ``time.perf_counter()``), and :meth:`blocks` whenever pool occupancy
    changes; :meth:`summary` reduces to the p50/p99 dictionary that
    ``last_stats`` and BENCH_serve.json carry.  A preempted request keeps
    its original arrival/admission stamps — preemption inflates its e2e
    latency, which is exactly what the percentiles should see.
    """

    def __init__(self) -> None:
        self._arrive: Dict[int, float] = {}
        self._admit: Dict[int, float] = {}
        self._first: Dict[int, float] = {}
        self._finish: Dict[int, float] = {}
        self.preemptions = 0
        self.preempted_rids: List[int] = []
        # time-weighted pool utilization: integral of used/total dt
        self._blk_t: Optional[float] = None
        self._blk_used = 0
        self._blk_total = 0
        self._blk_area = 0.0
        self._blk_span = 0.0

    # ---------------------------------------------------------- lifecycle
    def arrive(self, rid: int, t: float) -> None:
        self._arrive.setdefault(rid, t)

    def admit(self, rid: int, t: float) -> None:
        """First admission only: readmission after preemption does not
        reset the stamp (the wait is part of the request's latency)."""
        self._admit.setdefault(rid, t)

    def first_token(self, rid: int, t: float) -> None:
        self._first.setdefault(rid, t)

    def finish(self, rid: int, t: float) -> None:
        self._finish.setdefault(rid, t)

    def preempt(self, rid: int) -> None:
        self.preemptions += 1
        self.preempted_rids.append(rid)

    # --------------------------------------------------------- pool gauge
    def blocks(self, used: int, total: int, t: float) -> None:
        """Record pool occupancy at time ``t``; utilization is the
        time-weighted mean of ``used/total`` between samples."""
        if self._blk_t is not None and total > 0:
            dt = max(t - self._blk_t, 0.0)
            self._blk_area += dt * (self._blk_used / max(self._blk_total, 1))
            self._blk_span += dt
        self._blk_t = t
        self._blk_used = int(used)
        self._blk_total = int(total)

    # ------------------------------------------------------------ summary
    def _lat(self, a: Dict[int, float], b: Dict[int, float]) -> List[float]:
        return [b[r] - a[r] for r in b if r in a]

    def summary(self) -> Dict[str, Any]:
        queue = self._lat(self._arrive, self._admit)
        admission = self._lat(self._admit, self._first)
        e2e = self._lat(self._arrive, self._finish)
        util = (self._blk_area / self._blk_span
                if self._blk_span > 0 else None)
        return {
            "requests_seen": len(self._arrive),
            "requests_finished": len(self._finish),
            "queue_p50_s": _percentile(queue, 50),
            "queue_p99_s": _percentile(queue, 99),
            "admission_p50_s": _percentile(admission, 50),
            "admission_p99_s": _percentile(admission, 99),
            "e2e_p50_s": _percentile(e2e, 50),
            "e2e_p99_s": _percentile(e2e, 99),
            "kv_util_mean": round(util, 4) if util is not None else None,
            "preemptions": self.preemptions,
        }


@dataclasses.dataclass
class ChunkLedger:
    """An open stopwatch for one in-flight chunk on one worker."""

    worker: int
    start: int
    stop: int
    elapsed: float = 0.0
    tokens: int = 0

    @property
    def size(self) -> int:
        return self.stop - self.start


class LoopTelemetry:
    """Aggregate measured chunk times and flush them into a LoopHistory.

    Parameters
    ----------
    history:
        The cross-invocation store to flush into (may be None: telemetry
        then only aggregates statistics — useful for pure reporting).
    loop_id:
        History key; must match the ``LoopSpec.loop_id`` the adaptive
        scheduler plans against, or the epoch bump invalidates nothing.
        Left as None it is bound by ``PlanEngine.open_stream`` /
        ``execute_plan`` from the loop being measured.
    num_workers:
        Team size, for the summary's per-worker tables (optional).
    """

    def __init__(self, history: Optional[LoopHistory] = None,
                 loop_id: Optional[str] = None,
                 num_workers: Optional[int] = None) -> None:
        self.history = history
        self.loop_id = loop_id
        self.num_workers = num_workers
        self._open: Dict[int, ChunkLedger] = {}
        self._buffer: List[ChunkRecord] = []
        # chunks recorded via the ledger API; the scheduler hook skips
        # these so stream-fed elapsed values are not double counted
        self._ledgered: set = set()
        self.records_flushed = 0
        self.flushes = 0
        # aggregates (survive flushes)
        self._time: Dict[int, float] = {}
        self._iters: Dict[int, int] = {}
        self._chunks: Dict[int, int] = {}
        self._tokens: Dict[int, int] = {}
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------- epochs
    def epoch(self) -> int:
        """The measured-invocation epoch adaptive plan caches key on."""
        if self.history is None or self.loop_id is None:
            return 0
        return self.history.measured_invocations(self.loop_id)

    def record_membership(self, event: MembershipEvent) -> int:
        """Record a team-size change and return the new measured epoch.

        Writes one *measured* zero-size sentinel invocation (worker -1,
        elapsed 0.0) tagged with the event directly into the history —
        the same cache-invalidation edge as :meth:`flush`, so every
        cached adaptive plan for this loop misses on the next ``plan()``
        and replans over the new team.  The sentinel is invisible to the
        rate statistics (``worker_rates`` and the straggler mitigator
        both skip size-0 chunks) and survives history serialization
        (``from_json`` re-derives ``measured`` from the elapsed field).
        Also resizes the summary's team width to the new size.
        """
        self.num_workers = event.new_size
        if self.history is not None and self.loop_id is not None:
            self.history.open_invocation(self.loop_id, scheduler=event.tag)
            self.history.record(self.loop_id,
                                ChunkRecord(worker=-1, start=0, stop=0,
                                            elapsed=0.0))
            # close the sentinel invocation: ``history.record`` appends to
            # the LAST open invocation, so without a fresh boundary the
            # next flush would dump real chunks into the membership-tagged
            # invocation (polluting its provenance and eating the epoch
            # bump those chunks should have produced)
            self.history.open_invocation(self.loop_id)
        return self.epoch()

    # ------------------------------------------------- ledger (stopwatch)
    def begin(self, worker: int, chunk: Chunk) -> ChunkLedger:
        """Open a ledger for a freshly dequeued chunk.  An unclosed ledger
        for the same worker is ended (and buffered) first, so measurements
        are never silently dropped."""
        if worker in self._open:
            self.end(worker)
        led = ChunkLedger(worker=int(worker), start=int(chunk.start),
                          stop=int(chunk.stop))
        self._open[worker] = led
        return led

    def add_time(self, worker: int, dt: float, tokens: int = 0) -> None:
        """Attribute ``dt`` seconds (and optionally generated tokens) to
        the worker's open chunk — e.g. one prefill or one decode step."""
        led = self._open.get(worker)
        if led is None:
            return
        led.elapsed += float(dt)
        led.tokens += int(tokens)
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now - dt
        self._t_last = now

    def add_time_split(self, workers, dt: float, tokens=0) -> None:
        """Split one measured wall time equally across the open ledgers of
        ``workers`` — the batched serve step issues ONE jitted call that
        advances every active slot in lockstep, so each slot is charged
        ``dt / len(workers)``.  Per-slot attribution stays intact:
        AWF-family admission still replans from per-slot busy times.

        ``tokens`` may be an int (every worker credited the same count —
        the one-token-per-dispatch stepwise engine) or a mapping
        ``{worker: count}`` — the fused multi-token dispatch, where one
        call advances each slot by its OWN number of tokens (a slot that
        froze mid-dispatch produced fewer than the dispatch quantum), so
        the amortized wall-time share and the per-slot token credit stay
        consistent at any dispatch granularity."""
        ws = [w for w in workers if w in self._open]
        if not ws:
            return
        share = float(dt) / len(ws)
        for w in ws:
            tk = tokens.get(w, 0) if isinstance(tokens, dict) else tokens
            self.add_time(w, share, tokens=tk)

    def add_time_weighted(self, dt: float, weights: Dict[int, float],
                          tokens: Optional[Dict[int, int]] = None) -> None:
        """Split one measured wall time across the open ledgers
        proportionally to ``weights`` — per-host attribution for a
        lockstep data-parallel train step (the multi-host mirror of
        :meth:`add_time_split`): ONE jitted step advances every host, so
        host ``h`` is charged ``dt * w_h / sum(w)``, its modelled share
        of the step's compute, and credited its own token count.  In an
        emulated-host run the weights ARE the measurement model (token
        count x injected skew); a real multi-host deployment feeds
        genuine per-host clocks instead.  Hosts without an open ledger
        are skipped; a non-positive weight total falls back to an equal
        split so a measurement is never silently dropped."""
        ws = {w: max(float(weights.get(w, 0.0)), 0.0)
              for w in self._open}
        if not ws:
            return
        total = sum(ws.values())
        if total <= 0.0:
            ws = {w: 1.0 for w in ws}
            total = float(len(ws))
        for w, wt in ws.items():
            self.add_time(w, float(dt) * wt / total,
                          tokens=(tokens or {}).get(w, 0))

    def end(self, worker: int) -> Optional[float]:
        """Close the worker's ledger, buffer its record, and return the
        chunk's total elapsed time (the value to feed ``stream.next`` so
        within-invocation adaptive strategies see it)."""
        led = self._open.pop(worker, None)
        if led is None:
            return None
        self._buffer.append(ChunkRecord(worker=led.worker, start=led.start,
                                        stop=led.stop, elapsed=led.elapsed))
        self._ledgered.add((led.worker, led.start, led.stop))
        self._aggregate(led.worker, led.size, led.elapsed, led.tokens)
        return led.elapsed

    # ------------------------------------------------------ direct record
    def record_chunk(self, worker: int, start: int, stop: int,
                     elapsed: Optional[float], tokens: int = 0) -> None:
        """Buffer one measured chunk directly (train steps, plan replay,
        straggler deltas)."""
        self._buffer.append(ChunkRecord(worker=int(worker), start=int(start),
                                        stop=int(stop), elapsed=elapsed))
        if elapsed is not None:
            self._aggregate(int(worker), int(stop) - int(start),
                            float(elapsed), int(tokens))
            now = time.perf_counter()
            if self._t_first is None:
                self._t_first = now - elapsed
            self._t_last = now

    def record_chunks(self, workers, starts, stops, elapsed) -> None:
        """Bulk form of :meth:`record_chunk` over parallel sequences
        (``execute_plan``'s replay path — plain lists, one pass)."""
        append = self._buffer.append
        agg = self._aggregate
        for w, s, e, dt in zip(workers, starts, stops, elapsed):
            append(ChunkRecord(worker=w, start=s, stop=e, elapsed=dt))
            if dt is not None:
                agg(w, e - s, dt, 0)

    def observe_chunk(self, worker: int, chunk: Chunk,
                      elapsed: Optional[float]) -> None:
        """Scheduler measurement hook entry point
        (``SixOpBase.end_loop_body`` routes here when a telemetry object is
        attached to the context).  Chunks already buffered by the ledger
        API are skipped — their stream-fed elapsed is the same
        measurement."""
        key = (int(worker), int(chunk.start), int(chunk.stop))
        if key in self._ledgered:
            return
        self.record_chunk(worker, chunk.start, chunk.stop, elapsed)

    # --------------------------------------------------------------- flush
    def flush(self) -> int:
        """Write all buffered records (closing any open ledgers) into the
        history and return the resulting measured epoch.

        This is the cache-invalidation edge: the first record carrying a
        real elapsed time marks the current invocation *measured*, so the
        engine's next ``plan()`` for an adaptive scheduler misses its
        cached plan and replans from the new data.
        """
        for worker in list(self._open):
            self.end(worker)
        if self.history is not None and self._buffer:
            if self.loop_id is None:
                # refusing is better than recording under a wrong key the
                # adaptive scheduler will never look at (silent non-replan)
                raise ValueError(
                    "LoopTelemetry has a history but no loop_id; pass "
                    "loop_id= at construction or bind it via "
                    "PlanEngine.open_stream / execute_plan")
            for rec in self._buffer:
                self.history.record(self.loop_id, rec)
            self.records_flushed += len(self._buffer)
            self.flushes += 1
        self._buffer.clear()
        self._ledgered.clear()
        return self.epoch()

    @property
    def pending(self) -> int:
        """Buffered records not yet flushed (open ledgers excluded)."""
        return len(self._buffer)

    # ------------------------------------------------------------- summary
    def _aggregate(self, worker: int, iters: int, elapsed: float,
                   tokens: int) -> None:
        self._time[worker] = self._time.get(worker, 0.0) + elapsed
        self._iters[worker] = self._iters.get(worker, 0) + iters
        self._chunks[worker] = self._chunks.get(worker, 0) + 1
        self._tokens[worker] = self._tokens.get(worker, 0) + tokens

    def summary(self) -> Dict[str, Any]:
        """Machine-readable aggregate (what the bench harness serializes):
        per-worker busy time / iterations / rate, totals, and tok/s."""
        workers = sorted(self._time)
        if self.num_workers is not None:
            workers = list(range(self.num_workers))
        per_worker = {}
        for w in workers:
            t = self._time.get(w, 0.0)
            it = self._iters.get(w, 0)
            per_worker[w] = {
                "time_s": round(t, 6),
                "iters": it,
                "chunks": self._chunks.get(w, 0),
                "tokens": self._tokens.get(w, 0),
                "rate_s_per_iter": round(t / it, 9) if it else None,
            }
        total_time = sum(self._time.values())
        total_tokens = sum(self._tokens.values())
        wall = None
        if self._t_first is not None and self._t_last is not None:
            wall = max(self._t_last - self._t_first, 1e-12)
        times = [self._time.get(w, 0.0) for w in workers]
        mx = max(times, default=0.0)
        imbalance = (mx - sum(times) / len(times)) / mx if mx > 0 else 0.0
        return {
            "loop_id": self.loop_id,
            "per_worker": per_worker,
            "total_time_s": round(total_time, 6),
            "total_iters": sum(self._iters.values()),
            "total_tokens": total_tokens,
            "tok_s": (round(total_tokens / wall, 2)
                      if wall and total_tokens else None),
            "imbalance": round(imbalance, 4),
            "flushes": self.flushes,
            "records_flushed": self.records_flushed,
            "epoch": self.epoch(),
        }

    # ------------------------------------------------------------- helpers
    def worker_times(self) -> Dict[int, float]:
        return dict(self._time)

    def worker_iters(self) -> Dict[int, int]:
        return dict(self._iters)
