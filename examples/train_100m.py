"""End-to-end training driver: a ~110M-param dense LM with UDS-scheduled
packing, WF2-free (dense) pipeline, checkpoints.

Quick CPU demo (default, ~20M params, a few minutes):
    PYTHONPATH=src python examples/train_100m.py --steps 30

The full deliverable configuration (~110M params, 300 steps — sized for a
single accelerator or a small mesh; runs on CPU in hours):
    PYTHONPATH=src python examples/train_100m.py --full --steps 300
"""

import argparse

from repro.launch.train import TrainLoop
from repro.models.config import ModelConfig


def config(full: bool) -> ModelConfig:
    if full:
        return ModelConfig(                     # ~110M params (GPT-2-small-ish)
            name="demo-110m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32000,
            rope_theta=1e4, flash_threshold=2048)
    return ModelConfig(                         # ~21M params, quick CPU demo
        name="demo-20m", family="dense", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=8192,
        rope_theta=1e4, flash_threshold=2048)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--scheduler", default="fac2",
                    help="UDS for document packing (static|guided|fac2|awf|...)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config(args.full)
    n = cfg.param_count() / 1e6
    print(f"training {cfg.name}: {n:.1f}M params, packing scheduler "
          f"= {args.scheduler}")
    loop = TrainLoop(cfg, batch=args.batch, seq_len=args.seq_len,
                     scheduler=args.scheduler,
                     num_microbatches=args.microbatches,
                     ckpt_dir=args.ckpt_dir, data_sigma=1.2)
    losses = loop.run(args.steps, log_every=10)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
