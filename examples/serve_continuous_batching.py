"""Serving example: continuous batching where the admission policy is a UDS.

Requests are loop iterations; decode slots are workers; ``schedule(dynamic,1)``
is classic continuous batching (an idle slot admits the next request), and
guided/factoring policies admit request *chunks* when the queue is deep —
fewer admission decisions at the same utilization.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""

import time

import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Request, ServeLoop


def main() -> None:
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 32))
                                        ).astype(np.int32),
                    max_new=6)
            for i in range(12)]

    for sched in ("dynamic", "guided", "fac2"):
        loop = ServeLoop(cfg, slots=3, scheduler=sched)
        t0 = time.perf_counter()
        out = loop.run([Request(r.rid, r.prompt, r.max_new) for r in reqs])
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in out.values())
        print(f"schedule({sched:8s}): {len(out)} requests, {toks} tokens, "
              f"{dt:.2f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
