"""Fault-tolerance showcase: checkpoint/restart, injected failures, a
mid-run worker loss (membership replan), and AWF straggler mitigation —
the large-scale-runnability story exercised end to end on CPU.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import FailureInjector, TrainSupervisor
from repro.sched import StragglerMitigator


def main() -> None:
    cfg = get_smoke_config("phi3-mini-3.8b")
    model = get_model(cfg)
    opt_init, opt_update = make_optimizer("adamw", cosine_schedule(1e-3, 5, 200))
    step_raw = jax.jit(make_train_step(model, opt_update))
    B, S = 4, 64

    def init_state():
        params, _ = model.init(jax.random.PRNGKey(0), jnp.float32)
        return {"params": params, "opt": opt_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def make_step(state, step):
        key = jax.random.PRNGKey(step)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        params, opt, metrics = step_raw(state["params"], state["opt"],
                                        jnp.asarray(step, jnp.int32), batch)
        return ({"params": params, "opt": opt, "step": metrics["step"]},
                {"loss": float(metrics["loss"])})

    # step 8: flaky (restore + continue); step 17: hosts 2 and 3 are GONE
    # — a membership event: restore, resize the team, requeue their work
    injector = FailureInjector({8: "transient", 17: "host_loss:2,3"})
    with tempfile.TemporaryDirectory() as ckpt_dir:
        sup = TrainSupervisor(make_step, init_state, ckpt_dir,
                              ckpt_every=5, injector=injector, num_hosts=4,
                              on_membership=lambda ev: print(
                                  f"  [membership] {ev.kind}: "
                                  f"{ev.old_size} -> {ev.new_size} hosts "
                                  f"(lost {list(ev.lost)})"))
        report = sup.run(25)

    print(f"steps completed : {report.steps_completed}")
    print(f"restarts        : {report.restarts} "
          f"(injected at {injector.fired})")
    print(f"restored from   : steps {report.restores}")
    print(f"team            : 4 -> {report.final_hosts} hosts "
          f"({len(report.membership_events)} membership event)")
    print(f"loss            : {report.losses[0]:.3f} -> "
          f"{report.losses[-1]:.3f}")

    # straggler mitigation demo: host 2 is 40% slow
    m = StragglerMitigator(num_hosts=4)
    rng = np.random.default_rng(0)
    for _ in range(20):
        t = {h: 1.0 + 0.02 * rng.standard_normal() for h in range(4)}
        t[2] *= 1.4
        m.observe_step(t)
    print(f"stragglers      : {m.stragglers()} "
          f"(AWF weights {np.round(m.weights(), 3).tolist()})")
    print(f"token shares    : {m.token_shares(4096).tolist()} "
          "(slow host gets less work)")


if __name__ == "__main__":
    main()
