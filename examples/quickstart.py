"""Quickstart: define and use user-defined schedules (both paper interfaces).

Runs on CPU in seconds:
    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (LoopSpec, make_scheduler, plan_schedule,
                        simulate_loop)
from repro.core import declare, lambda_style as ls


# --- 1. a custom UDS in the declare style (paper §4.2) ----------------------
class LoopRecord:
    pass


def my_init(lb, ub, inc, chunk, nw, lr):
    lr.next = lb
    lr.ub, lr.chunk = ub, max(chunk, 1)


def my_next(lower, upper, step, lr):
    if lr.next >= lr.ub:
        return 0                      # the paper's "return 0"
    lower.set(lr.next)
    upper.set(min(lr.next + lr.chunk, lr.ub))
    lr.next = upper.value
    return 1


declare.declare_schedule(
    "blocks", arguments=1,
    init=declare.call(my_init, declare.OMP_LB, declare.OMP_UB,
                      declare.OMP_INCR, declare.OMP_CHUNKSZ,
                      declare.OMP_NUM_WORKERS, declare.ARG(0)),
    next=declare.call(my_next, declare.OMP_LB_CHUNK, declare.OMP_UB_CHUNK,
                      declare.OMP_CHUNK_INCR, declare.ARG(0)))

lr = LoopRecord()
res = simulate_loop(declare.use_schedule("blocks", lr),
                    LoopSpec(0, 100, num_workers=4, chunk=8),
                    np.ones(100))
print(f"declare-style 'blocks': makespan={res.makespan:.1f}, "
      f"dequeues={res.dequeues}")


# --- 2. the same idea in the lambda style (paper §4.1) ----------------------
def dequeue():
    ptr = ls.OMP_UDS_user_ptr()
    if ptr["next"] >= ls.OMP_UDS_loop_end():
        return 0
    c = ls.OMP_UDS_chunksize()
    ls.OMP_UDS_loop_chunk_start(ptr["next"])
    ls.OMP_UDS_loop_chunk_end(min(ptr["next"] + c, ls.OMP_UDS_loop_end()))
    ptr["next"] += c
    return 1


sched = ls.UDS(dequeue=dequeue, chunk=8, uds_data={"next": 0})
res = simulate_loop(sched, LoopSpec(0, 100, num_workers=4, chunk=8),
                    np.ones(100))
print(f"lambda-style inline UDS: makespan={res.makespan:.1f}")


# --- 3. the literature scheduler library under load imbalance ---------------
rng = np.random.default_rng(0)
costs = rng.lognormal(0.0, 1.5, 2000)          # heavy-tailed iterations
print("\nscheduler      makespan  (P=8, lognormal costs, overhead=1e-4)")
for name in ("static", "dynamic", "guided", "tss", "fac2", "awf_b", "af"):
    r = simulate_loop(make_scheduler(name),
                      LoopSpec(0, 2000, num_workers=8, loop_id=name),
                      costs, overhead=1e-4)
    print(f"  {name:12s} {r.makespan:8.2f}")


# --- 4. UDS chunk tables feeding a Pallas kernel -----------------------------
import jax.numpy as jnp
from repro.kernels.sched_matmul.ops import scheduled_matmul, tile_order_from_plan

plan = plan_schedule(make_scheduler("tss"), 8, 2)     # 8 M-tiles, 2 workers
order = tile_order_from_plan(plan, 8)
a = jnp.asarray(rng.normal(size=(8 * 128, 64)), jnp.float32)
b = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
out = scheduled_matmul(a, b, jnp.asarray(order), block_k=64, interpret=True)
err = float(jnp.abs(out - a @ b).max())
print(f"\nsched_matmul with TSS tile order {order.tolist()}: max|err|={err:.2e}")
